"""Continuous-batching inference demo: mixed prompt lengths, staggered
completion, slot reuse — across three architecture families.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax

from repro.configs import registry
from repro.models.transformer import init_lm
from repro.serve import Request, ServeEngine

for arch in ("qwen1.5-0.5b", "rwkv6-1.6b", "deepseek-v2-lite-16b"):
    cfg = registry.reduced_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=3, max_seq=64,
                      prefill_buckets=(8, 16))
    reqs = [Request(rid=i, prompt=list(range(1, 2 + i * 2)),
                    max_new=4 + 3 * (i % 3), temperature=0.0)
            for i in range(7)]
    t0 = time.perf_counter()
    outs = eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in outs.values())
    print(f"[{arch}] {len(outs)} requests / {toks} tokens in {dt:.1f}s; "
          f"engine stats: {eng.stats}")
    for rid in sorted(outs):
        print(f"   rid={rid} len={len(outs[rid])} -> {outs[rid][:6]}")
