"""Quickstart: the paper's dual-mode softmax unit in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. GELU via a two-element softmax (Eq. 8) — bit-accurate int32 unit.
2. The same unit in normal mode = attention softmax.
3. Drop the unit into a real transformer (attention softmax + FFN GELU
   both through the one unit) and run a forward pass.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import softmax_unit as unit
from repro.core.activations import gelu_exact
from repro.configs import registry
from repro.models.transformer import init_lm, lm_apply

# --- 1. GELU through the softmax datapath ---------------------------------
z = jnp.linspace(-4, 4, 9)
g_unit = unit.gelu_dualmode(z)            # z * softmax_1^2([k, -k])
g_ref = gelu_exact(z)
print("z           :", np.round(np.asarray(z), 2))
print("GELU (unit) :", np.round(np.asarray(g_unit), 4))
print("GELU (fp32) :", np.round(np.asarray(g_ref), 4))
print(f"max |err|   : {float(jnp.abs(g_unit - g_ref).max()):.2e}")

# --- 2. the same unit, normal mode -----------------------------------------
x = jax.random.normal(jax.random.PRNGKey(0), (2, 8)) * 3
p_unit = unit.softmax_dualmode(x)
p_ref = jax.nn.softmax(x, axis=-1)
print(f"softmax max |err| vs fp32: {float(jnp.abs(p_unit - p_ref).max()):.2e}")

# --- 3. a whole transformer on the unit ------------------------------------
cfg = registry.reduced_config("qwen1.5-0.5b").replace(
    softmax_impl="dualmode",           # attention softmax -> the unit
    activation="silu_dualmode")        # FFN SiLU -> the unit (exact mode)
params = init_lm(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
logits, _, _ = lm_apply(params, cfg, tokens)
ref_cfg = registry.reduced_config("qwen1.5-0.5b")
ref_logits, _, _ = lm_apply(params, ref_cfg, tokens)
drift = float(jnp.abs(jax.nn.softmax(logits[0, -1])
                      - jax.nn.softmax(ref_logits[0, -1])).max())
print(f"transformer forward OK; next-token distribution drift vs fp32: "
      f"{drift:.2e}")
