"""Paper Table I reproduction driver (example form): train a BERT-style
classifier, then swap GELU implementations at inference and compare.

    PYTHONPATH=src python examples/bert_accuracy_repro.py
"""
from benchmarks.table1_accuracy import downstream_accuracy, mae_table

print("GELU MAE vs FP32 erf-GELU (activation-scale inputs):")
for name, m in mae_table().items():
    print(f"  {name:14s} {m:.3e}")

print("\nDownstream accuracy (synthetic GLUE stand-in, same trained "
      "weights, GELU swapped at inference):")
for name, acc in downstream_accuracy().items():
    print(f"  {name:14s} {acc:.3f}")
print("\nClaim under test (paper Table I): swapping GELU -> dual-mode "
      "softmax unit leaves task accuracy unchanged.")
