"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the paper's dual-mode unit as the FFN activation, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --tiny   # quick

The model is a llama-style decoder (qwen1.5 family config scaled to
~100M params).  Training data is the deterministic synthetic bigram LM,
whose conditional entropy gives an exact loss floor to converge toward.
Kill it mid-run and rerun: it resumes from the newest checkpoint.
"""
import argparse

import jax

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data import SyntheticLM
from repro.launch.cells import count_params
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true",
                    help="~2M params (fast CPU smoke)")
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    base = registry.get_config("qwen1.5-0.5b")
    if args.tiny:
        cfg = registry.reduced_config("qwen1.5-0.5b").replace(vocab=512)
    else:
        # ~100M params: 8L x d640 x ffn2560, 16k vocab
        cfg = base.replace(n_layers=8, d_model=640, n_heads=10,
                           n_kv_heads=10, d_ff=2560, vocab=16384,
                           activation="silu_dualmode")
    n = count_params(cfg)
    print(f"[example] {cfg.name}-100m: {n['n_total']/1e6:.1f}M params "
          f"(activation={cfg.activation})")

    tcfg = TrainConfig(lr=6e-4, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps, checkpoint_every=50,
                       checkpoint_dir=args.ckpt, remat=True)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=0)
    trainer = Trainer(cfg, tcfg, global_batch=args.batch, seq_len=args.seq,
                      data=data)
    print(f"[example] loss floor (bigram entropy) ~ "
          f"{data.bigram_entropy():.3f} nats")
    metrics = trainer.run()
    print(f"[example] final: {metrics}")


if __name__ == "__main__":
    main()
