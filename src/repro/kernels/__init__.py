"""Pallas kernels for the dual-mode softmax/GELU unit (+ oracles)."""
from . import ops, ref  # noqa: F401
