"""Pallas kernels for the dual-mode softmax/GELU unit.

Layering (see ARCHITECTURE.md):

  datapath.py   the unit's float arithmetic — ONE definition, shared by
                every kernel body and the pure-JAX streamed paths
  tiling.py     one block-shape policy (pad-and-slice, no divisor search)
  dispatch.py   string -> implementation registry (softmax/attention/ffn)
  dualmode_softmax.py / fused_ffn.py / flash_attention.py   kernel bodies
  ops.py        public jit'd ops (custom VJPs, rank/padding handling)
  ref.py        pure-jnp oracles for the tests

This __init__ deliberately imports nothing: ``core.activations`` consumes
``kernels.datapath``, while ``kernels.ops`` consumes ``core.activations``
— eager submodule imports here would close that loop.  Import submodules
directly (``from repro.kernels import ops, ref`` still works).
"""
