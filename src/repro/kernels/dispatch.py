"""Kernel dispatch registry — every implementation string resolves here.

One place maps config strings to callables for the three datapath
consumers, so model code never switches on strings itself:

  softmax    'float' | 'dualmode' | 'dualmode_snap'   (attention probs)
  attention  'auto' | 'naive' | 'flash' | 'flash_pallas'
             | 'flash_pallas_int' | 'flash_pallas_int3'
             | 'flash_ring' | 'flash_decode'
  activation 'gelu_exact' | ... (delegates to repro.core.activations)
  ffn        'auto' | 'dense' | 'fused_pallas'  (gated-MLP execution)

Providers register themselves at import time (``models/attention.py``
registers 'naive', ``models/flash.py`` registers 'flash' and the 'auto'
rule, ``kernels/flash_attention.py`` registers 'flash_pallas',
``kernels/flash_attention_int.py`` registers 'flash_pallas_int' (the
one-sweep snapped-max unit) and 'flash_pallas_int3' (the three-sweep
pinned oracle), ``kernels/ring_attention.py`` registers 'flash_ring',
``kernels/fused_ffn.py`` registers 'fused_pallas') — the registry itself
imports nothing from ``models``, which keeps the layering acyclic:
datapath -> kernels -> dispatch -> models.

Attention resolution is softmax-aware: ``softmax_impl='dualmode'`` (or
'dualmode_snap') can never be silently dropped.  Every registration
DECLARES its capabilities (:class:`AttentionInfo`: honored softmax
modes, differentiability, s_q=1-only, mesh needs/safety) and resolution
is driven by those declarations — the table below is GENERATED from the
live registry by ``python -m repro.analysis.audit --write-docs`` and
re-derived on every audit run; a mismatch between this text and the
registry is a CI failure (the dispatch-table pass), so regenerate
instead of hand-editing.

[dispatch-table:begin]
Explicit `attn_impl` x `softmax_impl` — identical across phases
and meshes (the ring upgrade exists only inside 'auto').
'raise' cells are intentional ValueErrors: a dual-mode word
contract is never silently dropped.

| attn_impl | float | dualmode | dualmode_snap | grad | constraints |
|---|---|---|---|---|---|
| flash | ok | raise | raise | yes | - |
| flash_decode | ok | ok | ok | no | s_q=1 only |
| flash_pallas | ok | raise | raise | yes | - |
| flash_pallas_int | raise | ok | ok | no | - |
| flash_pallas_int3 | raise | ok | raise | no | - |
| flash_ring | ok | ok | ok | yes | needs mesh, mesh-safe |
| naive | ok | ok | ok | yes | mesh-safe |

`attn_impl='auto'` by (phase, mesh), resolved on the cpu/
interpret backend — on TPU the blocked float pick is
'flash_pallas' (``models.flash.blocked_impl``); everything else
is backend-independent.

| phase | mesh | float | dualmode | dualmode_snap |
|---|---|---|---|---|
| enc (128x128) | none | naive | naive | naive |
| enc (128x128) | ring8 | naive | naive | naive |
| prefill (4096x4096) | none | flash | flash_pallas_int | flash_pallas_int |
| prefill (4096x4096) | ring8 | flash_ring | flash_ring | flash_ring |
| decode (1x65536) | none | flash_decode | flash_decode | flash_decode |
| decode (1x65536) | ring8 | naive | naive | naive |

`norm_impl` providers — a fused provider must carry ALL three
block seams (``dispatch.NORM_SEAMS``); 'unfused' rows run the
reference norms in models/layers.py.  'auto' resolves to
'fused_pallas' on TPU and 'dense' elsewhere, for `norm_impl`
and `ffn_impl` alike (dispatch.resolve_norm / resolve_ffn).

| norm_impl | residual_norm | norm_linear | norm_glu |
|---|---|---|---|
| dense | unfused | unfused | unfused |
| fused_pallas | ok | ok | ok |
[dispatch-table:end]

Resolution is also shape- and backend-aware through the 'auto' rule
(registered by ``models/flash.py``): s_q=1 against a long KV cache picks
the split-KV decode kernel 'flash_decode' (in BOTH softmax modes — the
snapped monoid made the split fold word-exact); wide-q blocked shapes
pick the compiled Pallas kernel on TPU and the pure-JAX blocked path on
interpret backends (where interpret-mode Pallas loses to XLA).

Resolution is also mesh-aware when the caller opts in with a
``ring_axis``: when 'auto' lands on a blocked impl (float OR int) AND
the ambient ``with mesh:`` context shards the KV sequence over that
axis (both sequence dims divisible), the pick upgrades to 'flash_ring'
— the sequence-parallel ring composition of the same kernel, which
folds float (m, l, acc) or snapped int (m, S, acc) hop partials
according to ``softmax_impl``.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable

import jax

from repro.core import softmax_unit as _unit
from repro.core.activations import get_activation  # noqa: F401  (re-export)

# --------------------------------------------------------------------------
# softmax (attention probabilities)
# --------------------------------------------------------------------------

_SOFTMAX: dict[str, Callable] = {}


def register_softmax(name: str, fn: Callable) -> None:
    _SOFTMAX[name] = fn


def get_softmax(impl: str) -> Callable:
    """Attention-softmax implementation switch.

    'float'         : jax.nn.softmax (fp32 accumulate)
    'dualmode'      : the paper's unit, bit-accurate int path (jnp
                      emulation — same numerics the three-sweep Pallas
                      kernel executes)
    'dualmode_snap' : the snapped-max variant of the unit — the
                      whole-row oracle of every STREAMED dual-mode path
                      (one-sweep int flash, dual-mode decode/ring)
    """
    try:
        return _SOFTMAX[impl]
    except KeyError:
        raise ValueError(
            f"unknown softmax impl {impl!r}; have {sorted(_SOFTMAX)}")


register_softmax("float", lambda x: jax.nn.softmax(x, axis=-1))
register_softmax(
    "dualmode",
    lambda x: _unit.softmax_dualmode(
        x.astype("float32"), axis=-1).astype(x.dtype))
register_softmax(
    "dualmode_snap",
    lambda x: _unit.softmax_dualmode_snap(
        x.astype("float32"), axis=-1).astype(x.dtype))


# --------------------------------------------------------------------------
# attention (scores -> probs -> combine execution strategy)
# --------------------------------------------------------------------------

_ATTENTION: dict[str, Callable] = {}
_ATTENTION_AUTO: list[Callable] = []   # single slot: (s_q, t) -> impl name


@dataclass(frozen=True)
class AttentionInfo:
    """Declared capabilities of one registered attention impl.

    Resolution, the static auditor (``repro.analysis``), and the
    generated resolution table are all driven by these declarations, so
    an entry whose behavior drifts from its metadata fails the audit's
    dispatch-table pass.

    modes       softmax_impl values the entry honors.  Float-datapath
                kernels declare {'float'}; the int kernels declare the
                word contracts they stream ('dualmode_snap' for snapped
                words); dual-mode-CAPABLE entries declare all three and
                route internally.
    grad        differentiable (JAX AD or a custom VJP).  The int word
                paths are forward-only: step-quantized words have zero
                gradient a.e.
    decode_only entry contract is s_q == 1 rows (split-KV decode).
    needs_mesh  entry requires an ambient mesh carrying ``ring_axis``.
    mesh_safe   lowering against a KV-sequence-sharded cache does NOT
                materialize the full cache per chip (the whole-cache
                all-gather the analysis mesh-safety pass detects).
    note        one-line annotation for the generated table.
    """
    modes: frozenset[str]
    grad: bool
    decode_only: bool = False
    needs_mesh: bool = False
    mesh_safe: bool = False
    note: str = ""


_ATTENTION_INFO: dict[str, AttentionInfo] = {}

# analysis-only ambient-mesh override (see analysis_mesh below)
_MESH_OVERRIDE: list = []


def ambient_mesh():
    """The active ``with mesh:`` context's Mesh, or None.

    The ring-attention provider and the 'auto' ring upgrade read the
    mesh from here, so model code threads only the ``ring_axis`` string
    (configs stay pure data) and the same resolution works at trace
    time inside jit."""
    if _MESH_OVERRIDE:
        return _MESH_OVERRIDE[-1]
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    except (ImportError, AttributeError):     # pragma: no cover
        return None
    return None if mesh is None or mesh.empty else mesh


def ring_axis_size(ring_axis: str | None) -> int:
    """Size of ``ring_axis`` on the ambient mesh (0 when absent/unset)."""
    if not ring_axis:
        return 0
    mesh = ambient_mesh()
    if mesh is None or ring_axis not in mesh.axis_names:
        return 0
    return mesh.shape[ring_axis]


class _AnalysisMesh:
    """Resolution-level stand-in for a Mesh — only the attributes the
    resolver reads (``axis_names``, ``shape``, ``empty``) exist, so the
    dispatch matrix can be enumerated without emulated devices."""

    def __init__(self, axis_sizes: dict[str, int]):
        self.shape = dict(axis_sizes)
        self.axis_names = tuple(axis_sizes)
        self.empty = not axis_sizes

    def __repr__(self) -> str:   # pragma: no cover - debug aid
        return f"_AnalysisMesh({self.shape})"


@contextmanager
def analysis_mesh(axis_sizes: dict[str, int]):
    """Make :func:`ambient_mesh` report a mesh with ``axis_sizes``.

    ANALYSIS-ONLY seam: ``repro.analysis.dispatch_table`` enumerates the
    (impl x softmax x phase x mesh) resolution matrix under meshes that
    need not exist on the current backend.  Never use this to RUN a
    computation — only :func:`resolve_attention` and the 'auto' rule
    consult :func:`ambient_mesh`, and only they see the stand-in.
    """
    _MESH_OVERRIDE.append(_AnalysisMesh(axis_sizes))
    try:
        yield
    finally:
        _MESH_OVERRIDE.pop()


def register_attention(name: str, fn: Callable, *,
                       modes, grad: bool, decode_only: bool = False,
                       needs_mesh: bool = False, mesh_safe: bool = False,
                       note: str = "") -> None:
    """fn(q, k, v, *, q_pos, kv_valid, causal, scale, softmax_impl,
    ring_axis) -> (B,S,K,G,hv), plus the declared capability metadata
    (see :class:`AttentionInfo`).

    Every implementation takes the full contract (``ring_axis`` names
    the mesh axis the sequence-parallel ring rotates over; only
    'flash_ring' acts on it, the others accept and ignore it).  The
    ``modes`` declaration is load-bearing: resolution refuses any
    (impl, softmax_impl) pair outside it, and the entry itself must
    raise on undeclared modes — ``repro.analysis`` audits both sides,
    and an impl present in the registry WITHOUT metadata (registered by
    poking ``_ATTENTION`` directly) is an audit failure."""
    _ATTENTION[name] = fn
    _ATTENTION_INFO[name] = AttentionInfo(
        modes=frozenset(modes), grad=grad, decode_only=decode_only,
        needs_mesh=needs_mesh, mesh_safe=mesh_safe, note=note)


def attention_info(name: str) -> AttentionInfo:
    """Declared capabilities of ``name`` (loads providers on demand)."""
    if name not in _ATTENTION_INFO:
        _load_attention_providers()
    try:
        return _ATTENTION_INFO[name]
    except KeyError:
        raise ValueError(f"unknown attention impl {name!r}; "
                         f"have {sorted(_ATTENTION)}")


def attention_impls() -> list[str]:
    """All registered attention impl names (providers loaded)."""
    _load_attention_providers()
    return sorted(_ATTENTION)


def set_attention_auto_rule(rule: Callable) -> None:
    """rule(s_q, t_kv) -> implementation name, used for impl='auto'."""
    _ATTENTION_AUTO[:] = [rule]


def _load_attention_providers() -> None:
    """Import the provider modules so their registrations run — callers
    that resolve through the registry directly (serve engine, notebooks)
    must not depend on having imported ``repro.models`` first."""
    import repro.kernels.flash_attention      # noqa: F401
    import repro.kernels.flash_attention_int  # noqa: F401
    import repro.kernels.flash_decode         # noqa: F401
    import repro.kernels.ring_attention       # noqa: F401
    import repro.models.attention             # noqa: F401  (naive+flash+rule)


def resolve_attention(impl: str, s_q: int, t_kv: int,
                      softmax_impl: str = "float",
                      ring_axis: str | None = None) -> str:
    """Resolve 'auto' to a concrete implementation name.

    Softmax-aware and METADATA-DRIVEN: every impl's registration
    declares the softmax modes it honors (:class:`AttentionInfo`), and
    'dualmode'/'dualmode_snap' are numerics contracts, so resolution
    guarantees the bit-accurate unit actually executes —

      * 'auto' + a dual-mode contract: short rows stay 'naive'
        (whole-row unit); shapes the auto rule would stream through a
        float-only blocked path go to 'flash_pallas_int' (the unit's
        one-sweep snapped-max kernel) instead; s_q=1 decode rows keep
        'flash_decode' — its entry runs the snapped int split path, so
        long-cache dual-mode decode gets the same split-KV parallelism
        as float; the ring opt-in (below) upgrades to 'flash_ring',
        whose entry folds snapped int hop partials.
      * any explicit impl + a softmax mode outside its declared
        ``modes``: ValueError — e.g. 'flash'/'flash_pallas' (float
        log-domain by construction) with 'dualmode', or
        'flash_pallas_int'/'flash_pallas_int3' (the kernels ARE the
        unit) with 'float'.  Silently dropping a word contract is
        exactly the bug this guard exists to prevent.

    Mesh-aware (opt-in): with a non-empty ``ring_axis``, an 'auto' pick
    of a blocked path — float OR int — upgrades to 'flash_ring' when the
    ambient ``with mesh:`` context carries that axis with size > 1 and
    both sequence dims divide it — the shapes where the KV sequence
    actually shards.  Configs opt in via ``ModelConfig.ring_axis``; the
    default (``""``) never changes today's resolution.
    """
    if softmax_impl not in _SOFTMAX:
        raise ValueError(f"unknown softmax impl {softmax_impl!r}; "
                         f"have {sorted(_SOFTMAX)}")
    if impl == "auto" and not _ATTENTION_AUTO:
        _load_attention_providers()
    if impl == "auto":
        impl = _ATTENTION_AUTO[0](s_q, t_kv) if _ATTENTION_AUTO else "naive"
        if softmax_impl not in attention_info(impl).modes:
            # the auto rule picked a float-only blocked path under a
            # dual-mode word contract: the one-sweep snapped-max unit
            # kernel streams the same shapes bit-accurately
            impl = "flash_pallas_int"
        if impl in ("flash", "flash_pallas", "flash_pallas_int"):
            n = ring_axis_size(ring_axis)
            if n > 1 and s_q % n == 0 and t_kv % n == 0:
                # the ring entry folds float (m, l, acc) or snapped int
                # (m, S, acc) hop partials according to softmax_impl
                impl = "flash_ring"
    else:
        info = attention_info(impl)        # raises on unknown impls
        if softmax_impl not in info.modes:
            raise ValueError(
                f"attn_impl={impl!r} declares softmax modes "
                f"{sorted(info.modes)} and cannot honor "
                f"softmax_impl={softmax_impl!r} — the dualmode word "
                "contract is never silently dropped; use attn_impl="
                "'auto' (routes to 'naive'/'flash_pallas_int'/"
                "'flash_decode'), or an impl declaring the mode")
    if impl not in _ATTENTION:
        _load_attention_providers()
    if impl not in _ATTENTION:
        raise ValueError(
            f"unknown attention impl {impl!r}; have {sorted(_ATTENTION)}")
    return impl


def get_attention(impl: str) -> Callable:
    if impl not in _ATTENTION:
        _load_attention_providers()
    return _ATTENTION[impl]


# --------------------------------------------------------------------------
# paged attention (block-table KV gather variants)
# --------------------------------------------------------------------------

# Parallel registry for implementations that read K/V through a block
# pool + per-request block table instead of contiguous (B, T, ...) rows.
# Keyed by the SAME names as _ATTENTION: resolution stays the dense
# resolve_attention above (paged changes the memory layout, not the
# numerics contract), and the model layer asks get_paged_attention for
# the resolved name — falling back to a dense gather when the impl has
# no native block-table mode.

_PAGED_ATTENTION: dict[str, Callable] = {}


def register_paged_attention(name: str, fn: Callable) -> None:
    """fn(q, k_pool, v_pool, *, block_tables, q_pos, kv_valid, causal,
    scale, softmax_impl, ring_axis) -> (B,1,K,G,hv).

    ``k_pool``/``v_pool`` are (N_blocks, block_size, K, h) pools;
    ``block_tables`` is a (B, max_blocks) int32 map from each row's
    logical block index to its pool block (sentinel block 0 for entries
    past the row's length).  Everything after the layout — masking,
    causality, the partial-merge fold — matches the dense contract."""
    _PAGED_ATTENTION[name] = fn


def get_paged_attention(name: str) -> Callable | None:
    """The block-table native variant of ``name``, or None when the impl
    only speaks contiguous rows (caller gathers dense and dispatches)."""
    if name not in _PAGED_ATTENTION:
        _load_attention_providers()
    return _PAGED_ATTENTION.get(name)


# --------------------------------------------------------------------------
# FFN (gated-MLP execution strategy)
# --------------------------------------------------------------------------

_FFN: dict[str, Callable | None] = {"dense": None}


def register_ffn(name: str, fn: Callable) -> None:
    """fn(x2d, wg, wu, mode) -> (M, F) fused gate-matmul + activation."""
    _FFN[name] = fn


def resolve_ffn(impl: str) -> str:
    """Resolve ``ffn_impl='auto'`` to a concrete execution strategy.

    'auto' picks 'fused_pallas' on TPU — the compiled fused gated-matmul
    + activation epilogue — and 'dense' everywhere else, where
    interpret-mode Pallas loses to the plain XLA graph.  Explicit strings
    ('dense', 'fused_pallas') pass through untouched, so a config that
    pins an impl keeps it on every backend.
    """
    if impl == "auto":
        return "fused_pallas" if jax.default_backend() == "tpu" else "dense"
    return impl


def get_ffn(impl: str) -> Callable | None:
    """None means the plain (unfused) path; otherwise a fused GLU kernel."""
    if impl not in _FFN and impl == "fused_pallas":
        import repro.kernels.fused_ffn  # noqa: F401  (self-registers)
    try:
        return _FFN[impl]
    except KeyError:
        raise ValueError(f"unknown ffn impl {impl!r}; have {sorted(_FFN)}")


# --------------------------------------------------------------------------
# Norm (fused norm-seam execution strategy)
# --------------------------------------------------------------------------
#
# A norm provider is a dict of the block's three fusable seams —
#   'residual_norm' (x, r, g, b, kind, eps)  -> (x + r, norm(x + r))
#   'norm_linear'   (x, g, b, w, kind, eps)  -> norm(x) @ w
#   'norm_glu'      (x, g, b, wg, wu, kind, eps, mode) -> act(h@wg)*(h@wu)
# — registered as one unit so the dispatch-table auditor can check the
# provider contract (all three seams present and callable).

NORM_SEAMS = ("residual_norm", "norm_linear", "norm_glu")

_NORM: dict[str, dict[str, Callable] | None] = {"dense": None}


def register_norm(name: str, seams: dict[str, Callable]) -> None:
    """Register a fused-norm provider: a dict keyed by NORM_SEAMS."""
    _NORM[name] = seams


def resolve_norm(impl: str) -> str:
    """Resolve ``norm_impl='auto'`` — same policy as :func:`resolve_ffn`:
    'fused_pallas' on TPU, 'dense' elsewhere; explicit strings pass
    through untouched."""
    if impl == "auto":
        return "fused_pallas" if jax.default_backend() == "tpu" else "dense"
    return impl


def get_norm(impl: str) -> dict[str, Callable] | None:
    """None means the plain (unfused) norms; otherwise the seam dict."""
    if impl not in _NORM and impl == "fused_pallas":
        import repro.kernels.fused_norm  # noqa: F401  (self-registers)
    try:
        return _NORM[impl]
    except KeyError:
        raise ValueError(f"unknown norm impl {impl!r}; have {sorted(_NORM)}")
