"""Kernel dispatch registry — every implementation string resolves here.

One place maps config strings to callables for the three datapath
consumers, so model code never switches on strings itself:

  softmax    'float' | 'dualmode' | 'dualmode_snap'   (attention probs)
  attention  'auto' | 'naive' | 'flash' | 'flash_pallas'
             | 'flash_pallas_int' | 'flash_pallas_int3'
             | 'flash_ring' | 'flash_decode'
  activation 'gelu_exact' | ... (delegates to repro.core.activations)
  ffn        'auto' | 'dense' | 'fused_pallas'  (gated-MLP execution)

Providers register themselves at import time (``models/attention.py``
registers 'naive', ``models/flash.py`` registers 'flash' and the 'auto'
rule, ``kernels/flash_attention.py`` registers 'flash_pallas',
``kernels/flash_attention_int.py`` registers 'flash_pallas_int' (the
one-sweep snapped-max unit) and 'flash_pallas_int3' (the three-sweep
pinned oracle), ``kernels/ring_attention.py`` registers 'flash_ring',
``kernels/fused_ffn.py`` registers 'fused_pallas') — the registry itself
imports nothing from ``models``, which keeps the layering acyclic:
datapath -> kernels -> dispatch -> models.

Attention resolution is softmax-aware: ``softmax_impl='dualmode'`` can
never be silently dropped.  The resolution table:

  impl        + dualmode                    + float
  ----------- ----------------------------- -------------------------
  auto        short rows -> 'naive';        shape/backend/mesh rule
              blocked -> 'flash_pallas_int' (flash / flash_pallas /
              (one-sweep snapped unit);     flash_decode / flash_ring
              s_q=1 long KV ->              / naive)
              'flash_decode' (int split
              path); ring opt-in ->
              'flash_ring' (int hop fold)
  flash /     ValueError (float log-domain  passes through
  flash_pallas by construction)
  flash_decode runs its int snapped split   runs the float split path
  flash_ring   path (dual-mode capable)     runs the float hop fold
  flash_pallas passes through               ValueError (the kernels
  _int / _int3                              ARE the unit)

Resolution is also shape- and backend-aware through the 'auto' rule
(registered by ``models/flash.py``): s_q=1 against a long KV cache picks
the split-KV decode kernel 'flash_decode' (in BOTH softmax modes — the
snapped monoid made the split fold word-exact); wide-q blocked shapes
pick the compiled Pallas kernel on TPU and the pure-JAX blocked path on
interpret backends (where interpret-mode Pallas loses to XLA).

Resolution is also mesh-aware when the caller opts in with a
``ring_axis``: when 'auto' lands on a blocked impl (float OR int) AND
the ambient ``with mesh:`` context shards the KV sequence over that
axis (both sequence dims divisible), the pick upgrades to 'flash_ring'
— the sequence-parallel ring composition of the same kernel, which
folds float (m, l, acc) or snapped int (m, S, acc) hop partials
according to ``softmax_impl``.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.core import softmax_unit as _unit
from repro.core.activations import get_activation  # noqa: F401  (re-export)

# --------------------------------------------------------------------------
# softmax (attention probabilities)
# --------------------------------------------------------------------------

_SOFTMAX: dict[str, Callable] = {}


def register_softmax(name: str, fn: Callable) -> None:
    _SOFTMAX[name] = fn


def get_softmax(impl: str) -> Callable:
    """Attention-softmax implementation switch.

    'float'         : jax.nn.softmax (fp32 accumulate)
    'dualmode'      : the paper's unit, bit-accurate int path (jnp
                      emulation — same numerics the three-sweep Pallas
                      kernel executes)
    'dualmode_snap' : the snapped-max variant of the unit — the
                      whole-row oracle of every STREAMED dual-mode path
                      (one-sweep int flash, dual-mode decode/ring)
    """
    try:
        return _SOFTMAX[impl]
    except KeyError:
        raise ValueError(
            f"unknown softmax impl {impl!r}; have {sorted(_SOFTMAX)}")


register_softmax("float", lambda x: jax.nn.softmax(x, axis=-1))
register_softmax(
    "dualmode",
    lambda x: _unit.softmax_dualmode(
        x.astype("float32"), axis=-1).astype(x.dtype))
register_softmax(
    "dualmode_snap",
    lambda x: _unit.softmax_dualmode_snap(
        x.astype("float32"), axis=-1).astype(x.dtype))


# --------------------------------------------------------------------------
# attention (scores -> probs -> combine execution strategy)
# --------------------------------------------------------------------------

_ATTENTION: dict[str, Callable] = {}
_ATTENTION_AUTO: list[Callable] = []   # single slot: (s_q, t) -> impl name


# blocked impls that run the float log-domain datapath by construction —
# resolution refuses to pair these with softmax_impl='dualmode' (the
# bit-accurate words come from 'naive', 'flash_pallas_int', or the
# dual-mode-capable 'flash_decode'/'flash_ring' entries, which route to
# their int snapped paths internally)
FLOAT_BLOCKED_ATTENTION = frozenset({"flash", "flash_pallas"})

# kernels that ARE the bit-accurate unit — they cannot produce float-path
# words, so resolution refuses any softmax_impl but 'dualmode'
INT_ATTENTION = frozenset({"flash_pallas_int", "flash_pallas_int3"})


def ambient_mesh():
    """The active ``with mesh:`` context's Mesh, or None.

    The ring-attention provider and the 'auto' ring upgrade read the
    mesh from here, so model code threads only the ``ring_axis`` string
    (configs stay pure data) and the same resolution works at trace
    time inside jit."""
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    except (ImportError, AttributeError):     # pragma: no cover
        return None
    return None if mesh is None or mesh.empty else mesh


def ring_axis_size(ring_axis: str | None) -> int:
    """Size of ``ring_axis`` on the ambient mesh (0 when absent/unset)."""
    if not ring_axis:
        return 0
    mesh = ambient_mesh()
    if mesh is None or ring_axis not in mesh.axis_names:
        return 0
    return mesh.shape[ring_axis]


def register_attention(name: str, fn: Callable) -> None:
    """fn(q, k, v, *, q_pos, kv_valid, causal, scale, softmax_impl,
    ring_axis) -> (B,S,K,G,hv).

    Every implementation takes the full contract (``ring_axis`` names
    the mesh axis the sequence-parallel ring rotates over; only
    'flash_ring' acts on it, the others accept and ignore it).  'naive'
    honors any ``softmax_impl``; 'flash_decode' and 'flash_ring' are
    dual-mode CAPABLE — their entries route to the float or the snapped
    int path on ``softmax_impl``; the float blocked ones ('flash',
    'flash_pallas') are the float log-domain form by construction and
    are never resolved with 'dualmode' (see :func:`resolve_attention`);
    'flash_pallas_int'/'flash_pallas_int3' ARE the dual-mode unit
    streamed and require 'dualmode'."""
    _ATTENTION[name] = fn


def set_attention_auto_rule(rule: Callable) -> None:
    """rule(s_q, t_kv) -> implementation name, used for impl='auto'."""
    _ATTENTION_AUTO[:] = [rule]


def _load_attention_providers() -> None:
    """Import the provider modules so their registrations run — callers
    that resolve through the registry directly (serve engine, notebooks)
    must not depend on having imported ``repro.models`` first."""
    import repro.kernels.flash_attention      # noqa: F401
    import repro.kernels.flash_attention_int  # noqa: F401
    import repro.kernels.flash_decode         # noqa: F401
    import repro.kernels.ring_attention       # noqa: F401
    import repro.models.attention             # noqa: F401  (naive+flash+rule)


def resolve_attention(impl: str, s_q: int, t_kv: int,
                      softmax_impl: str = "float",
                      ring_axis: str | None = None) -> str:
    """Resolve 'auto' to a concrete implementation name.

    Softmax-aware: 'dualmode' is a numerics contract, so resolution
    guarantees the bit-accurate unit actually executes —

      * 'auto' + 'dualmode': short rows stay 'naive' (whole-row unit);
        shapes the auto rule would stream go to 'flash_pallas_int' (the
        unit's one-sweep snapped-max kernel), never a float path; s_q=1
        decode rows keep 'flash_decode' — its entry runs the snapped int
        split path, so long-cache dual-mode decode gets the same split-KV
        parallelism as float; the ring opt-in (below) upgrades to
        'flash_ring', whose entry folds snapped int hop partials.
      * explicit 'flash'/'flash_pallas' + 'dualmode': ValueError — these
        run the float datapath by construction, and silently dropping
        the unit is exactly the bug this guard exists to prevent.
      * explicit 'flash_pallas_int'/'flash_pallas_int3' + anything but
        'dualmode': ValueError (the kernels ARE the unit; they cannot
        produce float-path words).

    Mesh-aware (opt-in): with a non-empty ``ring_axis``, an 'auto' pick
    of a blocked path — float OR int — upgrades to 'flash_ring' when the
    ambient ``with mesh:`` context carries that axis with size > 1 and
    both sequence dims divide it — the shapes where the KV sequence
    actually shards.  Configs opt in via ``ModelConfig.ring_axis``; the
    default (``""``) never changes today's resolution.
    """
    if impl == "auto" and not _ATTENTION_AUTO:
        _load_attention_providers()
    if impl == "auto":
        impl = _ATTENTION_AUTO[0](s_q, t_kv) if _ATTENTION_AUTO else "naive"
        if softmax_impl == "dualmode" and impl in FLOAT_BLOCKED_ATTENTION:
            # blocked dual-mode: the one-sweep snapped-max unit kernel
            impl = "flash_pallas_int"
        if impl in ("flash", "flash_pallas", "flash_pallas_int"):
            n = ring_axis_size(ring_axis)
            if n > 1 and s_q % n == 0 and t_kv % n == 0:
                # the ring entry folds float (m, l, acc) or snapped int
                # (m, S, acc) hop partials according to softmax_impl
                impl = "flash_ring"
    elif softmax_impl == "dualmode" and impl in FLOAT_BLOCKED_ATTENTION:
        raise ValueError(
            f"attn_impl={impl!r} runs the float log-domain datapath and "
            "cannot honor softmax_impl='dualmode' — use attn_impl='auto' "
            "(routes to 'naive'/'flash_pallas_int'/'flash_decode'), "
            "'naive', or 'flash_pallas_int'")
    if impl in INT_ATTENTION and softmax_impl != "dualmode":
        raise ValueError(
            f"attn_impl={impl!r} is the bit-accurate dual-mode "
            f"unit; softmax_impl={softmax_impl!r} would be ignored — set "
            "softmax_impl='dualmode' (or pick a float attention impl)")
    if impl not in _ATTENTION:
        _load_attention_providers()
    if impl not in _ATTENTION:
        raise ValueError(
            f"unknown attention impl {impl!r}; have {sorted(_ATTENTION)}")
    return impl


def get_attention(impl: str) -> Callable:
    if impl not in _ATTENTION:
        _load_attention_providers()
    return _ATTENTION[impl]


# --------------------------------------------------------------------------
# paged attention (block-table KV gather variants)
# --------------------------------------------------------------------------

# Parallel registry for implementations that read K/V through a block
# pool + per-request block table instead of contiguous (B, T, ...) rows.
# Keyed by the SAME names as _ATTENTION: resolution stays the dense
# resolve_attention above (paged changes the memory layout, not the
# numerics contract), and the model layer asks get_paged_attention for
# the resolved name — falling back to a dense gather when the impl has
# no native block-table mode.

_PAGED_ATTENTION: dict[str, Callable] = {}


def register_paged_attention(name: str, fn: Callable) -> None:
    """fn(q, k_pool, v_pool, *, block_tables, q_pos, kv_valid, causal,
    scale, softmax_impl, ring_axis) -> (B,1,K,G,hv).

    ``k_pool``/``v_pool`` are (N_blocks, block_size, K, h) pools;
    ``block_tables`` is a (B, max_blocks) int32 map from each row's
    logical block index to its pool block (sentinel block 0 for entries
    past the row's length).  Everything after the layout — masking,
    causality, the partial-merge fold — matches the dense contract."""
    _PAGED_ATTENTION[name] = fn


def get_paged_attention(name: str) -> Callable | None:
    """The block-table native variant of ``name``, or None when the impl
    only speaks contiguous rows (caller gathers dense and dispatches)."""
    if name not in _PAGED_ATTENTION:
        _load_attention_providers()
    return _PAGED_ATTENTION.get(name)


# --------------------------------------------------------------------------
# FFN (gated-MLP execution strategy)
# --------------------------------------------------------------------------

_FFN: dict[str, Callable | None] = {"dense": None}


def register_ffn(name: str, fn: Callable) -> None:
    """fn(x2d, wg, wu, mode) -> (M, F) fused gate-matmul + activation."""
    _FFN[name] = fn


def resolve_ffn(impl: str) -> str:
    """Resolve ``ffn_impl='auto'`` to a concrete execution strategy.

    'auto' picks 'fused_pallas' on TPU — the compiled fused gated-matmul
    + activation epilogue — and 'dense' everywhere else, where
    interpret-mode Pallas loses to the plain XLA graph.  Explicit strings
    ('dense', 'fused_pallas') pass through untouched, so a config that
    pins an impl keeps it on every backend.
    """
    if impl == "auto":
        return "fused_pallas" if jax.default_backend() == "tpu" else "dense"
    return impl


def get_ffn(impl: str) -> Callable | None:
    """None means the plain (unfused) path; otherwise a fused GLU kernel."""
    if impl not in _FFN and impl == "fused_pallas":
        import repro.kernels.fused_ffn  # noqa: F401  (self-registers)
    try:
        return _FFN[impl]
    except KeyError:
        raise ValueError(f"unknown ffn impl {impl!r}; have {sorted(_FFN)}")
