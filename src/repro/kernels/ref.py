"""Pure-jnp oracles for every kernel in this package.

Two tiers per kernel:
  *_bitexact : the same int32 algorithm in plain jnp (repro.core) — kernels
               in precision='int' must match these EXACTLY (atol=0).
  *_exact    : textbook float math — kernels must match within the unit's
               approximation error (documented bounds, cf. paper Table I).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import softmax_unit as unit
from repro.core.activations import gelu_exact, gelu_tanh, silu


# bit-exact oracles (same arithmetic, no pallas)
def softmax_bitexact(x):
    return unit.softmax_dualmode(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def gelu_bitexact(z):
    return unit.gelu_dualmode(z.astype(jnp.float32)).astype(z.dtype)


def silu_bitexact(z):
    return unit.silu_dualmode(z.astype(jnp.float32)).astype(z.dtype)


# float-exact oracles
def softmax_exact(x):
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def fused_glu_ref(x, wg, wu, mode: str = "silu"):
    """Oracle for kernels/fused_ffn.py: unfused matmuls + float activation."""
    g = (x.astype(jnp.float32) @ wg.astype(jnp.float32))
    u = (x.astype(jnp.float32) @ wu.astype(jnp.float32))
    act = gelu_tanh(g) if mode == "gelu" else silu(g)
    return (act * u).astype(x.dtype)


def gelu_exact_ref(z):
    return gelu_exact(z.astype(jnp.float32)).astype(z.dtype)


def gelu_tanh_ref(z):
    return gelu_tanh(z.astype(jnp.float32)).astype(z.dtype)


def silu_exact_ref(z):
    return silu(z.astype(jnp.float32)).astype(z.dtype)
