"""Pallas TPU kernel for the dual-mode softmax unit (paper Fig. 2/3).

One kernel family — the TPU analogue of one shared silicon datapath —
serves three modes:

  'softmax' : row softmax, Eq. (10) log-domain normalization
  'gelu'    : N/2-wide (here: fully elementwise) GELU via two-element
              softmax, Eq. (8)
  'silu'    : exact SiLU via two-element softmax (beyond-paper)

and two arithmetic paths:

  precision='int'   bit-accurate S5.10 / int32 emulation of the hardware
                    (quantize at the VMEM tile boundary, exactly where the
                    unit's ingress quantizer sits)
  precision='float' same algorithm in f32 (PWL replaced by native exp2/
                    log2 — the "what if the unit had float lanes" ablation)

Tiling: GELU/SiLU modes are elementwise -> 2D tile grid.  Softmax mode
keeps whole rows resident in VMEM (reductions need the full row) and grids
over row blocks.  Block shapes are chosen so a tile is <= ~2 MiB of VMEM
and the trailing dim is a multiple of 128 (VPU lane width).

Validated on CPU with interpret=True against kernels/ref.py; the int path
is bit-identical to repro.core.softmax_unit by construction (same jnp ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import softmax_unit as unit
from repro.core.fixedpoint import EXP_FRAC, IN_FRAC, dequantize, quantize

# --- kernel bodies ----------------------------------------------------------

def _softmax_body(x_ref, o_ref, *, precision: str):
    x = x_ref[...]
    if precision == "int":
        y = unit.softmax_int(quantize(x.astype(jnp.float32)), axis=-1)
        o_ref[...] = dequantize(y, EXP_FRAC).astype(o_ref.dtype)
    else:
        x = x.astype(jnp.float32)
        m = jnp.max(x, axis=-1, keepdims=True)
        t = (x - m) * 1.4426950408889634           # log2 domain
        e = jnp.exp2(t)
        s = jnp.sum(e, axis=-1, keepdims=True)
        w = t - jnp.log2(s)                        # divide in log domain
        o_ref[...] = jnp.exp2(w).astype(o_ref.dtype)


def _pair_act_body(z_ref, o_ref, *, mode: str, precision: str):
    z = z_ref[...]
    if precision == "int":
        zq = quantize(z.astype(jnp.float32))
        y = unit.gelu_int(zq) if mode == "gelu" else unit.silu_int(zq)
        o_ref[...] = dequantize(y, IN_FRAC).astype(o_ref.dtype)
    else:
        z = z.astype(jnp.float32)
        if mode == "gelu":
            k = unit.gelu_k_float(z)
        else:
            k = 0.5 * z
        # softmax_1^2([k,-k]) through the same float log-domain datapath
        amax = jnp.abs(k)
        l2e = 1.4426950408889634
        t1 = (k - amax) * l2e
        t2 = (-k - amax) * l2e
        s = jnp.exp2(t1) + jnp.exp2(t2)
        sig = jnp.exp2(t1 - jnp.log2(s))
        o_ref[...] = (z * sig).astype(o_ref.dtype)


# --- pallas_call wrappers ----------------------------------------------------

def _row_block(n_rows: int, n_cols: int) -> int:
    """Rows per block: keep tile under ~2 MiB f32, at least 1 row."""
    budget = (2 * 1024 * 1024) // 4
    rows = max(1, budget // max(n_cols, 1))
    while n_rows % rows:
        rows -= 1
    return rows


def _tile2d(m: int, n: int) -> tuple[int, int]:
    bn = n if n % 128 else min(n, 512)
    while n % bn:
        bn -= 1
    bm = max(1, ((2 * 1024 * 1024) // 4) // bn)
    while m % bm:
        bm -= 1
    return bm, bn


@functools.partial(jax.jit, static_argnames=("precision", "interpret"))
def softmax_pallas(x, *, precision: str = "int", interpret: bool = False):
    """Row softmax over the last axis of a 2D array via the dual-mode unit."""
    assert x.ndim == 2, "kernel operates on (rows, row_len)"
    rows, cols = x.shape
    br = _row_block(rows, cols)
    return pl.pallas_call(
        functools.partial(_softmax_body, precision=precision),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("mode", "precision", "interpret"))
def pair_act_pallas(z, *, mode: str = "gelu", precision: str = "int",
                    interpret: bool = False):
    """GELU/SiLU over a 2D array via the unit's GELU mode (elementwise)."""
    assert z.ndim == 2
    m, n = z.shape
    bm, bn = _tile2d(m, n)
    return pl.pallas_call(
        functools.partial(_pair_act_body, mode=mode, precision=precision),
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(z)
