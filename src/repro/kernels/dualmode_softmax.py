"""Pallas TPU kernel for the dual-mode softmax unit (paper Fig. 2/3).

One kernel family — the TPU analogue of one shared silicon datapath —
serves three modes:

  'softmax' : row softmax, Eq. (10) log-domain normalization
  'gelu'    : N/2-wide (here: fully elementwise) GELU via two-element
              softmax, Eq. (8)
  'silu'    : exact SiLU via two-element softmax (beyond-paper)

and two arithmetic paths:

  precision='int'   bit-accurate S5.10 / int32 emulation of the hardware
                    (quantize at the VMEM tile boundary, exactly where the
                    unit's ingress quantizer sits) — repro.core.softmax_unit
  precision='float' the same algorithm in f32 — repro.kernels.datapath

Both bodies are one-line calls into the shared libraries: this file owns
only the pallas_call plumbing.  Tiling comes from kernels/tiling.py —
non-divisible shapes are padded up to the block grid and sliced back
(softmax pads columns with datapath.MASK_VALUE so the padded tail carries
no probability mass), never degraded to 1-wide blocks.

Validated on CPU with interpret=True against kernels/ref.py; the int path
is bit-identical to repro.core.softmax_unit by construction (same jnp ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import softmax_unit as unit
from repro.core.fixedpoint import EXP_FRAC, IN_FRAC, dequantize, quantize

from . import datapath as dp
from . import tiling

# --- kernel bodies ----------------------------------------------------------

def _softmax_body(x_ref, o_ref, *, precision: str):
    x = x_ref[...].astype(jnp.float32)
    if precision == "int":
        y = unit.softmax_int(quantize(x), axis=-1)
        o_ref[...] = dequantize(y, EXP_FRAC).astype(o_ref.dtype)
    else:
        o_ref[...] = dp.row_softmax(x).astype(o_ref.dtype)


def _pair_act_body(z_ref, o_ref, *, mode: str, precision: str):
    z = z_ref[...].astype(jnp.float32)
    if precision == "int":
        zq = quantize(z)
        y = unit.gelu_int(zq) if mode == "gelu" else unit.silu_int(zq)
        o_ref[...] = dequantize(y, IN_FRAC).astype(o_ref.dtype)
    else:
        o_ref[...] = dp.pair_act(z, mode).astype(o_ref.dtype)


# --- pallas_call wrappers ----------------------------------------------------

@functools.partial(jax.jit, static_argnames=("precision", "interpret"))
def softmax_pallas(x, *, precision: str = "int", interpret: bool = False):
    """Row softmax over the last axis of a 2D array via the dual-mode unit."""
    assert x.ndim == 2, "kernel operates on (rows, row_len)"
    rows, cols = x.shape
    # pad the row tail so padded columns carry no probability mass.  The
    # int path pads with MASK_VALUE (-30 quantizes into the S5.10
    # saturation band, whose 14-bit exponential is exactly 0); the float
    # lane never quantizes, so it needs a true -inf — a finite pad would
    # dominate rows whose real scores all sit below it.
    pad = dp.MASK_VALUE if precision == "int" else -jnp.inf
    xp, _ = tiling.pad_dim(x, 1, tiling.LANE, value=pad)
    br = tiling.row_block(rows, xp.shape[1])
    # the ROW tail is sliced off whole, so it pads with a finite 0.0 —
    # reusing the column no-mass value made float-path phantom rows all
    # -inf, whose in-kernel (-inf) - (-inf) = NaN poisoned jax.debug_nans
    # runs even though the rows were discarded
    xp, _ = tiling.pad_dim(xp, 0, br, value=0.0)
    y = pl.pallas_call(
        functools.partial(_softmax_body, precision=precision),
        grid=(xp.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, xp.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, xp.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp)
    return tiling.unpad(tiling.unpad(y, 0, rows), 1, cols)


def vmem_plan(rows: int, cols: int):
    """Static VMEM residency of the whole-row softmax kernel and the
    elementwise GELU/SiLU kernel (see ``flash_attention.vmem_plan`` for
    the contract)."""
    width = tiling.round_up(cols, tiling.LANE)
    br = tiling.row_block(rows, width)
    bm, bn = tiling.tile2d(rows, cols)
    return {
        "softmax_rows": {
            "in:x": ((br, width), jnp.float32),
            "out:y": ((br, width), jnp.float32),
        },
        "pair_act": {
            "in:z": ((bm, bn), jnp.float32),
            "out:y": ((bm, bn), jnp.float32),
        },
    }


@functools.partial(jax.jit, static_argnames=("mode", "precision", "interpret"))
def pair_act_pallas(z, *, mode: str = "gelu", precision: str = "int",
                    interpret: bool = False):
    """GELU/SiLU over a 2D array via the unit's GELU mode (elementwise)."""
    assert z.ndim == 2
    m, n = z.shape
    bm, bn = tiling.tile2d(m, n)
    zp, _ = tiling.pad_dim(z, 0, bm)
    zp, _ = tiling.pad_dim(zp, 1, bn)
    y = pl.pallas_call(
        functools.partial(_pair_act_body, mode=mode, precision=precision),
        grid=(zp.shape[0] // bm, zp.shape[1] // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(zp.shape, z.dtype),
        interpret=interpret,
    )(zp)
    return tiling.unpad(tiling.unpad(y, 0, m), 1, n)
