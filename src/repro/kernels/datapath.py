"""The shared float datapath of the dual-mode softmax unit.

This module is the single source of truth for the unit's FLOAT arithmetic
(the "what if the unit had float lanes" form): every exponential is taken
as 2**t with t in the log2 domain, and every division is a subtraction in
that domain — exactly the structure of the paper's Eq. (8)/(10) hardware,
with the 8-piece PWL replaced by native exp2/log2.  The bit-accurate INT
path (S5.10 / int32) lives in ``repro.core.softmax_unit``; together these
are the only two definitions of the unit's arithmetic in the tree.

Everything here is plain ``jnp`` on arrays — no pallas imports — so the
same functions serve as

  * Pallas kernel bodies (``kernels/dualmode_softmax.py``,
    ``kernels/fused_ffn.py``, ``kernels/flash_attention.py``),
  * the pure-JAX streamed form (``models/flash.py``), and
  * the float reference activations (``core/activations.py``).

ROM constants
-------------
LOG2E          log2(e): multiply to move a natural-log exponent into the
               log2 domain (t = x * log2e, then exp(x) = 2**t).
SQRT_2_OVER_PI / GELU_CUBIC
               the GELU k-datapath coefficients of Eq. (8):
               k = sqrt(2/pi) * (z + 0.044715 z^3).
MASK_VALUE     the additive-mask score for invalid attention positions,
               shared by the naive and all streamed/blocked paths so they
               agree bitwise on which keys are "off".  -30.0 (not -1e30)
               because the unit's ingress quantizer saturates S5.10 inputs
               at -32 (paper §IV): exp(-30) already underflows the 14-bit
               exponential ROM, and any more-negative float would quantize
               to the same word.  Keeping the float paths at the same
               value means float and dual-mode attention mask identically.
"""
from __future__ import annotations

import jax.numpy as jnp

LOG2E = 1.4426950408889634
SQRT_2_OVER_PI = 0.7978845608028654
GELU_CUBIC = 0.044715
MASK_VALUE = -30.0


# --------------------------------------------------------------------------
# row softmax (normal mode, Eq. 10)
# --------------------------------------------------------------------------

def row_softmax(x, axis: int = -1):
    """Eq. (10): softmax with the division done in the log2 domain.

    y_i = 2**(t_i - log2(sum_j 2**t_j)),  t = (x - max(x)) * log2(e).
    """
    x = x.astype(jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    t = (x - m) * LOG2E
    s = jnp.sum(jnp.exp2(t), axis=axis, keepdims=True)
    return jnp.exp2(t - jnp.log2(s))


# --------------------------------------------------------------------------
# pair softmax (GELU mode, Eq. 8)
# --------------------------------------------------------------------------

def gelu_k(z):
    """The GELU k-datapath: k = sqrt(2/pi) * (z + 0.044715 z^3)."""
    return SQRT_2_OVER_PI * (z + GELU_CUBIC * z * z * z)


def pair_sigmoid(k):
    """softmax_1^2([k, -k]) = sigma(2k) through the log-domain datapath.

    The two-element softmax of the unit's GELU mode: max tap |k|, two
    exponentials, the pair adder tap, one log, one exponential.
    """
    amax = jnp.abs(k)
    t1 = (k - amax) * LOG2E
    t2 = (-k - amax) * LOG2E
    s = jnp.exp2(t1) + jnp.exp2(t2)
    return jnp.exp2(t1 - jnp.log2(s))


def gelu(z):
    """GELU mode (Eq. 8): z * softmax_1^2([k, -k])."""
    return z * pair_sigmoid(gelu_k(z))


def silu(z):
    """Exact-identity SiLU mode: z * softmax_1^2([z/2, -z/2])."""
    return z * pair_sigmoid(0.5 * z)


def pair_act(z, mode: str):
    """GELU/SiLU selector over the shared pair-softmax datapath."""
    if mode == "gelu":
        return gelu(z)
    if mode == "silu":
        return silu(z)
    raise ValueError(f"unknown pair-act mode {mode!r}")


def pair_act_grad(z, mode: str):
    """d/dz of :func:`pair_act` — the single float home of the derivative.

    Written in terms of the unit's own ``pair_sigmoid`` tap (s = sigma(2k))
    so the backward kernels evaluate the identical log-domain exponentials
    the forward ran:

        y  = z * s(k(z))
        y' = s + z * 2 s (1 - s) * k'(z)

    with k(z) = z/2 (SiLU, so 2k' = 1) or the Eq. (8) cubic (GELU, where
    k' = sqrt(2/pi) * (1 + 3 * 0.044715 z^2)).
    """
    if mode == "gelu":
        s = pair_sigmoid(gelu_k(z))
        kp = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_CUBIC * z * z)
        return s + z * (2.0 * s * (1.0 - s)) * kp
    if mode == "silu":
        s = pair_sigmoid(0.5 * z)
        return s + z * s * (1.0 - s)
    raise ValueError(f"unknown pair-act mode {mode!r}")


# --------------------------------------------------------------------------
# online softmax (Eq. 10 streamed — flash attention's inner step)
# --------------------------------------------------------------------------

def online_softmax_update(m, l, s):
    """One streamed block of Eq. (10) (Milakov & Gimelshein recurrence).

    m, l : (..., 1) running row max / running normalizer
    s    : (..., N) this block's scores (already masked with MASK_VALUE)

    Returns (m_new, l_new, p, corr) where ``p = 2**((s - m_new)·log2e)``
    are the unnormalized probabilities of this block and ``corr`` rescales
    any accumulator built under the old max:  acc <- acc * corr + p @ v.
    """
    m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp2((s - m_new) * LOG2E)
    corr = jnp.exp2((m - m_new) * LOG2E)
    l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
    return m_new, l_new, p, corr


def online_softmax_finish(l, acc):
    """Final normalization: acc holds sum_j p_j v_j, l the (..., 1) sums."""
    return acc / jnp.maximum(l, 1e-30)


def online_softmax_partial(s, v=None):
    """Self-contained partial state (m, l, acc) of one block of keys.

    ``s`` (..., N) are this block's masked scores, ``v`` (..., N, d) the
    matching values (``None`` -> probability-only partial, acc (..., N) =
    the unnormalized probabilities themselves).  ``m`` is clamped at
    MASK_VALUE — the same floor the streamed paths start their running
    max from — so all-phantom blocks (every score -inf) produce the empty
    sentinel instead of NaN probabilities.
    """
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), MASK_VALUE)
    p = jnp.exp2((s - m) * LOG2E)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = p if v is None else jnp.einsum("...n,...nd->...d", p, v)
    return m, l, acc


def online_softmax_merge(part_a, part_b):
    """Merge two online-softmax partial states — the ring-attention fold.

    Each part is ``(m, l, acc)`` with ``m``/``l`` shaped (..., 1) and
    ``acc`` (..., d): the running max, normalizer and UNNORMALIZED
    weighted-value accumulator over a subset of keys (``acc = out * l``
    recovers it from a finished block).  The combine is the associative,
    commutative monoid operation of the Milakov–Gimelshein recurrence —
    per-shard partials merge EXACTLY regardless of how the key set was
    split, which is the algebraic fact sequence-parallel ring attention
    (``kernels/ring_attention.py``) relies on:

        m  = max(m_a, m_b)
        l  = l_a * 2**((m_a-m)·log2e) + l_b * 2**((m_b-m)·log2e)
        acc likewise.

    Identity element: ``(MASK_VALUE, 0, 0)`` — the empty-shard sentinel
    (the float twin of the int path's PHANTOM_Q): every streamed path
    initializes its running max at MASK_VALUE, so partials never carry a
    smaller max and merging the sentinel is a bit-exact no-op.
    """
    m_a, l_a, acc_a = part_a
    m_b, l_b, acc_b = part_b
    m = jnp.maximum(m_a, m_b)
    c_a = jnp.exp2((m_a - m) * LOG2E)
    c_b = jnp.exp2((m_b - m) * LOG2E)
    return m, l_a * c_a + l_b * c_b, acc_a * c_a + acc_b * c_b


# --------------------------------------------------------------------------
# normalization (third resident of the unit — SOLE/Choi co-design)
# --------------------------------------------------------------------------
#
# RMSNorm/LayerNorm join softmax and GELU on the shared datapath: the
# 1/sqrt(v) each needs is one more log-domain traversal of the same unit,
# rsqrt(v) = 2**(-0.5 * log2(v)) — one log tap, one halving shift, one
# exponential, exactly the SOLE reuse.  These are the SINGLE float
# definitions; ``models/layers.py`` wraps them (downcast at the very end)
# and the fused Pallas seams (``kernels/fused_norm.py``) inline them as
# epilogue/prologue bodies.
#
# Numeric contract (what every fused seam is pinned against):
#   * statistics AND the gain/bias application happen in f32; the caller
#     performs exactly one downcast, on the finished f32 result;
#   * one-pass sums (sum of squares; LayerNorm var = E[x^2] - E[x]^2,
#     clamped at 0) so Pallas bodies need a single sweep of the row;
#   * ``eps`` has NO default — call sites must thread cfg.norm_eps.

def _rsqrt_log2(v):
    """rsqrt through the unit: 2**(-0.5 * log2(v)).  v must be > 0."""
    return jnp.exp2(-0.5 * jnp.log2(v))


def rmsnorm(x, g, eps):
    """RMSNorm, f32 in/out: x * rsqrt(mean(x^2) + eps) * g.

    Returns f32 regardless of input dtype — the caller owns the single
    final downcast.  ``g`` broadcasts over the leading axes.
    """
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    r = _rsqrt_log2(ms + eps)
    return x32 * r * g.astype(jnp.float32)


def layernorm(x, g, b, eps):
    """LayerNorm, f32 in/out, one-pass moments (var = E[x^2] - E[x]^2)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.maximum(jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
                      - jnp.square(mu), 0.0)
    r = _rsqrt_log2(var + eps)
    return (x32 - mu) * r * g.astype(jnp.float32) + b.astype(jnp.float32)


def rmsnorm_vjp(x, g, eps, dy):
    """VJP of :func:`rmsnorm` wrt (x, g) — the single gradient home.

    With r = rsqrt(ms + eps) and w_i = g_i * dy_i:

        dx_i = r * w_i - x_i * r^3 * mean(x * w)
        dg-hat_i = dy_i * x_i * r        (callers reduce over leading axes)

    All f32; ``dy`` is upcast.  Returns (dx, dg_hat).
    """
    x32 = x.astype(jnp.float32)
    dy32 = dy.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    r = _rsqrt_log2(ms + eps)
    w = g.astype(jnp.float32) * dy32
    dx = r * w - x32 * (r * r * r) * jnp.mean(
        x32 * w, axis=-1, keepdims=True)
    return dx, dy32 * x32 * r


def layernorm_vjp(x, g, eps, dy):
    """VJP of :func:`layernorm` wrt (x, g, b).

    With xhat = (x - mu) * r and w_i = g_i * dy_i:

        dx = r * (w - mean(w) - xhat * mean(w * xhat))
        dg-hat = dy * xhat,  db-hat = dy   (callers reduce leading axes)

    Returns (dx, dg_hat, db_hat), all f32.
    """
    x32 = x.astype(jnp.float32)
    dy32 = dy.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.maximum(jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
                      - jnp.square(mu), 0.0)
    r = _rsqrt_log2(var + eps)
    xhat = (x32 - mu) * r
    w = g.astype(jnp.float32) * dy32
    dx = r * (w - jnp.mean(w, axis=-1, keepdims=True)
              - xhat * jnp.mean(w * xhat, axis=-1, keepdims=True))
    return dx, dy32 * xhat, dy32


def norm_apply(x, g, b, *, kind: str, eps: float):
    """rms/layer selector — the fused kernels' epilogue body."""
    if kind == "rms":
        return rmsnorm(x, g, eps)
    if kind == "layer":
        return layernorm(x, g, b, eps)
    raise ValueError(f"unknown norm kind {kind!r}")


def online_softmax_merge_n(m, l, acc, axis: int = 0):
    """Vectorized n-way fold of partial states stacked along ``axis``.

    The split-KV decode path ("flash decoding") produces one partial per
    KV split; folding them pairwise with :func:`online_softmax_merge`
    would chain n-1 dependent rescales, while the monoid structure lets
    the whole fold collapse to ONE max and ONE rescaled sum:

        m*  = max_i m_i
        l*  = sum_i l_i   * 2**((m_i - m*)·log2e)
        acc* = sum_i acc_i * 2**((m_i - m*)·log2e)

    ``m``/``l`` broadcast against ``acc`` (the usual layout keeps a
    trailing singleton dim on the statistics).  Reductions keep ``axis``
    as a singleton so the fold is shape-stable for the caller.  Sentinel
    partials ``(MASK_VALUE, 0, 0)`` contribute exact IEEE zeros, so
    including empty splits is a bit-exact no-op — same identity law as
    the pairwise merge, checked in tests/test_datapath.py.

    INT twins: the bit-accurate unit has the same monoid structure once
    the running max is snapped to a power of two — see
    ``repro.core.softmax_unit.online_merge_int`` (pairwise, the ring's
    fold) and ``online_merge_n_int`` (this n-way form, the dual-mode
    decode's split fold), where the state is (m snapped, S depth-bucket
    words, acc) and every rescale is an exact shift.
    """
    m_all = jnp.max(m, axis=axis, keepdims=True)
    c = jnp.exp2((m - m_all) * LOG2E)
    return (m_all, jnp.sum(l * c, axis=axis, keepdims=True),
            jnp.sum(acc * c, axis=axis, keepdims=True))
