"""Pallas blocked flash attention on the BIT-ACCURATE int datapath.

``kernels/flash_attention.py`` streams the float log-domain form of
Eq. (10); this sibling streams the S5.10/int32 unit itself
(``repro.core.softmax_unit``), so the paper's dual-mode numerics run on
blocked shapes instead of silently degrading to fp32 the moment the
dispatcher picks a streamed path.

Two kernels live here:

``flash_pallas_int`` — ONE KV sweep, snapped-max mode.  The running max
is ceil-snapped to a power of two (``softmax_unit.snap_max_int``), which
makes every rescale-by-``exp2(m_old - m_new)`` an EXACT arithmetic shift
on int words: the PWL probability word depends only on ``t mod 2**16``
(max-independent), the max contributes an integer depth, and the
normalizer carry is one int32 partial sum per depth (the bucket vector
of ``softmax_unit.online_merge_int`` — a true word monoid).  The f32
weighted-value accumulator rescales by exact powers of two
(``snap_scale_f32``), so the kernel's output equals the whole-row
:func:`repro.core.softmax_unit.softmax_snap` reference with only f32
summation-order noise — and is BITWISE equal under an identity-v probe.

``flash_pallas_int3`` — the original three-sweep kernel, kept as the
pinned oracle of the UNSNAPPED unit: the classic rescale is not
multiplicative in words (the 8-piece exp2 is not multiplicative), so the
unsnapped recurrence must run max, sum, emit as three sequential sweeps
over the same KV tiles

    sweep 0  m <- max(m, max(block))            int32 S5.10 carry
    sweep 1  l <- l + sum(exp2 words >> guard)  int32 guard-shifted carry
    sweep 2  acc <- acc + dequant(prob words) @ v

telescoping to the EXACT whole-row
:func:`repro.core.softmax_unit.softmax_int` words.  KV is read 3x per q
tile — the bandwidth price the snapped kernel exists to remove.

Shapes, masking, and tiling match the float kernel: q (B,S,K,G,h),
k (B,T,K,h), v (B,T,K,hv) -> (B,S,K,G,hv); user-invalid or causally
masked keys score ``datapath.MASK_VALUE`` BEFORE quantization (the same
finite word the naive dual-mode path sees), while tiling-phantom keys
take the ``PHANTOM_Q`` sentinel whose exponential is the literal 0 word.
Scores quantize as ``quantize((q*scale) . k)`` in exactly the naive
path's operation order (scale folded into q in f32 before the dot), so
the S5.10 score words — and therefore the probability words — are
identical to naive ``softmax_impl='dualmode'`` (three-sweep) /
``'dualmode_snap'`` (one-sweep).

Forward-only: the int unit is step-quantized (gradients vanish a.e.), so
no VJP is defined and differentiating through these kernels raises.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import softmax_unit as unit
from repro.core.fixedpoint import EXP_FRAC, I32, T_FRAC, dequantize, quantize

from . import datapath as dp
from . import dispatch, tiling
from .flash_attention import _STATE_LANES, attention_blockspecs, \
    rowstat_blockspec


def int_score_words(q, kb, qpos_ref, valid_ref, kv_tile, *, block_kv: int,
                    causal: bool, t_kv: int):
    """One tile of S5.10 score WORDS — the int twin of
    ``flash_attention.masked_score_block``, shared by every int kernel
    body (one-sweep, three-sweep, decode) so they can never disagree on
    masking or quantization order: mask to ``MASK_VALUE`` (the finite
    word the naive dual-mode path sees), quantize, then overwrite
    tiling-phantom positions with the ``PHANTOM_Q`` sentinel."""
    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    mask = valid_ref[...] != 0                            # (1, bkv) -> bcast
    kv_pos = kv_tile * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    if causal:
        q_pos = qpos_ref[...].reshape(-1, 1)
        mask = mask & (kv_pos <= q_pos)
    s = jnp.where(mask, s, dp.MASK_VALUE)
    sq = quantize(s)                                      # S5.10 score words
    return jnp.where(kv_pos < t_kv, sq, I32(unit.PHANTOM_Q))


def slide_lanes(S, k):
    """Kernel-side bucket slide: S'[:, d] = S[:, d-k] (0-fill, drop past
    the last bucket).  Same words as ``softmax_unit.slide_buckets_int``
    but built from STATIC lane shifts (pad/slice) selected by the binary
    decomposition of k — no gathers, so it lowers on the TPU vector unit.
    """
    nb = unit.N_SNAP_BUCKETS
    S = jnp.where(k >= nb, 0, S)
    kc = jnp.minimum(k, nb - 1)
    for b in (1, 2, 4, 8):
        shifted = jnp.concatenate(
            [jnp.zeros(S.shape[:-1] + (b,), S.dtype), S[..., :nb - b]],
            axis=-1)
        S = jnp.where((kc & b) != 0, shifted, S)
    return S


def snap_tile_update(m, S, acc, sq, vb, guard_shift: int):
    """One KV tile of the snapped online recurrence — the kernel-shaped
    form of insert-then-merge, shared by the one-sweep flash body and the
    dual-mode decode body.

    m (rows, 1) int32 snapped carry, S (rows, N_SNAP_BUCKETS) int32
    bucket carry, acc (rows, hv) f32, sq (rows, bkv) S5.10 score words,
    vb (bkv, hv) f32.  Returns the updated (m, S, acc).  Words are
    bit-identical to folding ``online_partial_int`` of this tile into the
    carry with ``online_merge_int``; acc additionally accumulates the
    exact f32 numerators against vb.
    """
    t = unit.to_snap_domain(sq)
    m_new = jnp.maximum(
        m, unit.snap_max_int(jnp.max(t, axis=-1, keepdims=True)))
    k_corr = (m_new - m) >> T_FRAC
    p = unit.snap_prob_word(t, guard_shift)               # (rows, bkv)
    d = (m_new >> T_FRAC) - (t >> T_FRAC)
    S_blk = jnp.concatenate(
        [jnp.sum(jnp.where(d == kk, p, 0), axis=-1, keepdims=True)
         for kk in range(unit.N_SNAP_BUCKETS)], axis=-1)
    S_new = slide_lanes(S, k_corr) + S_blk
    num = p.astype(jnp.float32) * unit.snap_scale_f32(d)  # exact f32
    acc_new = acc * unit.snap_scale_f32(k_corr) + jnp.dot(
        num, vb, preferred_element_type=jnp.float32)
    return m_new, S_new, acc_new


# --------------------------------------------------------------------------
# one-sweep snapped kernel ('flash_pallas_int')
# --------------------------------------------------------------------------

def _flash_snap_body(qpos_ref, valid_ref, q_ref, k_ref, v_ref, o_ref, *rest,
                     block_kv: int, causal: bool, t_kv: int,
                     guard_shift: int, with_partial: bool):
    if with_partial:
        m_out_ref, s_out_ref, m_ref, s_ref, acc_ref = rest
    else:
        m_ref, s_ref, acc_ref = rest
    kj = pl.program_id(3)
    hv = o_ref.shape[-1]
    nb = unit.N_SNAP_BUCKETS

    @pl.when(kj == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, unit.SNAP_MIN)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, 0, :].astype(jnp.float32)          # (bq, h) pre-scaled
    kb = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, h)
    vb = v_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, hv)
    sq = int_score_words(q, kb, qpos_ref, valid_ref, kj, block_kv=block_kv,
                         causal=causal, t_kv=t_kv)

    m_new, S_new, acc_new = snap_tile_update(
        m_ref[:, :1], s_ref[:, :nb], acc_ref[:, :hv], sq, vb, guard_shift)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    s_ref[:, :nb] = S_new
    acc_ref[:, :hv] = acc_new

    @pl.when(kj == pl.num_programs(3) - 1)
    def _():
        if with_partial:
            # UNNORMALIZED partial out: the ring folds (m, S, acc) across
            # hops with the int monoid and finishes ONCE at the end
            o_ref[0, :, 0, 0, :] = acc_ref[:, :hv]
            m_out_ref[0, 0, 0, :] = m_ref[:, 0]
            s_out_ref[0, 0, 0, :, :] = s_ref[:, :nb]
        else:
            l = unit.online_finish_int(s_ref[:, :nb])     # (bq,)
            out = acc_ref[:, :hv] / l[:, None].astype(jnp.float32)
            o_ref[0, :, 0, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_kv", "interpret", "guard_shift",
    "with_partial"))
def _flash_snap_jit(q, k, v, q_pos, kv_valid, scale, *, causal: bool,
                    block_q: int, block_kv: int, interpret: bool,
                    guard_shift: int, with_partial: bool):
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    bq, bkv = block_q, block_kv
    nb = unit.N_SNAP_BUCKETS
    # naive op order: q*scale in f32 BEFORE the dot (pins the score words)
    q = q.astype(jnp.float32) * scale

    qf, qp, kf, vf, valid = tiling.pad_attention_operands(
        q, q_pos, k, v, kv_valid, bq, bkv)
    s_p, t_p = qf.shape[1], kf.shape[1]

    in_specs, out_spec = attention_blockspecs(bq, bkv, g, hd, hv)
    grid = (b, kh * g, s_p // bq, t_p // bkv)
    if with_partial:
        out_specs = [
            pl.BlockSpec((1, bq, 1, 1, hv),
                         lambda b_, h_, qi, kj: (b_, qi, h_ // g, h_ % g, 0)),
            rowstat_blockspec(bq, g),
            pl.BlockSpec((1, 1, 1, bq, nb),
                         lambda b_, h_, qi, kj: (b_, h_ // g, h_ % g, qi, 0)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((b, s_p, kh, g, hv), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, g, s_p), jnp.int32),
            jax.ShapeDtypeStruct((b, kh, g, s_p, nb), jnp.int32),
        ]
    else:
        out_specs = out_spec
        out_shape = jax.ShapeDtypeStruct((b, s_p, kh, g, hv), v.dtype)
    out = pl.pallas_call(
        functools.partial(_flash_snap_body, block_kv=bkv, causal=causal,
                          t_kv=t, guard_shift=guard_shift,
                          with_partial=with_partial),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, _STATE_LANES), jnp.int32),    # snapped max m
            pltpu.VMEM((bq, _STATE_LANES), jnp.int32),    # depth buckets S
            pltpu.VMEM((bq, tiling.scratch_lanes(hv)),
                       jnp.float32),                      # weighted-v acc
        ],
        interpret=interpret,
    )(qp, valid, qf, kf, vf)
    if with_partial:
        acc, m, S = out
        return (tiling.unpad(acc, 1, s_q), tiling.unpad(m, 3, s_q),
                tiling.unpad(S, 3, s_q))
    return tiling.unpad(out, 1, s_q)


def flash_attention_pallas_int(q, k, v, *, q_pos, kv_valid,
                               causal: bool = True,
                               scale: float | None = None,
                               block_q: int | None = None,
                               block_kv: int | None = None,
                               interpret: bool | None = None,
                               guard_shift: int | None = None,
                               return_partial: bool = False):
    """ONE-sweep blocked dual-mode attention (snapped-max unit).

    Output is the naive ``softmax_impl='dualmode_snap'`` attention with
    identical (p, d, l) words; only the final f32 numerator@v summation
    order differs (blocked vs whole-row), and under an identity-v probe
    the outputs are bitwise equal.

    ``guard_shift`` defaults to the whole-row rule for an n=t row; ring
    callers override it with the GLOBAL row guard so hop partials merge
    word-exact.  ``return_partial=True`` returns the UNNORMALIZED
    ``(acc, m, S)`` — acc (B,S,K,G,hv) f32, m (B,K,G,S) int32 snapped,
    S (B,K,G,S,N_SNAP_BUCKETS) int32 — the mergeable monoid partial.
    """
    hd = q.shape[-1]
    t = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = (1.0 / hd ** 0.5) if scale is None else scale
    if guard_shift is None:
        guard_shift = max(0, t.bit_length() - 16)
    bq, bkv = tiling.attention_blocks(q.shape[1], t)
    bq = bq if block_q is None else block_q
    bkv = bkv if block_kv is None else block_kv
    return _flash_snap_jit(q, k, v, q_pos, kv_valid, jnp.float32(scale),
                           causal=causal, block_q=bq, block_kv=bkv,
                           interpret=interpret, guard_shift=guard_shift,
                           with_partial=return_partial)


# --------------------------------------------------------------------------
# three-sweep unsnapped kernel ('flash_pallas_int3', the pinned oracle)
# --------------------------------------------------------------------------

def _flash_int_body(qpos_ref, valid_ref, q_ref, k_ref, v_ref,
                    o_ref, m_ref, l_ref, acc_ref, *, block_kv: int,
                    causal: bool, t_kv: int, guard_shift: int):
    phase = pl.program_id(3)
    kj = pl.program_id(4)
    hv = o_ref.shape[-1]

    @pl.when((phase == 0) & (kj == 0))
    def _():
        m_ref[...] = jnp.full_like(m_ref, unit.PHANTOM_Q)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, 0, :].astype(jnp.float32)          # (bq, h) pre-scaled
    kb = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, h)
    sq = int_score_words(q, kb, qpos_ref, valid_ref, kj, block_kv=block_kv,
                         causal=causal, t_kv=t_kv)

    m = m_ref[:, :1]                                      # (bq, 1)

    @pl.when(phase == 0)
    def _():
        m_ref[...] = jnp.broadcast_to(unit.online_max_int(m, sq),
                                      m_ref.shape)

    @pl.when(phase == 1)
    def _():
        l_new = unit.online_sum_int(l_ref[:, :1], m, sq, guard_shift)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(phase == 2)
    def _():
        p = unit.online_probs_int(m, l_ref[:, :1], sq, guard_shift)
        pf = dequantize(p, EXP_FRAC)                      # exact prob floats
        vb = v_ref[0, :, 0, :].astype(jnp.float32)        # (bkv, hv)
        # acc scratch is lane-rounded (hv may be off the 128 grid — MLA);
        # only the live [:, :hv] slice carries data
        acc_ref[:, :hv] = acc_ref[:, :hv] + jnp.dot(
            pf, vb, preferred_element_type=jnp.float32)

    @pl.when((phase == 2) & (kj == pl.num_programs(4) - 1))
    def _():
        o_ref[0, :, 0, 0, :] = acc_ref[:, :hv].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_kv", "interpret"))
def _flash_int_jit(q, k, v, q_pos, kv_valid, scale, *, causal: bool,
                   block_q: int, block_kv: int, interpret: bool):
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    bq, bkv = block_q, block_kv
    # same guard as the whole-row unit applies for an n=t row
    guard_shift = max(0, t.bit_length() - 16)
    # fold the traced scale into q in the naive path's op order (q*scale
    # in f32 BEFORE the dot): the per-element score dot is then bitwise
    # identical to the naive einsum, keeping the quantized words pinned
    q = q.astype(jnp.float32) * scale

    qf, qp, kf, vf, valid = tiling.pad_attention_operands(
        q, q_pos, k, v, kv_valid, bq, bkv)
    s_p, t_p = qf.shape[1], kf.shape[1]

    in_specs, out_spec = attention_blockspecs(bq, bkv, g, hd, hv)
    # only the emit sweep reads v: pin its block index to 0 during the
    # max/sum sweeps (ph // 2 = 0, 0, 1) so v HBM->VMEM traffic stays ~1x
    # instead of riding every kv step of all three sweeps
    in_specs[4] = pl.BlockSpec(
        (1, bkv, 1, hv),
        lambda b_, h_, qi, ph, kj: (b_, kj * (ph // 2), h_ // g, 0))
    grid = (b, kh * g, s_p // bq, 3, t_p // bkv)          # 3 = sweeps
    out = pl.pallas_call(
        functools.partial(_flash_int_body, block_kv=bkv, causal=causal,
                          t_kv=t, guard_shift=guard_shift),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, s_p, kh, g, hv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _STATE_LANES), jnp.int32),    # running max m
            pltpu.VMEM((bq, _STATE_LANES), jnp.int32),    # guard-shifted l
            pltpu.VMEM((bq, tiling.scratch_lanes(hv)),
                       jnp.float32),                      # weighted-v acc
        ],
        interpret=interpret,
    )(qp, valid, qf, kf, vf)
    return tiling.unpad(out, 1, s_q)


def flash_attention_pallas_int3(q, k, v, *, q_pos, kv_valid,
                                causal: bool = True,
                                scale: float | None = None,
                                block_q: int | None = None,
                                block_kv: int | None = None,
                                interpret: bool | None = None):
    """THREE-sweep blocked dual-mode attention (unsnapped unit oracle).

    Output is the naive ``softmax_impl='dualmode'`` attention with the
    identical int probability words; only the final f32 prob@v
    accumulation order differs (blocked vs whole-row sum).
    """
    hd = q.shape[-1]
    t = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = (1.0 / hd ** 0.5) if scale is None else scale
    bq, bkv = tiling.attention_blocks(q.shape[1], t)
    bq = bq if block_q is None else block_q
    bkv = bkv if block_kv is None else block_kv
    return _flash_int_jit(q, k, v, q_pos, kv_valid,
                          jnp.float32(scale), causal=causal, block_q=bq,
                          block_kv=bkv, interpret=interpret)


def _attention_entry(q, k, v, *, q_pos, kv_valid, causal, scale,
                     softmax_impl="dualmode", ring_axis=""):
    # the one-sweep kernel runs on snap words internally, so it honors
    # BOTH int contracts: 'dualmode' and the snapped monoid
    # 'dualmode_snap' produce the identical finished words here
    if softmax_impl not in ("dualmode", "dualmode_snap"):
        raise ValueError(
            "attn_impl='flash_pallas_int' IS the bit-accurate unit; it "
            f"cannot honor softmax_impl={softmax_impl!r} (use 'dualmode', "
            "or a float impl: 'flash'/'flash_pallas')")
    return flash_attention_pallas_int(q, k, v, q_pos=q_pos,
                                      kv_valid=kv_valid, causal=causal,
                                      scale=scale)


def _attention_entry3(q, k, v, *, q_pos, kv_valid, causal, scale,
                      softmax_impl="dualmode", ring_axis=""):
    if softmax_impl != "dualmode":
        raise ValueError(
            "attn_impl='flash_pallas_int3' IS the bit-accurate unit; it "
            f"cannot honor softmax_impl={softmax_impl!r} (use 'dualmode', "
            "or a float impl: 'flash'/'flash_pallas')")
    return flash_attention_pallas_int3(q, k, v, q_pos=q_pos,
                                       kv_valid=kv_valid, causal=causal,
                                       scale=scale)


def vmem_plan(s_q: int, t_kv: int, hd: int, hv: int, g: int = 1):
    """Static VMEM residency of both int kernels (see
    ``flash_attention.vmem_plan`` for the contract).  The one-sweep plan
    prices the partial-emitting variant — its extra (m, S) outputs are
    the worst case."""
    bq, bkv = tiling.attention_blocks(s_q, t_kv)
    nb = unit.N_SNAP_BUCKETS
    common = {
        "in:q_pos": ((1, bq), jnp.int32),
        "in:kv_valid": ((1, bkv), jnp.int32),
        "in:q": ((1, bq, 1, 1, hd), jnp.float32),
        "in:k": ((1, bkv, 1, hd), jnp.float32),
        "in:v": ((1, bkv, 1, hv), jnp.float32),
        "out:o": ((1, bq, 1, 1, hv), jnp.float32),
        "scratch:m": ((bq, _STATE_LANES), jnp.int32),
        "scratch:s": ((bq, _STATE_LANES), jnp.int32),
        "scratch:acc": ((bq, tiling.scratch_lanes(hv)), jnp.float32),
    }
    return {
        "flash_int_onesweep": dict(
            common,
            **{"out:part_m": ((1, 1, 1, bq), jnp.int32),
               "out:part_s": ((1, 1, 1, bq, nb), jnp.int32)}),
        "flash_int_threesweep": dict(common),
    }


dispatch.register_attention(
    "flash_pallas_int", _attention_entry,
    modes=("dualmode", "dualmode_snap"), grad=False,
    note="snapped one-sweep int kernel (forward-only)")
dispatch.register_attention(
    "flash_pallas_int3", _attention_entry3,
    modes=("dualmode",), grad=False,
    note="three-sweep int oracle (forward-only)")
