"""Pallas blocked flash attention on the BIT-ACCURATE int datapath.

``kernels/flash_attention.py`` streams the float log-domain form of
Eq. (10); this sibling streams the S5.10/int32 unit itself
(``repro.core.softmax_unit``), so the paper's dual-mode numerics run on
blocked shapes instead of silently degrading to fp32 the moment the
dispatcher picks a streamed path.

Why three KV sweeps: the float flash recurrence rescales stale partial
sums by exp(m_old - m_new) when the running max moves.  That correction
is exact in float algebra but NOT in the unit's PWL arithmetic (the
8-piece exp2 is not multiplicative), so a one-sweep online rescale would
change words.  The unit's max fold and guard-shifted sum fold are however
associative int32 reductions, and the emit step is elementwise given the
final (m, l) — so the kernel runs the online recurrence as three
sequential sweeps over the same KV tiles

    sweep 0  m <- max(m, max(block))            int32 S5.10 carry
    sweep 1  l <- l + sum(exp2 words >> guard)  int32 guard-shifted carry
    sweep 2  acc <- acc + dequant(prob words) @ v

with (m, l, acc) in VMEM scratch, and telescopes to the EXACT whole-row
:func:`repro.core.softmax_unit.softmax_int` words (the fold steps are
``online_max_int`` / ``online_sum_int`` / ``online_probs_int`` — shared
verbatim with the pure-jnp blocked oracle that tests pin bit-identical).
KV is read 3x per q tile: that is the bandwidth price of bit-exactness,
fine for the decode/accuracy-study shapes this path serves.

Shapes, masking, and tiling match the float kernel: q (B,S,K,G,h),
k (B,T,K,h), v (B,T,K,hv) -> (B,S,K,G,hv); user-invalid or causally
masked keys score ``datapath.MASK_VALUE`` BEFORE quantization (the same
finite word the naive dual-mode path sees), while tiling-phantom keys
take the ``PHANTOM_Q`` sentinel whose exponential is the literal 0 word.
Scores quantize as ``quantize((q*scale) . k)`` in exactly the naive
path's operation order (scale folded into q in f32 before the dot), so
the S5.10 score words — and therefore the probability words — are
identical to naive ``softmax_impl='dualmode'``.

Forward-only: the int unit is step-quantized (gradients vanish a.e.), so
no VJP is defined and differentiating through this kernel raises.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import softmax_unit as unit
from repro.core.fixedpoint import EXP_FRAC, I32, dequantize, quantize

from . import datapath as dp
from . import dispatch, tiling
from .flash_attention import _STATE_LANES, attention_blockspecs


def _flash_int_body(qpos_ref, valid_ref, q_ref, k_ref, v_ref,
                    o_ref, m_ref, l_ref, acc_ref, *, block_kv: int,
                    causal: bool, t_kv: int, guard_shift: int):
    phase = pl.program_id(3)
    kj = pl.program_id(4)
    hv = o_ref.shape[-1]

    @pl.when((phase == 0) & (kj == 0))
    def _():
        m_ref[...] = jnp.full_like(m_ref, unit.PHANTOM_Q)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, 0, :].astype(jnp.float32)          # (bq, h) pre-scaled
    kb = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, h)
    # naive order: (q*scale) . k, THEN mask — scale folded into q outside
    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bkv)

    mask = valid_ref[...] != 0                            # (1, bkv) -> bcast
    kv_pos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if causal:
        q_pos = qpos_ref[...].reshape(-1, 1)              # (bq, 1)
        mask = mask & (kv_pos <= q_pos)
    s = jnp.where(mask, s, dp.MASK_VALUE)
    sq = quantize(s)                                      # S5.10 score words
    # tiling-padded phantom keys carry EXACTLY zero mass (int -inf
    # analogue); user-invalid keys keep the finite quantized MASK_VALUE
    # word so masking matches the naive dual-mode path bitwise
    sq = jnp.where(kv_pos < t_kv, sq, I32(unit.PHANTOM_Q))

    m = m_ref[:, :1]                                      # (bq, 1)

    @pl.when(phase == 0)
    def _():
        m_ref[...] = jnp.broadcast_to(unit.online_max_int(m, sq),
                                      m_ref.shape)

    @pl.when(phase == 1)
    def _():
        l_new = unit.online_sum_int(l_ref[:, :1], m, sq, guard_shift)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(phase == 2)
    def _():
        p = unit.online_probs_int(m, l_ref[:, :1], sq, guard_shift)
        pf = dequantize(p, EXP_FRAC)                      # exact prob floats
        vb = v_ref[0, :, 0, :].astype(jnp.float32)        # (bkv, hv)
        # acc scratch is lane-rounded (hv may be off the 128 grid — MLA);
        # only the live [:, :hv] slice carries data
        acc_ref[:, :hv] = acc_ref[:, :hv] + jnp.dot(
            pf, vb, preferred_element_type=jnp.float32)

    @pl.when((phase == 2) & (kj == pl.num_programs(4) - 1))
    def _():
        o_ref[0, :, 0, 0, :] = acc_ref[:, :hv].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_kv", "interpret"))
def _flash_int_jit(q, k, v, q_pos, kv_valid, scale, *, causal: bool,
                   block_q: int, block_kv: int, interpret: bool):
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    bq, bkv = block_q, block_kv
    # same guard as the whole-row unit applies for an n=t row
    guard_shift = max(0, t.bit_length() - 16)
    # fold the traced scale into q in the naive path's op order (q*scale
    # in f32 BEFORE the dot): the per-element score dot is then bitwise
    # identical to the naive einsum, keeping the quantized words pinned
    q = q.astype(jnp.float32) * scale

    qf, qp, kf, vf, valid = tiling.pad_attention_operands(
        q, q_pos, k, v, kv_valid, bq, bkv)
    s_p, t_p = qf.shape[1], kf.shape[1]

    in_specs, out_spec = attention_blockspecs(bq, bkv, g, hd, hv)
    # only the emit sweep reads v: pin its block index to 0 during the
    # max/sum sweeps (ph // 2 = 0, 0, 1) so v HBM->VMEM traffic stays ~1x
    # instead of riding every kv step of all three sweeps
    in_specs[4] = pl.BlockSpec(
        (1, bkv, 1, hv),
        lambda b_, h_, qi, ph, kj: (b_, kj * (ph // 2), h_ // g, 0))
    grid = (b, kh * g, s_p // bq, 3, t_p // bkv)          # 3 = sweeps
    out = pl.pallas_call(
        functools.partial(_flash_int_body, block_kv=bkv, causal=causal,
                          t_kv=t, guard_shift=guard_shift),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, s_p, kh, g, hv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _STATE_LANES), jnp.int32),    # running max m
            pltpu.VMEM((bq, _STATE_LANES), jnp.int32),    # guard-shifted l
            pltpu.VMEM((bq, tiling.scratch_lanes(hv)),
                       jnp.float32),                      # weighted-v acc
        ],
        interpret=interpret,
    )(qp, valid, qf, kf, vf)
    return tiling.unpad(out, 1, s_q)


def flash_attention_pallas_int(q, k, v, *, q_pos, kv_valid,
                               causal: bool = True,
                               scale: float | None = None,
                               block_q: int | None = None,
                               block_kv: int | None = None,
                               interpret: bool | None = None):
    """Blocked dual-mode attention; see module docstring.

    Output is the naive ``softmax_impl='dualmode'`` attention with the
    identical int probability words; only the final f32 prob@v
    accumulation order differs (blocked vs whole-row sum).
    """
    hd = q.shape[-1]
    t = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = (1.0 / hd ** 0.5) if scale is None else scale
    bq, bkv = tiling.attention_blocks(q.shape[1], t)
    bq = bq if block_q is None else block_q
    bkv = bkv if block_kv is None else block_kv
    return _flash_int_jit(q, k, v, q_pos, kv_valid,
                          jnp.float32(scale), causal=causal, block_q=bq,
                          block_kv=bkv, interpret=interpret)


def _attention_entry(q, k, v, *, q_pos, kv_valid, causal, scale,
                     softmax_impl="dualmode", ring_axis=""):
    if softmax_impl != "dualmode":
        raise ValueError(
            "attn_impl='flash_pallas_int' IS the bit-accurate unit; it "
            f"cannot honor softmax_impl={softmax_impl!r} (use 'dualmode', "
            "or a float impl: 'flash'/'flash_pallas')")
    return flash_attention_pallas_int(q, k, v, q_pos=q_pos,
                                      kv_valid=kv_valid, causal=causal,
                                      scale=scale)


dispatch.register_attention("flash_pallas_int", _attention_entry)
