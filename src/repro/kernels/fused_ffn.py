"""Beyond-paper Pallas kernel: gated-FFN matmul with the GELU-via-softmax
epilogue fused in VMEM.

    Y = act(X @ Wg) * (X @ Wu)

where `act` is the paper's Eq. 8 evaluated in the unit's own log-domain
float form (``kernels/datapath.pair_act`` — the same arithmetic every
other kernel body runs).  The unfused graph writes the (tokens, d_ff)
gate activations to HBM and reads them back for the elementwise multiply;
fusing the epilogue into the matmul tile keeps them VMEM-resident — at
qwen3-14b train_4k that round trip is 2·tokens·d_ff·2B = 146 GB/step of
HBM traffic (≈0.18 s at 819 GB/s), removed entirely.

Tiling: grid over (M/bm, F/bf) output tiles; K (= d_model) kept whole per
tile — X tile (bm, K) + two weight tiles (K, bf) fit VMEM for every
assigned arch (K ≤ 5120: 3 × 128·5120·4B ≈ 7.9 MB < 16 MB v5e VMEM).
Block shapes come from kernels/tiling.py: MXU-aligned, with M and F padded
up to the block grid (zero rows/columns cost act(0)·0 = 0 and are sliced
off) instead of shrinking blocks to divisors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import datapath as dp
from . import dispatch, tiling


def _ffn_body(x_ref, wg_ref, wu_ref, o_ref, *, mode: str):
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (dp.pair_act(g, mode) * u).astype(o_ref.dtype)


def _glu_reference(x, wg, wu, mode: str):
    """Unfused float graph with the SAME epilogue arithmetic — the
    differentiation surrogate for the kernel's backward pass."""
    g = jnp.dot(x.astype(jnp.float32), wg.astype(jnp.float32))
    u = jnp.dot(x.astype(jnp.float32), wu.astype(jnp.float32))
    return (dp.pair_act(g, mode) * u).astype(x.dtype)


@functools.partial(jax.jit,
                   static_argnames=("mode", "interpret", "bm", "bf"))
def fused_glu_pallas(x, wg, wu, *, mode: str = "silu",
                     interpret: bool = False, bm: int = 128, bf: int = 512):
    """x (M,K) @ wg/wu (K,F) with fused activation epilogue -> (M,F).

    Differentiable: Pallas has no AD rule for the fused body, so the
    backward pass recomputes through the unfused reference graph (same
    datapath arithmetic, so gradients match the kernel's own math).
    """
    m, k = x.shape
    f = wg.shape[1]
    bm, bf = tiling.matmul_blocks(m, f, want_m=bm, want_f=bf)

    def forward(x_, wg_, wu_):
        xp, _ = tiling.pad_dim(x_, 0, bm)
        wgp, _ = tiling.pad_dim(wg_, 1, bf)
        wup, _ = tiling.pad_dim(wu_, 1, bf)
        y = pl.pallas_call(
            functools.partial(_ffn_body, mode=mode),
            grid=(xp.shape[0] // bm, wgp.shape[1] // bf),
            in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                      pl.BlockSpec((k, bf), lambda i, j: (0, j)),
                      pl.BlockSpec((k, bf), lambda i, j: (0, j))],
            out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((xp.shape[0], wgp.shape[1]),
                                           x_.dtype),
            interpret=interpret,
        )(xp, wgp, wup)
        return tiling.unpad(tiling.unpad(y, 0, m), 1, f)

    @jax.custom_vjp
    def run(x_, wg_, wu_):
        return forward(x_, wg_, wu_)

    def fwd(x_, wg_, wu_):
        return forward(x_, wg_, wu_), (x_, wg_, wu_)

    def bwd(res, gy):
        _, vjp = jax.vjp(lambda a, b, c: _glu_reference(a, b, c, mode), *res)
        return vjp(gy)

    run.defvjp(fwd, bwd)
    return run(x, wg, wu)


def _ffn_entry(x, wg, wu, mode):
    return fused_glu_pallas(
        x, wg, wu, mode=mode, interpret=jax.default_backend() != "tpu")


dispatch.register_ffn("fused_pallas", _ffn_entry)
