"""Beyond-paper Pallas kernel: gated-FFN matmul with the GELU-via-softmax
epilogue fused in VMEM.

    Y = act(X @ Wg) * (X @ Wu)

where `act` is the paper's Eq. 8 evaluated in the unit's own log-domain
float form (``kernels/datapath.pair_act`` — the same arithmetic every
other kernel body runs).  The unfused graph writes the (tokens, d_ff)
gate activations to HBM and reads them back for the elementwise multiply;
fusing the epilogue into the matmul tile keeps them VMEM-resident — at
qwen3-14b train_4k that round trip is 2·tokens·d_ff·2B = 146 GB/step of
HBM traffic (≈0.18 s at 819 GB/s), removed entirely.

The backward is fused the same way: one kernel recomputes the (g, u)
tiles and emits d_gate = dY·u·act'(g) and d_up = dY·act(g) in VMEM
(``datapath.pair_act_grad`` is the single float home of the derivative);
the four surrounding matmuls (dX, dWg, dWu) are plain XLA dots.  The
unfused ``_glu_reference`` graph remains the differentiation reference
tests pin gradients against.

Tiling: grid over (M/bm, F/bf) output tiles; K (= d_model) kept whole per
tile — X tile (bm, K) + two weight tiles (K, bf) fit VMEM for every
assigned arch (K ≤ 5120: 3 × 128·5120·4B ≈ 7.9 MB < 16 MB v5e VMEM).
Block shapes resolve BEFORE the jit boundary (mirroring
``flash_attention_pallas``): ``kernels/tiling.matmul_blocks`` when the
caller passes none, explicit ``bm``/``bf`` hints honored (rounded up to the
hardware alignment) — so distinct hints that resolve identically share
one compilation.  M and F
are padded up to the block grid (zero rows/columns cost act(0)·0 = 0 and
are sliced off) instead of shrinking blocks to divisors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import datapath as dp
from . import dispatch, tiling


def _ffn_body(x_ref, wg_ref, wu_ref, o_ref, *, mode: str):
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (dp.pair_act(g, mode) * u).astype(o_ref.dtype)


def _ffn_bwd_body(x_ref, wg_ref, wu_ref, dy_ref, dg_ref, du_ref, *,
                  mode: str):
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    dg_ref[...] = dy * u * dp.pair_act_grad(g, mode)
    du_ref[...] = dy * dp.pair_act(g, mode)


def _glu_reference(x, wg, wu, mode: str):
    """Unfused float graph with the SAME epilogue arithmetic — the
    reference the fused forward AND backward are pinned against."""
    g = jnp.dot(x.astype(jnp.float32), wg.astype(jnp.float32))
    u = jnp.dot(x.astype(jnp.float32), wu.astype(jnp.float32))
    return (dp.pair_act(g, mode) * u).astype(x.dtype)


def _glu_bwd_call(x, wg, wu, dy, *, mode: str, bm: int, bf: int,
                  interpret: bool):
    """(d_gate, d_up) f32 tiles from the fused backward kernel."""
    m, k = x.shape
    f = wg.shape[1]
    xp, _ = tiling.pad_dim(x, 0, bm)
    wgp, _ = tiling.pad_dim(wg, 1, bf)
    wup, _ = tiling.pad_dim(wu, 1, bf)
    dyp, _ = tiling.pad_dim(dy.astype(jnp.float32), 0, bm)
    dyp, _ = tiling.pad_dim(dyp, 1, bf)
    dg, du = pl.pallas_call(
        functools.partial(_ffn_bwd_body, mode=mode),
        grid=(xp.shape[0] // bm, wgp.shape[1] // bf),
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, bf), lambda i, j: (0, j)),
                  pl.BlockSpec((k, bf), lambda i, j: (0, j)),
                  pl.BlockSpec((bm, bf), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((bm, bf), lambda i, j: (i, j))] * 2,
        out_shape=[jax.ShapeDtypeStruct((xp.shape[0], wgp.shape[1]),
                                        jnp.float32)] * 2,
        interpret=interpret,
    )(xp, wgp, wup, dyp)
    return (tiling.unpad(tiling.unpad(dg, 0, m), 1, f),
            tiling.unpad(tiling.unpad(du, 0, m), 1, f))


def fused_glu_pallas(x, wg, wu, *, mode: str = "silu",
                     interpret: bool = False, bm: int | None = None,
                     bf: int | None = None):
    """x (M,K) @ wg/wu (K,F) with fused activation epilogue -> (M,F).

    Blocks resolve HERE, before the jit boundary: the tiling policy when
    ``bm``/``bf`` are None, the caller's explicit hints (rounded up to
    the SUBLANE/LANE alignment) otherwise — so a hint can no longer
    trigger a recompile whose value is then second-guessed inside the
    trace.

    Differentiable: the custom VJP runs the fused backward kernel
    (d_gate/d_up computed in VMEM via ``datapath.pair_act_grad``); the
    unfused ``_glu_reference`` graph is the reference tests pin against.
    """
    m, _ = x.shape
    f = wg.shape[1]
    rbm, rbf = tiling.matmul_blocks(m, f)
    # explicit hints are honored, rounded UP to the hardware alignment —
    # an off-grid block (bf=32 < the 128 lane width) would mis-tile in
    # compiled (non-interpret) mode
    bm = rbm if bm is None else tiling.round_up(bm, tiling.SUBLANE)
    bf = rbf if bf is None else tiling.round_up(bf, tiling.LANE)
    return _fused_glu_jit(x, wg, wu, mode=mode, interpret=interpret,
                          bm=bm, bf=bf)


@functools.partial(jax.jit,
                   static_argnames=("mode", "interpret", "bm", "bf"))
def _fused_glu_jit(x, wg, wu, *, mode: str, interpret: bool, bm: int,
                   bf: int):
    m, k = x.shape
    f = wg.shape[1]

    def forward(x_, wg_, wu_):
        xp, _ = tiling.pad_dim(x_, 0, bm)
        wgp, _ = tiling.pad_dim(wg_, 1, bf)
        wup, _ = tiling.pad_dim(wu_, 1, bf)
        y = pl.pallas_call(
            functools.partial(_ffn_body, mode=mode),
            grid=(xp.shape[0] // bm, wgp.shape[1] // bf),
            in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                      pl.BlockSpec((k, bf), lambda i, j: (0, j)),
                      pl.BlockSpec((k, bf), lambda i, j: (0, j))],
            out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((xp.shape[0], wgp.shape[1]),
                                           x_.dtype),
            interpret=interpret,
        )(xp, wgp, wup)
        return tiling.unpad(tiling.unpad(y, 0, m), 1, f)

    @jax.custom_vjp
    def run(x_, wg_, wu_):
        return forward(x_, wg_, wu_)

    def fwd(x_, wg_, wu_):
        return forward(x_, wg_, wu_), (x_, wg_, wu_)

    def bwd(res, gy):
        x_, wg_, wu_ = res
        dg, du = _glu_bwd_call(x_, wg_, wu_, gy, mode=mode, bm=bm, bf=bf,
                               interpret=interpret)
        xf = x_.astype(jnp.float32)
        dx = (jnp.dot(dg, wg_.astype(jnp.float32).T)
              + jnp.dot(du, wu_.astype(jnp.float32).T))
        dwg = jnp.dot(xf.T, dg)
        dwu = jnp.dot(xf.T, du)
        return (dx.astype(x_.dtype), dwg.astype(wg_.dtype),
                dwu.astype(wu_.dtype))

    run.defvjp(fwd, bwd)
    return run(x, wg, wu)


def vmem_plan(m: int, k: int, f: int):
    """Static VMEM residency of the fused GLU forward and backward
    kernels (see ``flash_attention.vmem_plan`` for the contract).  The
    contraction dim ``k`` is unblocked — the whole (bm, k) x (k, bf)
    panels are resident, which is what makes this worth auditing."""
    bm, bf = tiling.matmul_blocks(m, f)
    fwd = {
        "in:x": ((bm, k), jnp.float32),
        "in:wg": ((k, bf), jnp.float32),
        "in:wu": ((k, bf), jnp.float32),
        "out:y": ((bm, bf), jnp.float32),
    }
    bwd = dict(fwd)
    del bwd["out:y"]
    bwd.update({
        "in:dy": ((bm, bf), jnp.float32),
        "out:dg": ((bm, bf), jnp.float32),
        "out:du": ((bm, bf), jnp.float32),
    })
    return {"ffn_fwd": fwd, "ffn_bwd": bwd}


def _ffn_entry(x, wg, wu, mode):
    return fused_glu_pallas(
        x, wg, wu, mode=mode, interpret=jax.default_backend() != "tpu")


dispatch.register_ffn("fused_pallas", _ffn_entry)
