"""Beyond-paper Pallas kernel: gated-FFN matmul with the GELU-via-softmax
epilogue fused in VMEM.

    Y = act(X @ Wg) * (X @ Wu)

where `act` is the paper's Eq. 8 evaluated in the unit's own log-domain
float form (exp as 2^u·2^v).  The unfused graph writes the (tokens, d_ff)
gate activations to HBM and reads them back for the elementwise multiply;
fusing the epilogue into the matmul tile keeps them VMEM-resident — at
qwen3-14b train_4k that round trip is 2·tokens·d_ff·2B = 146 GB/step of
HBM traffic (≈0.18 s at 819 GB/s), removed entirely.

Tiling: grid over (M/bm, F/bf) output tiles; K (= d_model) kept whole per
tile — X tile (bm, K) + two weight tiles (K, bf) fit VMEM for every
assigned arch (K ≤ 5120: 3 × 128·5120·4B ≈ 7.9 MB < 16 MB v5e VMEM).
MXU alignment: bm, bf multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LOG2E = 1.4426950408889634
_SQRT_2_OVER_PI = 0.7978845608028654


def _epilogue(g, mode: str):
    """The unit's GELU-mode arithmetic (float lanes), on a VMEM tile."""
    if mode == "gelu":
        k = _SQRT_2_OVER_PI * (g + 0.044715 * g * g * g)
    else:                                    # exact SiLU identity
        k = 0.5 * g
    amax = jnp.abs(k)
    t1 = (k - amax) * _LOG2E
    t2 = (-k - amax) * _LOG2E
    sig = jnp.exp2(t1 - jnp.log2(jnp.exp2(t1) + jnp.exp2(t2)))
    return g * sig


def _ffn_body(x_ref, wg_ref, wu_ref, o_ref, *, mode: str):
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (_epilogue(g, mode) * u).astype(o_ref.dtype)


def _pick(n: int, want: int) -> int:
    b = min(want, n)
    while n % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit,
                   static_argnames=("mode", "interpret", "bm", "bf"))
def fused_glu_pallas(x, wg, wu, *, mode: str = "silu",
                     interpret: bool = False, bm: int = 128, bf: int = 512):
    """x (M,K) @ wg/wu (K,F) with fused activation epilogue -> (M,F)."""
    m, k = x.shape
    f = wg.shape[1]
    bm = _pick(m, bm)
    bf = _pick(f, bf)
    return pl.pallas_call(
        functools.partial(_ffn_body, mode=mode),
        grid=(m // bm, f // bf),
        in_specs=[pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, bf), lambda i, j: (0, j)),
                  pl.BlockSpec((k, bf), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, f), x.dtype),
        interpret=interpret,
    )(x, wg, wu)
