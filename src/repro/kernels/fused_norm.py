"""Pallas kernels for the block's norm seams — normalization joins the
shared unit (ROADMAP item 3; SOLE / Choi et al. in PAPERS.md).

The transformer block has three memory-bound seams where a norm sits
between a residual stream and a matmul, each costing an HBM round trip of
the (tokens, d_model) activation in the unfused graph:

  residual_norm   (x, r)        -> (x + r, norm(x + r))
                  the attention-output / FFN epilogue: the residual add
                  and the next sublayer's norm happen in VMEM, so the
                  normalized stream never round-trips HBM between them.
  norm_linear     x @ W seams   -> norm(x) @ W
                  the norm -> QKV-projection prologue: the normalized
                  activations are consumed by the matmul tile in VMEM
                  instead of being written out and read back.
  norm_glu        gated FFN     -> act(norm(x) @ Wg) * (norm(x) @ Wu)
                  the norm -> gate/up prologue, extending the fused-GLU
                  epilogue kernel (fused_ffn.py) one seam upstream.

All three inline the datapath's norm arithmetic (``kernels/datapath``:
rsqrt as exp2(-0.5*log2(v)) — one more traversal of the unit's log-domain
hardware), with moments and gain/bias entirely in f32 and a single
downcast on the finished result — the exact contract of the dense norms
in ``models/layers.py``, so fused-vs-dense parity is a <=1e-5 tolerance
(reduction order differs; see tests/test_fused_norm.py).

Backward: each kernel carries a custom VJP whose gradients route through
the datapath's single VJP homes (``rmsnorm_vjp``/``layernorm_vjp``); the
norm_glu backward reuses the fused GLU backward kernel
(``fused_ffn._glu_bwd_call``) for the in-VMEM d_gate/d_up tiles.  The
surrounding dots are plain XLA, mirroring fused_ffn's fwd-fused /
bwd-hybrid split.

Tiling follows the package policy: blocks resolve BEFORE the jit
boundary (``tiling.norm_rows`` / ``tiling.matmul_blocks``), the token
axis pads up to the block grid, and the feature/contraction dim stays
whole per tile (same as fused_ffn's unblocked K) — which also means the
row moments are computed over the TRUE feature width, never a padded
one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import datapath as dp
from . import dispatch, tiling
from .fused_ffn import _glu_bwd_call


def _hat(xn, *, kind: str, eps: float):
    """Normalized rows (no gain/bias) — the in-kernel moment datapath.

    ``xn`` is f32 (rows, d) with d the TRUE feature width (the tiles
    keep the feature dim whole, so no padded columns pollute the means).
    """
    inv_n = 1.0 / xn.shape[-1]
    if kind == "rms":
        ms = jnp.sum(xn * xn, axis=-1, keepdims=True) * inv_n
        return xn * jnp.exp2(-0.5 * jnp.log2(ms + eps))
    if kind == "layer":
        mu = jnp.sum(xn, axis=-1, keepdims=True) * inv_n
        var = jnp.maximum(
            jnp.sum(xn * xn, axis=-1, keepdims=True) * inv_n - mu * mu, 0.0)
        return (xn - mu) * jnp.exp2(-0.5 * jnp.log2(var + eps))
    raise ValueError(f"unknown norm kind {kind!r}")


def _dense_h(x, g, b, *, kind: str, eps: float):
    """The dense f32 normalized-and-scaled stream (datapath reference) —
    what the backward recomputes instead of saving h."""
    if kind == "rms":
        return dp.rmsnorm(x, g, eps)
    return dp.layernorm(x, g, b, eps)


def _norm_vjp(x, g, b, dy, *, kind: str, eps: float):
    """(dx, dg, db) through the datapath VJP homes; leading axes of the
    elementwise dg-hat/db-hat are reduced here.  db is None for rms."""
    if kind == "rms":
        dx, dg_hat = dp.rmsnorm_vjp(x, g, eps, dy)
        return dx, jnp.sum(dg_hat, axis=0), None
    dx, dg_hat, db_hat = dp.layernorm_vjp(x, g, eps, dy)
    return dx, jnp.sum(dg_hat, axis=0), jnp.sum(db_hat, axis=0)


# --------------------------------------------------------------------------
# residual-add + norm epilogue
# --------------------------------------------------------------------------

def _resnorm_body(x_ref, r_ref, g_ref, b_ref, xo_ref, ho_ref, *,
                  kind: str, eps: float):
    xn = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    h = (_hat(xn, kind=kind, eps=eps) * g_ref[...].astype(jnp.float32)
         + b_ref[...].astype(jnp.float32))
    xo_ref[...] = xn.astype(xo_ref.dtype)
    ho_ref[...] = h.astype(ho_ref.dtype)


def fused_residual_norm(x, r, g, b=None, *, kind: str, eps: float,
                        interpret: bool = False, bm: int | None = None):
    """(x + r, norm(x + r) * g + b) with both outputs produced in VMEM.

    ``x``/``r`` are (..., d); ``b=None`` means rms (no bias).  Returns
    both outputs in x's dtype — the epilogue's h IS the next sublayer's
    input, downcast once, exactly like the dense contract.
    """
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    r2 = r.reshape(-1, d)
    m = x2.shape[0]
    rbm = tiling.norm_rows(m, d)
    bm = rbm if bm is None else tiling.round_up(bm, tiling.SUBLANE)
    has_b = b is not None
    xo, ho = _resnorm_jit(x2, r2, g, b if has_b else jnp.zeros_like(g),
                          kind=kind, eps=eps, interpret=interpret, bm=bm,
                          has_b=has_b)
    return xo.reshape(shape), ho.reshape(shape)


@functools.partial(jax.jit, static_argnames=("kind", "eps", "interpret",
                                             "bm", "has_b"))
def _resnorm_jit(x, r, g, b, *, kind: str, eps: float, interpret: bool,
                 bm: int, has_b: bool):
    m, d = x.shape

    def forward(x_, r_, g_, b_):
        xp, _ = tiling.pad_dim(x_, 0, bm)
        rp, _ = tiling.pad_dim(r_, 0, bm)
        xo, ho = pl.pallas_call(
            functools.partial(_resnorm_body, kind=kind, eps=eps),
            grid=(xp.shape[0] // bm,),
            in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                      pl.BlockSpec((bm, d), lambda i: (i, 0)),
                      pl.BlockSpec((1, d), lambda i: (0, 0)),
                      pl.BlockSpec((1, d), lambda i: (0, 0))],
            out_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))] * 2,
            out_shape=[jax.ShapeDtypeStruct((xp.shape[0], d), x_.dtype)] * 2,
            interpret=interpret,
        )(xp, rp, g_.reshape(1, d), b_.reshape(1, d))
        return tiling.unpad(xo, 0, m), tiling.unpad(ho, 0, m)

    @jax.custom_vjp
    def run(x_, r_, g_, b_):
        return forward(x_, r_, g_, b_)

    def fwd(x_, r_, g_, b_):
        return forward(x_, r_, g_, b_), (x_, r_, g_, b_)

    def bwd(res, gy):
        x_, r_, g_, b_ = res
        d_xnew, dh = gy
        xn = x_.astype(jnp.float32) + r_.astype(jnp.float32)
        dxn, dg, db = _norm_vjp(xn, g_, b_, dh, kind=kind, eps=eps)
        dxn = dxn + d_xnew.astype(jnp.float32)
        db = (db if db is not None else jnp.zeros_like(b_, jnp.float32))
        if not has_b:           # placeholder bias: no gradient flows out
            db = jnp.zeros_like(db)
        return (dxn.astype(x_.dtype), dxn.astype(r_.dtype),
                dg.astype(g_.dtype), db.astype(b_.dtype))

    run.defvjp(fwd, bwd)
    return run(x, r, g, b)


# --------------------------------------------------------------------------
# norm -> linear prologue (QKV projection)
# --------------------------------------------------------------------------

def _norm_linear_body(x_ref, g_ref, b_ref, w_ref, o_ref, *, kind: str,
                      eps: float):
    xn = x_ref[...].astype(jnp.float32)
    h = (_hat(xn, kind=kind, eps=eps) * g_ref[...].astype(jnp.float32)
         + b_ref[...].astype(jnp.float32))
    o_ref[...] = jnp.dot(h, w_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def fused_norm_linear(x, g, b, w, *, kind: str, eps: float,
                      interpret: bool = False, bm: int | None = None,
                      bf: int | None = None):
    """norm(x) @ w without materializing the normalized stream.

    ``x`` (..., d), ``w`` (d, F) -> (..., F).  ``b=None`` for rms.
    The x tile is read once and both the moments and the matmul consume
    it in VMEM — the prologue's HBM saving (see BENCH_block.json).
    """
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    m, f = x2.shape[0], w.shape[1]
    rbm, rbf = tiling.matmul_blocks(m, f)
    bm = rbm if bm is None else tiling.round_up(bm, tiling.SUBLANE)
    bf = rbf if bf is None else tiling.round_up(bf, tiling.LANE)
    has_b = b is not None
    o = _norm_linear_jit(x2, g, b if has_b else jnp.zeros_like(g), w,
                         kind=kind, eps=eps, interpret=interpret, bm=bm,
                         bf=bf, has_b=has_b)
    return o.reshape(shape[:-1] + (f,))


@functools.partial(jax.jit, static_argnames=("kind", "eps", "interpret",
                                             "bm", "bf", "has_b"))
def _norm_linear_jit(x, g, b, w, *, kind: str, eps: float, interpret: bool,
                     bm: int, bf: int, has_b: bool):
    m, d = x.shape
    f = w.shape[1]

    def forward(x_, g_, b_, w_):
        xp, _ = tiling.pad_dim(x_, 0, bm)
        wp, _ = tiling.pad_dim(w_, 1, bf)
        o = pl.pallas_call(
            functools.partial(_norm_linear_body, kind=kind, eps=eps),
            grid=(xp.shape[0] // bm, wp.shape[1] // bf),
            in_specs=[pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
                      pl.BlockSpec((1, d), lambda i, j: (0, 0)),
                      pl.BlockSpec((1, d), lambda i, j: (0, 0)),
                      pl.BlockSpec((d, bf), lambda i, j: (0, j))],
            out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]),
                                           x_.dtype),
            interpret=interpret,
        )(xp, g_.reshape(1, d), b_.reshape(1, d), wp)
        return tiling.unpad(tiling.unpad(o, 0, m), 1, f)

    @jax.custom_vjp
    def run(x_, g_, b_, w_):
        return forward(x_, g_, b_, w_)

    def fwd(x_, g_, b_, w_):
        return forward(x_, g_, b_, w_), (x_, g_, b_, w_)

    def bwd(res, do):
        x_, g_, b_, w_ = res
        do32 = do.astype(jnp.float32)
        dh = jnp.dot(do32, w_.astype(jnp.float32).T)
        h = _dense_h(x_, g_, b_, kind=kind, eps=eps)
        dw = jnp.dot(h.T, do32)
        dx, dg, db = _norm_vjp(x_, g_, b_, dh, kind=kind, eps=eps)
        db = (db if db is not None else jnp.zeros_like(b_, jnp.float32))
        if not has_b:
            db = jnp.zeros_like(db)
        return (dx.astype(x_.dtype), dg.astype(g_.dtype),
                db.astype(b_.dtype), dw.astype(w_.dtype))

    run.defvjp(fwd, bwd)
    return run(x, g, b, w)


# --------------------------------------------------------------------------
# norm -> gated-GLU prologue (fused_ffn one seam upstream)
# --------------------------------------------------------------------------

def _norm_glu_body(x_ref, g_ref, b_ref, wg_ref, wu_ref, o_ref, *,
                   kind: str, eps: float, mode: str):
    xn = x_ref[...].astype(jnp.float32)
    h = (_hat(xn, kind=kind, eps=eps) * g_ref[...].astype(jnp.float32)
         + b_ref[...].astype(jnp.float32))
    gm = jnp.dot(h, wg_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    um = jnp.dot(h, wu_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    o_ref[...] = (dp.pair_act(gm, mode) * um).astype(o_ref.dtype)


def fused_norm_glu(x, g, b, wg, wu, *, kind: str, eps: float, mode: str,
                   interpret: bool = False, bm: int | None = None,
                   bf: int | None = None):
    """act(norm(x) @ wg) * (norm(x) @ wu) — norm prologue + the fused GLU
    epilogue in one kernel.  ``x`` (..., d) -> (..., F); ``b=None`` rms."""
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    m, f = x2.shape[0], wg.shape[1]
    rbm, rbf = tiling.matmul_blocks(m, f)
    bm = rbm if bm is None else tiling.round_up(bm, tiling.SUBLANE)
    bf = rbf if bf is None else tiling.round_up(bf, tiling.LANE)
    has_b = b is not None
    o = _norm_glu_jit(x2, g, b if has_b else jnp.zeros_like(g), wg, wu,
                      kind=kind, eps=eps, mode=mode, interpret=interpret,
                      bm=bm, bf=bf, has_b=has_b)
    return o.reshape(shape[:-1] + (f,))


@functools.partial(jax.jit, static_argnames=("kind", "eps", "mode",
                                             "interpret", "bm", "bf",
                                             "has_b"))
def _norm_glu_jit(x, g, b, wg, wu, *, kind: str, eps: float, mode: str,
                  interpret: bool, bm: int, bf: int, has_b: bool):
    m, d = x.shape
    f = wg.shape[1]

    def forward(x_, g_, b_, wg_, wu_):
        xp, _ = tiling.pad_dim(x_, 0, bm)
        wgp, _ = tiling.pad_dim(wg_, 1, bf)
        wup, _ = tiling.pad_dim(wu_, 1, bf)
        o = pl.pallas_call(
            functools.partial(_norm_glu_body, kind=kind, eps=eps,
                              mode=mode),
            grid=(xp.shape[0] // bm, wgp.shape[1] // bf),
            in_specs=[pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
                      pl.BlockSpec((1, d), lambda i, j: (0, 0)),
                      pl.BlockSpec((1, d), lambda i, j: (0, 0)),
                      pl.BlockSpec((d, bf), lambda i, j: (0, j)),
                      pl.BlockSpec((d, bf), lambda i, j: (0, j))],
            out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((xp.shape[0], wgp.shape[1]),
                                           x_.dtype),
            interpret=interpret,
        )(xp, g_.reshape(1, d), b_.reshape(1, d), wgp, wup)
        return tiling.unpad(tiling.unpad(o, 0, m), 1, f)

    @jax.custom_vjp
    def run(x_, g_, b_, wg_, wu_):
        return forward(x_, g_, b_, wg_, wu_)

    def fwd(x_, g_, b_, wg_, wu_):
        return forward(x_, g_, b_, wg_, wu_), (x_, g_, b_, wg_, wu_)

    def bwd(res, dy):
        x_, g_, b_, wg_, wu_ = res
        h = _dense_h(x_, g_, b_, kind=kind, eps=eps)
        # the fused GLU backward kernel emits d_gate/d_up in VMEM — the
        # norm prologue only changes what the surrounding dots contract
        dgm, dum = _glu_bwd_call(h, wg_, wu_, dy, mode=mode, bm=bm, bf=bf,
                                 interpret=interpret)
        dh = (jnp.dot(dgm, wg_.astype(jnp.float32).T)
              + jnp.dot(dum, wu_.astype(jnp.float32).T))
        dwg = jnp.dot(h.T, dgm)
        dwu = jnp.dot(h.T, dum)
        dx, dg, db = _norm_vjp(x_, g_, b_, dh, kind=kind, eps=eps)
        db = (db if db is not None else jnp.zeros_like(b_, jnp.float32))
        if not has_b:
            db = jnp.zeros_like(db)
        return (dx.astype(x_.dtype), dg.astype(g_.dtype),
                db.astype(b_.dtype), dwg.astype(wg_.dtype),
                dwu.astype(wu_.dtype))

    run.defvjp(fwd, bwd)
    return run(x, g, b, wg, wu)


# --------------------------------------------------------------------------
# audit surface + registration
# --------------------------------------------------------------------------

def vmem_plan(m: int, d: int, f: int):
    """Static VMEM residency of the three fused-norm kernels (audited by
    repro.analysis.vmem against VMEM_CORE_BUDGET).  The feature dim ``d``
    is unblocked in every kernel — the moments need whole rows — which is
    exactly the residency worth auditing."""
    bm_r = tiling.norm_rows(m, d)
    bm, bf = tiling.matmul_blocks(m, f)
    resnorm = {
        "in:x": ((bm_r, d), jnp.float32),
        "in:r": ((bm_r, d), jnp.float32),
        "in:g": ((1, d), jnp.float32),
        "in:b": ((1, d), jnp.float32),
        "out:x_new": ((bm_r, d), jnp.float32),
        "out:h": ((bm_r, d), jnp.float32),
    }
    norm_linear = {
        "in:x": ((bm, d), jnp.float32),
        "in:g": ((1, d), jnp.float32),
        "in:b": ((1, d), jnp.float32),
        "in:w": ((d, bf), jnp.float32),
        "out:o": ((bm, bf), jnp.float32),
    }
    norm_glu = {
        "in:x": ((bm, d), jnp.float32),
        "in:g": ((1, d), jnp.float32),
        "in:b": ((1, d), jnp.float32),
        "in:wg": ((d, bf), jnp.float32),
        "in:wu": ((d, bf), jnp.float32),
        "out:o": ((bm, bf), jnp.float32),
    }
    return {"resnorm_fwd": resnorm, "norm_linear_fwd": norm_linear,
            "norm_glu_fwd": norm_glu}


def _interp() -> bool:
    return jax.default_backend() != "tpu"


dispatch.register_norm("fused_pallas", {
    "residual_norm": lambda x, r, g, b, *, kind, eps: fused_residual_norm(
        x, r, g, b, kind=kind, eps=eps, interpret=_interp()),
    "norm_linear": lambda x, g, b, w, *, kind, eps: fused_norm_linear(
        x, g, b, w, kind=kind, eps=eps, interpret=_interp()),
    "norm_glu": lambda x, g, b, wg, wu, *, kind, eps, mode: fused_norm_glu(
        x, g, b, wg, wu, kind=kind, eps=eps, mode=mode,
        interpret=_interp()),
})
