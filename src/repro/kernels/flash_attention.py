"""Pallas blocked flash attention on the unit's log-domain datapath.

The paper's softmax normalizes in the LOG domain (Eq. 10); that form
telescopes exactly into the online-softmax recurrence, so the streamed
inner step here is literally :func:`repro.kernels.datapath.
online_softmax_update` — the same function the pure-JAX blocked path
(``models/flash.py``) runs.  This kernel adds the Pallas grid around it:
KV is streamed through VMEM in (block_kv)-sized tiles while the running
(m, l, acc) state lives in VMEM scratch across the sequential kv grid
dimension, so the (S, T) score matrix is never materialized in HBM.

Shapes match the model-side attention core (GQA/MLA compatible):

    q (B, S, K, G, h)   k (B, T, K, h)   v (B, T, K, hv)  ->  (B, S, K, G, hv)

with G query groups per KV head and hv possibly != h (MLA).  Masking: kv
position t attends iff ``kv_valid[b, t]`` and (not causal or
``t <= q_pos[b, s]``); masked scores take ``datapath.MASK_VALUE`` exactly
like the naive path, so all three implementations agree on masking.

Non-divisible S/T are padded up to the block grid (``kernels/tiling.py``
policy) and the output sliced back; padded KV rows are simply invalid.
Runs on CPU with ``interpret=True`` (the default off-TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import datapath as dp
from . import dispatch, tiling

_STATE_LANES = 128   # lane width of the (m, l) scratch rows


def attention_blockspecs(bq: int, bkv: int, g: int, hd: int, hv: int):
    """(in_specs for (q_pos, kv_valid, q, k, v), out_spec) shared by every
    flash kernel flavor.  Index maps take (b, head, q_tile, *rest) with
    the kv tile as the LAST grid dim, so the same specs serve the float
    kernel's 4D grid and the int kernel's 5D (extra sweep dim) grid.
    """
    in_specs = [
        pl.BlockSpec((1, bq), lambda b_, h_, qi, *r: (b_, qi)),
        pl.BlockSpec((1, bkv), lambda b_, h_, qi, *r: (b_, r[-1])),
        pl.BlockSpec((1, bq, 1, 1, hd),
                     lambda b_, h_, qi, *r: (b_, qi, h_ // g, h_ % g, 0)),
        pl.BlockSpec((1, bkv, 1, hd),
                     lambda b_, h_, qi, *r: (b_, r[-1], h_ // g, 0)),
        pl.BlockSpec((1, bkv, 1, hv),
                     lambda b_, h_, qi, *r: (b_, r[-1], h_ // g, 0)),
    ]
    out_spec = pl.BlockSpec(
        (1, bq, 1, 1, hv),
        lambda b_, h_, qi, *r: (b_, qi, h_ // g, h_ % g, 0))
    return in_specs, out_spec


def _flash_body(qpos_ref, valid_ref, q_ref, k_ref, v_ref, o_ref,
                m_ref, l_ref, acc_ref, *, block_kv: int, causal: bool,
                t_kv: int):
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, dp.MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, 0, :].astype(jnp.float32)          # (bq, h) pre-scaled
    kb = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, h)
    vb = v_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, hv)
    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bkv)

    mask = valid_ref[...] != 0                            # (1, bkv) -> bcast
    kv_pos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if causal:
        q_pos = qpos_ref[...].reshape(-1, 1)              # (bq, 1)
        mask = mask & (kv_pos <= q_pos)
    s = jnp.where(mask, s, dp.MASK_VALUE)
    # tiling-padded phantom keys carry NO mass (-inf); user-invalid keys
    # keep the finite MASK_VALUE so masking matches the naive path bitwise
    s = jnp.where(kv_pos < t_kv, s, -jnp.inf)

    m, l = m_ref[:, :1], l_ref[:, :1]                     # (bq, 1)
    m_new, l_new, p, corr = dp.online_softmax_update(m, l, s)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, vb, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kj == pl.num_programs(3) - 1)
    def _():
        out = dp.online_softmax_finish(l_ref[:, :1], acc_ref[...])
        o_ref[0, :, 0, 0, :] = out.astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, q_pos, kv_valid, causal: bool = True,
                           scale: float | None = None,
                           block_q: int | None = None,
                           block_kv: int | None = None,
                           interpret: bool | None = None):
    """Blocked flash attention; see module docstring for shapes/masking.

    ``scale`` rides as a TRACED operand (folded into the q pre-scale
    before the kernel), so distinct head-dim/user scales share one
    compilation — only genuinely structural args (blocks, causal,
    interpret) are jit-static.

    Differentiable: Pallas has no AD rule for the streamed body, so the
    backward pass recomputes through the pure-JAX blocked path
    (models/flash.py) — the identical online-softmax arithmetic, just
    unfused.  Dedicated dq/dk/dv Pallas kernels are a ROADMAP item.
    """
    hd = q.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = (1.0 / hd ** 0.5) if scale is None else scale
    bq, bkv = tiling.attention_blocks(q.shape[1], k.shape[1])
    bq = bq if block_q is None else block_q
    bkv = bkv if block_kv is None else block_kv
    return _flash_pallas_jit(q, k, v, q_pos, kv_valid,
                             jnp.float32(scale), causal=causal, block_q=bq,
                             block_kv=bkv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_kv", "interpret"))
def _flash_pallas_jit(q, k, v, q_pos, kv_valid, scale, *, causal: bool,
                      block_q: int, block_kv: int, interpret: bool):
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    bq, bkv = block_q, block_kv
    # fold the traced scale into q HERE, outside the custom_vjp, so (a) no
    # tracer is closed over by fwd/bwd and (b) d(scale) flows through the
    # multiply for free while the kernel itself stays scale-free
    q = q.astype(jnp.float32) * scale

    def forward(q_, k_, v_, q_pos_, kv_valid_):
        qf, qp, kf, vf, valid = tiling.pad_attention_operands(
            q_, q_pos_, k_, v_, kv_valid_, bq, bkv)
        s_p, t_p = qf.shape[1], kf.shape[1]

        in_specs, out_spec = attention_blockspecs(bq, bkv, g, hd, hv)
        grid = (b, kh * g, s_p // bq, t_p // bkv)
        out = pl.pallas_call(
            functools.partial(_flash_body, block_kv=bkv, causal=causal,
                              t_kv=t),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, s_p, kh, g, hv), v_.dtype),
            scratch_shapes=[
                pltpu.VMEM((bq, _STATE_LANES), jnp.float32),  # running max m
                pltpu.VMEM((bq, _STATE_LANES), jnp.float32),  # running sum l
                pltpu.VMEM((bq, hv), jnp.float32),            # weighted-v acc
            ],
            interpret=interpret,
        )(qp, valid, qf, kf, vf)
        return tiling.unpad(out, 1, s_q)

    # q_pos / kv_valid ride along as explicit primals (closing over them
    # would leak the enclosing jit's tracers into the custom_vjp jaxpr);
    # being integer/bool they get float0 cotangents.
    @jax.custom_vjp
    def run(q_, k_, v_, q_pos_, kv_valid_):
        return forward(q_, k_, v_, q_pos_, kv_valid_)

    def fwd(q_, k_, v_, q_pos_, kv_valid_):
        return forward(q_, k_, v_, q_pos_, kv_valid_), \
            (q_, k_, v_, q_pos_, kv_valid_)

    def bwd(res, gy):
        import numpy as np
        from repro.models.flash import flash_attention as flash_ref
        q_, k_, v_, q_pos_, kv_valid_ = res
        # q_ is already pre-scaled, so the recompute runs at scale=1.0 (a
        # static float — the traced scale operand must not be closed over)
        _, vjp = jax.vjp(
            lambda a, b_, c: flash_ref(a, b_, c, q_pos=q_pos_,
                                       kv_valid=kv_valid_, causal=causal,
                                       scale=1.0), q_, k_, v_)
        f0 = jax.dtypes.float0
        return (*vjp(gy), np.zeros(q_pos_.shape, f0),
                np.zeros(kv_valid_.shape, f0))

    run.defvjp(fwd, bwd)
    return run(q, k, v, q_pos, kv_valid)


def _attention_entry(q, k, v, *, q_pos, kv_valid, causal, scale,
                     softmax_impl="float"):
    if softmax_impl == "dualmode":
        raise ValueError(
            "attn_impl='flash_pallas' is the float blocked kernel and "
            "cannot honor softmax_impl='dualmode' — use 'naive' or "
            "'flash_pallas_int'")
    return flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                  causal=causal, scale=scale)


dispatch.register_attention("flash_pallas", _attention_entry)
