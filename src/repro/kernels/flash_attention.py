"""Pallas blocked flash attention on the unit's log-domain datapath.

The paper's softmax normalizes in the LOG domain (Eq. 10); that form
telescopes exactly into the online-softmax recurrence, so the streamed
inner step here is literally :func:`repro.kernels.datapath.
online_softmax_update` — the same function the pure-JAX blocked path
(``models/flash.py``) runs.  This kernel adds the Pallas grid around it:
KV is streamed through VMEM in (block_kv)-sized tiles while the running
(m, l, acc) state lives in VMEM scratch across the sequential kv grid
dimension, so the (S, T) score matrix is never materialized in HBM.

Shapes match the model-side attention core (GQA/MLA compatible):

    q (B, S, K, G, h)   k (B, T, K, h)   v (B, T, K, hv)  ->  (B, S, K, G, hv)

with G query groups per KV head and hv possibly != h (MLA).  Masking: kv
position t attends iff ``kv_valid[b, t]`` and (not causal or
``t <= q_pos[b, s]``); masked scores take ``datapath.MASK_VALUE`` exactly
like the naive path, so all three implementations agree on masking.

Non-divisible S/T are padded up to the block grid (``kernels/tiling.py``
policy) and the output sliced back; padded KV rows are simply invalid.
Runs on CPU with ``interpret=True`` (the default off-TPU).

Residual contract: the forward emits the per-row online-softmax
statistics ``(m, l)`` — running max and normalizer of the PRE-SCALED
masked scores, laid out (B, K, G, S) — as extra kernel outputs.  The
custom VJP saves ``(o, m, l)`` so the backward kernels
(``kernels/flash_attention_bwd.py``) re-derive the probabilities from the
same :func:`datapath.online_softmax_update` arithmetic instead of
re-running the whole unfused forward graph.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import datapath as dp
from . import dispatch, tiling

_STATE_LANES = 128   # lane width of the (m, l) scratch rows


def attention_blockspecs(bq: int, bkv: int, g: int, hd: int, hv: int):
    """(in_specs for (q_pos, kv_valid, q, k, v), out_spec) shared by every
    flash kernel flavor.  Index maps take (b, head, q_tile, *rest) with
    the kv tile as the LAST grid dim, so the same specs serve the float
    kernel's 4D grid and the int kernel's 5D (extra sweep dim) grid.
    """
    in_specs = [
        pl.BlockSpec((1, bq), lambda b_, h_, qi, *r: (b_, qi)),
        pl.BlockSpec((1, bkv), lambda b_, h_, qi, *r: (b_, r[-1])),
        pl.BlockSpec((1, bq, 1, 1, hd),
                     lambda b_, h_, qi, *r: (b_, qi, h_ // g, h_ % g, 0)),
        pl.BlockSpec((1, bkv, 1, hd),
                     lambda b_, h_, qi, *r: (b_, r[-1], h_ // g, 0)),
        pl.BlockSpec((1, bkv, 1, hv),
                     lambda b_, h_, qi, *r: (b_, r[-1], h_ // g, 0)),
    ]
    out_spec = pl.BlockSpec(
        (1, bq, 1, 1, hv),
        lambda b_, h_, qi, *r: (b_, qi, h_ // g, h_ % g, 0))
    return in_specs, out_spec


def vmem_plan(s_q: int, t_kv: int, hd: int, hv: int, g: int = 1):
    """Static VMEM residency of the forward float kernel at this shape.

    {call_name: {ref_name: (block_shape, dtype)}} with ``in:``/``out:``/
    ``scratch:`` key prefixes — ``repro.analysis.vmem`` prices each call
    as 2x(in+out tiles, double-buffered) + scratch against
    ``tiling.VMEM_CORE_BUDGET`` and cross-checks the shapes against the
    traced kernel's ref avals.  Must mirror the pallas_call specs above
    exactly (the audit fails on drift, not this module).
    """
    bq, bkv = tiling.attention_blocks(s_q, t_kv)
    return {"flash_fwd": {
        "in:q_pos": ((1, bq), jnp.int32),
        "in:kv_valid": ((1, bkv), jnp.int32),
        "in:q": ((1, bq, 1, 1, hd), jnp.float32),
        "in:k": ((1, bkv, 1, hd), jnp.float32),
        "in:v": ((1, bkv, 1, hv), jnp.float32),
        "out:o": ((1, bq, 1, 1, hv), jnp.float32),
        "out:m": ((1, 1, 1, bq), jnp.float32),
        "out:l": ((1, 1, 1, bq), jnp.float32),
        "scratch:m": ((bq, _STATE_LANES), jnp.float32),
        "scratch:l": ((bq, _STATE_LANES), jnp.float32),
        "scratch:acc": ((bq, tiling.scratch_lanes(hv)), jnp.float32),
    }}


def rowstat_blockspec(bq: int, g: int):
    """BlockSpec for the (B, K, G, S) per-row statistic arrays (m, l, D)
    on the forward/dq grid layout (b, head, q_tile, *rest)."""
    return pl.BlockSpec((1, 1, 1, bq),
                        lambda b_, h_, qi, *r: (b_, h_ // g, h_ % g, qi))


def masked_score_block(q, kb, qpos_ref, valid_ref, kv_tile: int, *,
                       block_kv: int, causal: bool, t_kv: int):
    """(masked scores, mask) tile — ONE definition of the flash masking.

    Scores take ``datapath.MASK_VALUE`` for user-invalid / causally
    masked keys (matching the naive path bitwise) and ``-inf`` for
    tiling-padded phantom keys, which must carry NO mass.  Shared by the
    forward body and both backward kernels so forward and backward can
    never disagree on which keys are "off".  The mask is returned because
    the backward must zero dS where the score was replaced by the
    constant MASK_VALUE — the ``jnp.where`` routes no gradient into the
    untaken branch, and the reference VJP therefore sends exactly 0
    through masked positions while their (tiny but nonzero) probability
    mass still reaches dV.
    """
    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bkv)
    mask = valid_ref[...] != 0                            # (1, bkv) -> bcast
    kv_pos = kv_tile * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    if causal:
        q_pos = qpos_ref[...].reshape(-1, 1)              # (bq, 1)
        mask = mask & (kv_pos <= q_pos)
    s = jnp.where(mask, s, dp.MASK_VALUE)
    return jnp.where(kv_pos < t_kv, s, -jnp.inf), \
        jnp.broadcast_to(mask, s.shape)


def _flash_body(qpos_ref, valid_ref, q_ref, k_ref, v_ref, o_ref, *rest,
                block_kv: int, causal: bool, t_kv: int, with_stats: bool):
    if with_stats:
        m_out_ref, l_out_ref, m_ref, l_ref, acc_ref = rest
    else:
        m_ref, l_ref, acc_ref = rest
    kj = pl.program_id(3)
    hv = o_ref.shape[-1]

    @pl.when(kj == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, dp.MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, 0, :].astype(jnp.float32)          # (bq, h) pre-scaled
    kb = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, h)
    vb = v_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, hv)
    s, _ = masked_score_block(q, kb, qpos_ref, valid_ref, kj,
                              block_kv=block_kv, causal=causal, t_kv=t_kv)

    m, l = m_ref[:, :1], l_ref[:, :1]                     # (bq, 1)
    m_new, l_new, p, corr = dp.online_softmax_update(m, l, s)
    # acc scratch is lane-rounded (hv may be off the 128 grid — MLA);
    # only the live [:, :hv] slice carries data
    acc_ref[:, :hv] = acc_ref[:, :hv] * corr + jnp.dot(
        p, vb, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kj == pl.num_programs(3) - 1)
    def _():
        out = dp.online_softmax_finish(l_ref[:, :1], acc_ref[:, :hv])
        o_ref[0, :, 0, 0, :] = out.astype(o_ref.dtype)
        if with_stats:
            m_out_ref[0, 0, 0, :] = m_ref[:, 0]
            l_out_ref[0, 0, 0, :] = l_ref[:, 0]


def _flash_fwd_call(q, k, v, q_pos, kv_valid, *, causal: bool, bq: int,
                    bkv: int, interpret: bool, with_stats: bool):
    """Padded forward pallas_call; ``q`` must already be pre-scaled f32.

    ``with_stats=True`` (the grad/fwd path) returns (o, m, l) with m/l
    the (B, K, G, S) per-row online-softmax statistics — the backward
    kernels' residuals.  ``with_stats=False`` (the inference primal)
    returns o alone, so forward-only calls never pay the extra stat HBM
    writes.  Everything is sliced back to the logical sequence length.
    """
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    qf, qp, kf, vf, valid = tiling.pad_attention_operands(
        q, q_pos, k, v, kv_valid, bq, bkv)
    s_p, t_p = qf.shape[1], kf.shape[1]

    in_specs, out_spec = attention_blockspecs(bq, bkv, g, hd, hv)
    stat_spec = rowstat_blockspec(bq, g)
    o_shape = jax.ShapeDtypeStruct((b, s_p, kh, g, hv), v.dtype)
    stat_shape = jax.ShapeDtypeStruct((b, kh, g, s_p), jnp.float32)
    grid = (b, kh * g, s_p // bq, t_p // bkv)
    out = pl.pallas_call(
        functools.partial(_flash_body, block_kv=bkv, causal=causal,
                          t_kv=t, with_stats=with_stats),
        grid=grid,
        in_specs=in_specs,
        out_specs=([out_spec, stat_spec, stat_spec] if with_stats
                   else out_spec),
        out_shape=([o_shape, stat_shape, stat_shape] if with_stats
                   else o_shape),
        scratch_shapes=[
            pltpu.VMEM((bq, _STATE_LANES), jnp.float32),  # running max m
            pltpu.VMEM((bq, _STATE_LANES), jnp.float32),  # running sum l
            pltpu.VMEM((bq, tiling.scratch_lanes(hv)),
                       jnp.float32),                      # weighted-v acc
        ],
        interpret=interpret,
    )(qp, valid, qf, kf, vf)
    if not with_stats:
        return tiling.unpad(out, 1, s_q)
    o, m, l = out
    return (tiling.unpad(o, 1, s_q), tiling.unpad(m, 3, s_q),
            tiling.unpad(l, 3, s_q))


def flash_attention_pallas(q, k, v, *, q_pos, kv_valid, causal: bool = True,
                           scale: float | None = None,
                           block_q: int | None = None,
                           block_kv: int | None = None,
                           interpret: bool | None = None,
                           return_stats: bool = False):
    """Blocked flash attention; see module docstring for shapes/masking.

    ``scale`` rides as a TRACED operand (folded into the q pre-scale
    before the kernel), so distinct head-dim/user scales share one
    compilation — only genuinely structural args (blocks, causal,
    interpret) are jit-static.

    Differentiable: the custom VJP runs the dedicated dq and dk/dv Pallas
    kernels (``kernels/flash_attention_bwd.py``) from the saved
    ``(o, m, l)`` residuals — the pure-JAX blocked path (models/flash.py)
    remains the reference the backward is pinned against in tests.

    ``return_stats=True`` returns ``(out, m, l)`` with the (B, K, G, S)
    per-row statistics of the pre-scaled scores (forward-only form, for
    residual-contract parity tests).
    """
    hd = q.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = (1.0 / hd ** 0.5) if scale is None else scale
    bq, bkv = tiling.attention_blocks(q.shape[1], k.shape[1])
    bq = bq if block_q is None else block_q
    bkv = bkv if block_kv is None else block_kv
    return _flash_pallas_jit(q, k, v, q_pos, kv_valid,
                             jnp.float32(scale), causal=causal, block_q=bq,
                             block_kv=bkv, interpret=interpret,
                             return_stats=return_stats)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_kv", "interpret", "return_stats"))
def _flash_pallas_jit(q, k, v, q_pos, kv_valid, scale, *, causal: bool,
                      block_q: int, block_kv: int, interpret: bool,
                      return_stats: bool = False):
    bq, bkv = block_q, block_kv
    # fold the traced scale into q HERE, outside the custom_vjp, so (a) no
    # tracer is closed over by fwd/bwd and (b) d(scale) flows through the
    # multiply for free while the kernel itself stays scale-free
    q = q.astype(jnp.float32) * scale

    if return_stats:
        return _flash_fwd_call(q, k, v, q_pos, kv_valid, causal=causal,
                               bq=bq, bkv=bkv, interpret=interpret,
                               with_stats=True)

    # q_pos / kv_valid ride along as explicit primals (closing over them
    # would leak the enclosing jit's tracers into the custom_vjp jaxpr);
    # being integer/bool they get float0 cotangents.
    @jax.custom_vjp
    def run(q_, k_, v_, q_pos_, kv_valid_):
        # the non-differentiated primal: stats are only a backward
        # residual, so inference calls skip their HBM writes entirely
        return _flash_fwd_call(q_, k_, v_, q_pos_, kv_valid_,
                               causal=causal, bq=bq, bkv=bkv,
                               interpret=interpret, with_stats=False)

    def fwd(q_, k_, v_, q_pos_, kv_valid_):
        o, m, l = _flash_fwd_call(q_, k_, v_, q_pos_, kv_valid_,
                                  causal=causal, bq=bq, bkv=bkv,
                                  interpret=interpret, with_stats=True)
        return o, (q_, k_, v_, o, m, l, q_pos_, kv_valid_)

    def bwd(res, gy):
        import numpy as np
        from .flash_attention_bwd import flash_attention_bwd_pallas
        q_, k_, v_, o, m, l, q_pos_, kv_valid_ = res
        # q_ is already pre-scaled, so the backward kernels run scale-free
        # (the scale's own gradient flows through the fold-in multiply)
        dq, dk, dv = flash_attention_bwd_pallas(
            q_, k_, v_, o, m, l, gy, q_pos=q_pos_, kv_valid=kv_valid_,
            causal=causal, block_q=bq, block_kv=bkv, interpret=interpret)
        f0 = jax.dtypes.float0
        return (dq, dk.astype(k_.dtype), dv.astype(v_.dtype),
                np.zeros(q_pos_.shape, f0), np.zeros(kv_valid_.shape, f0))

    run.defvjp(fwd, bwd)
    return run(q, k, v, q_pos, kv_valid)


def _attention_entry(q, k, v, *, q_pos, kv_valid, causal, scale,
                     softmax_impl="float", ring_axis=""):
    if softmax_impl != "float":
        raise ValueError(
            "attn_impl='flash_pallas' is the float blocked kernel and "
            f"cannot honor softmax_impl={softmax_impl!r} (a dualmode word "
            "contract) — use 'naive' or 'flash_pallas_int'")
    return flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                  causal=causal, scale=scale)


dispatch.register_attention(
    "flash_pallas", _attention_entry,
    modes=("float",), grad=True,
    note="Pallas float kernel with custom VJP")
