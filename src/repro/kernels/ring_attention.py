"""Sequence-parallel ring flash attention over a mesh axis.

The ROADMAP's last kernel item: compose the blocked Pallas flash
attention with the distributed layer.  Online softmax is an associative,
commutative monoid (Milakov & Gimelshein), so per-shard ``(m, l, acc)``
partials merge EXACTLY via :func:`repro.kernels.datapath.
online_softmax_merge` no matter how the key set was split — ring
attention is the datapath's fold run across devices, and the existing
``(m, l)`` residual contract is the interface:

  * Q (with its global positions) stays put, sharded along the sequence
    dim over ``axis``; the K/V/kv_valid shards rotate around the ring
    with ``jax.lax.ppermute``, each carrying its global key offset.
  * Each hop runs the EXISTING single-device Pallas kernel
    (``flash_attention_pallas(..., return_stats=True)``) on the local q
    shard against the visiting KV shard — the kernel sees shard-local
    key positions, so the hop shifts ``q_pos`` by the shard's offset —
    and merges the hop's partial into the running (m, l, acc).
  * Causal hops whose KV shard lies entirely in every local row's
    future are skipped (``lax.cond``): such a shard would contribute
    only the exp(MASK_VALUE) ~ 1e-13 relative mass of fully-masked keys,
    and not visiting it at all is where the ring's throughput win lives
    (the diagonal wavefront does ~half the hops of the full rotation).

Backward: the custom VJP composes the PR-3 dq and dk/dv kernels
(``kernels/flash_attention_bwd.py``) per hop with a REVERSE rotation in
which each KV shard travels the ring together with its dk/dv
accumulator — every q shard adds its contribution as the block visits,
and after the full circle the accumulator arrives back on the shard
that owns the KV block.  dS is formed from the MERGED (m, l) — the
whole-row statistics — so each hop's tile gradients are exactly the
single-device backward's for those columns, and dq sums over hops.

Shapes match every other flash flavor (GQA/MLA compatible):

    q (B, S, K, G, h)   k (B, T, K, h)   v (B, T, K, hv) -> (B, S, K, G, hv)

with S and T both divisible by the ring axis size.  Runs on CPU under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` with
``interpret=True`` (the default off-TPU) — the multi-device CI lane.

DUAL-MODE ring (``softmax_impl='dualmode'``, forward-only): the snapped
int monoid of :mod:`repro.core.softmax_unit` is a partial contract
exactly like ``(m, l, o*l)``, so each hop runs the one-sweep int kernel
(``flash_attention_pallas_int(..., return_partial=True)``) and folds the
``(m snapped, S buckets, acc)`` hop partial with
:func:`repro.core.softmax_unit.online_merge_int`.  The guard shift is
fixed from the GLOBAL key count before sharding, so every shard's words
are the whole-row unit's words and the fold is word-exact regardless of
ring size or hop order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import softmax_unit as unit
from repro.distributed.pipeline import shard_map_compat

from . import datapath as dp
from . import dispatch, tiling
from .flash_attention import flash_attention_pallas
from .flash_attention_bwd import flash_attention_bwd_pallas
from .flash_attention_int import flash_attention_pallas_int


def _stats_to_rows(x):
    """(B, K, G, S) kernel-stat layout -> (B, S, K, G, 1) merge layout."""
    return jnp.moveaxis(x, 3, 1)[..., None]


def _rows_to_stats(x):
    """(B, S, K, G, 1) merge layout -> (B, K, G, S) kernel-stat layout."""
    return jnp.moveaxis(x[..., 0], 1, 3)


def _ring_perm(n: int, reverse: bool = False):
    if reverse:
        return [(i, (i - 1) % n) for i in range(n)]
    return [(i, (i + 1) % n) for i in range(n)]


def _rotate(tree, axis: str, perm):
    return jax.tree.map(lambda x: jax.lax.ppermute(x, axis, perm), tree)


# --------------------------------------------------------------------------
# per-shard loops (run INSIDE shard_map; q pre-scaled f32)
# --------------------------------------------------------------------------

def _ring_fwd_local(qf, k, v, q_pos, kv_valid, *, axis, n_shards, t_loc,
                    causal, block_q, block_kv, interpret, skip_hops):
    b, s_loc, kh, g, _ = qf.shape
    hv = v.shape[-1]
    off0 = (jax.lax.axis_index(axis) * t_loc).astype(jnp.int32)[None]
    qpos_max = jnp.max(q_pos)
    perm = _ring_perm(n_shards)

    m0 = jnp.full((b, s_loc, kh, g, 1), dp.MASK_VALUE, jnp.float32)
    l0 = jnp.zeros((b, s_loc, kh, g, 1), jnp.float32)
    acc0 = jnp.zeros((b, s_loc, kh, g, hv), jnp.float32)

    def hop(carry, _):
        k_c, v_c, valid_c, off_c, m, l, acc = carry

        def run(m_, l_, acc_):
            o_h, m_h, l_h = flash_attention_pallas(
                qf, k_c, v_c, q_pos=q_pos - off_c[0], kv_valid=valid_c,
                causal=causal, scale=1.0, block_q=block_q,
                block_kv=block_kv, interpret=interpret, return_stats=True)
            m_h, l_h = _stats_to_rows(m_h), _stats_to_rows(l_h)
            # o = acc/l (online_softmax_finish): o*l recovers the shard's
            # unnormalized accumulator, the mergeable partial
            acc_h = o_h.astype(jnp.float32) * l_h
            return dp.online_softmax_merge((m_, l_, acc_),
                                           (m_h, l_h, acc_h))

        if skip_hops and causal:
            m, l, acc = jax.lax.cond(
                off_c[0] <= qpos_max, run,
                lambda m_, l_, acc_: (m_, l_, acc_), m, l, acc)
        else:
            m, l, acc = run(m, l, acc)
        k_c, v_c, valid_c, off_c = _rotate((k_c, v_c, valid_c, off_c),
                                           axis, perm)
        return (k_c, v_c, valid_c, off_c, m, l, acc), None

    carry0 = (k, v, kv_valid, off0, m0, l0, acc0)
    (_, _, _, _, m, l, acc), _ = jax.lax.scan(hop, carry0, None,
                                              length=n_shards)
    out = dp.online_softmax_finish(l, acc).astype(v.dtype)
    return out, _rows_to_stats(m), _rows_to_stats(l)


def _ring_fwd_local_int(qf, k, v, q_pos, kv_valid, *, axis, n_shards,
                        t_loc, causal, block_q, block_kv, interpret,
                        skip_hops, guard_shift):
    """Dual-mode twin of ``_ring_fwd_local``: the hop partial is the
    snapped int monoid state, folded with ``online_merge_int``.  The
    caller fixes ``guard_shift`` from the GLOBAL key count so hop words
    match the whole-row unit's.  Forward-only."""
    b, s_loc, kh, g, _ = qf.shape
    hv = v.shape[-1]
    nb = unit.N_SNAP_BUCKETS
    off0 = (jax.lax.axis_index(axis) * t_loc).astype(jnp.int32)[None]
    qpos_max = jnp.max(q_pos)
    perm = _ring_perm(n_shards)

    m0 = jnp.full((b, s_loc, kh, g, 1), unit.SNAP_MIN, jnp.int32)
    S0 = jnp.zeros((b, s_loc, kh, g, nb), jnp.int32)
    acc0 = jnp.zeros((b, s_loc, kh, g, hv), jnp.float32)

    def hop(carry, _):
        k_c, v_c, valid_c, off_c, m, S, acc = carry

        def run(m_, S_, acc_):
            acc_h, m_h, S_h = flash_attention_pallas_int(
                qf, k_c, v_c, q_pos=q_pos - off_c[0], kv_valid=valid_c,
                causal=causal, scale=1.0, block_q=block_q,
                block_kv=block_kv, interpret=interpret,
                guard_shift=guard_shift, return_partial=True)
            # stats (B,K,G,S[,nb]) -> merge rows (B,S,K,G,[1|nb])
            m_h = _stats_to_rows(m_h)
            S_h = jnp.moveaxis(S_h, 3, 1)
            return unit.online_merge_int((m_, S_, acc_), (m_h, S_h, acc_h))

        if skip_hops and causal:
            m, S, acc = jax.lax.cond(
                off_c[0] <= qpos_max, run,
                lambda m_, S_, acc_: (m_, S_, acc_), m, S, acc)
        else:
            m, S, acc = run(m, S, acc)
        k_c, v_c, valid_c, off_c = _rotate((k_c, v_c, valid_c, off_c),
                                           axis, perm)
        return (k_c, v_c, valid_c, off_c, m, S, acc), None

    carry0 = (k, v, kv_valid, off0, m0, S0, acc0)
    (_, _, _, _, m, S, acc), _ = jax.lax.scan(hop, carry0, None,
                                              length=n_shards)
    l = unit.online_finish_int(S)                      # (B, S_loc, K, G)
    return (acc / l[..., None].astype(jnp.float32)).astype(v.dtype)


def _ring_bwd_local(qf, k, v, o, m, l, do, q_pos, kv_valid, *, axis,
                    n_shards, t_loc, causal, block_q, block_kv, interpret,
                    skip_hops):
    b, s_loc, kh, g, hd = qf.shape
    off0 = (jax.lax.axis_index(axis) * t_loc).astype(jnp.int32)[None]
    qpos_max = jnp.max(q_pos)
    # reverse rotation: each KV shard travels WITH its dk/dv accumulator
    # and is home again after the full circle
    perm = _ring_perm(n_shards, reverse=True)

    dq0 = jnp.zeros((b, s_loc, kh, g, hd), jnp.float32)
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)

    def hop(carry, _):
        k_c, v_c, valid_c, off_c, dk_c, dv_c, dq = carry

        def run(dq_, dk_, dv_):
            dq_h, dk_h, dv_h = flash_attention_bwd_pallas(
                qf, k_c, v_c, o, m, l, do, q_pos=q_pos - off_c[0],
                kv_valid=valid_c, causal=causal, block_q=block_q,
                block_kv=block_kv, interpret=interpret)
            return dq_ + dq_h, dk_ + dk_h, dv_ + dv_h

        if skip_hops and causal:
            dq, dk_c, dv_c = jax.lax.cond(
                off_c[0] <= qpos_max, run,
                lambda dq_, dk_, dv_: (dq_, dk_, dv_), dq, dk_c, dv_c)
        else:
            dq, dk_c, dv_c = run(dq, dk_c, dv_c)
        k_c, v_c, valid_c, off_c, dk_c, dv_c = _rotate(
            (k_c, v_c, valid_c, off_c, dk_c, dv_c), axis, perm)
        return (k_c, v_c, valid_c, off_c, dk_c, dv_c, dq), None

    carry0 = (k, v, kv_valid, off0, dk0, dv0, dq0)
    (_, _, _, _, dk, dv, dq), _ = jax.lax.scan(hop, carry0, None,
                                               length=n_shards)
    return dq, dk, dv


def _ring_local(qf, k, v, q_pos, kv_valid, *, return_stats, **kw):
    """shard_map body: custom VJP around the two ring loops."""
    if return_stats:
        return _ring_fwd_local(qf, k, v, q_pos, kv_valid, **kw)

    @jax.custom_vjp
    def run(qf_, k_, v_, q_pos_, kv_valid_):
        out, _, _ = _ring_fwd_local(qf_, k_, v_, q_pos_, kv_valid_, **kw)
        return out

    def fwd(qf_, k_, v_, q_pos_, kv_valid_):
        out, m, l = _ring_fwd_local(qf_, k_, v_, q_pos_, kv_valid_, **kw)
        return out, (qf_, k_, v_, out, m, l, q_pos_, kv_valid_)

    def bwd(res, gy):
        import numpy as np
        qf_, k_, v_, o, m, l, q_pos_, kv_valid_ = res
        dq, dk, dv = _ring_bwd_local(
            qf_, k_, v_, o, m, l, gy.astype(jnp.float32), q_pos_,
            kv_valid_, **kw)
        f0 = jax.dtypes.float0
        return (dq, dk.astype(k_.dtype), dv.astype(v_.dtype),
                np.zeros(q_pos_.shape, f0), np.zeros(kv_valid_.shape, f0))

    run.defvjp(fwd, bwd)
    return run(qf, k, v, q_pos, kv_valid)


# --------------------------------------------------------------------------
# global-array entry
# --------------------------------------------------------------------------

def ring_flash_attention(q, k, v, *, q_pos, kv_valid, mesh=None,
                         axis: str = "model", causal: bool = True,
                         scale: float | None = None,
                         block_q: int | None = None,
                         block_kv: int | None = None,
                         interpret: bool | None = None,
                         skip_masked_hops: bool = True,
                         return_stats: bool = False,
                         softmax_impl: str = "float"):
    """Sequence-parallel ring flash attention (see module docstring).

    Takes GLOBAL arrays and wraps the per-shard ring loop in a
    ``shard_map`` over ``axis``: q/q_pos/k/v/kv_valid shard along their
    sequence dims, everything else is replicated.  ``mesh=None`` picks
    up the ambient ``with mesh:`` context.  Differentiable: the custom
    VJP composes the dedicated backward kernels per hop (reverse
    rotation, dk/dv accumulated on the shard that owns the KV block).

    ``return_stats=True`` returns ``(out, m, l)`` with the MERGED
    whole-row statistics laid out (B, K, G, S) — the same residual
    contract as the single-device kernel, which parity tests pin the
    merge against.  ``skip_masked_hops=False`` forces every hop to run
    (the skipped hops' only contribution is the exp(MASK_VALUE) mass of
    fully-masked keys, ~1e-13 relative).

    ``softmax_impl='dualmode'`` runs the snapped int monoid per hop (see
    module docstring) — forward-only, and ``return_stats`` is not
    supported there (the int partial is (m, S-buckets, acc), a different
    residual contract).
    """
    if mesh is None:
        mesh = dispatch.ambient_mesh()
    if mesh is None or axis not in mesh.axis_names:
        raise ValueError(
            f"ring_flash_attention needs a mesh with axis {axis!r} — pass "
            "mesh= or run under `with mesh:` (launch/mesh.auto_mesh)")
    n = mesh.shape[axis]
    s_q, hd = q.shape[1], q.shape[-1]
    t = k.shape[1]
    if s_q % n or t % n:
        raise ValueError(
            f"flash_ring shards the sequence dims over {axis!r} (size "
            f"{n}): s_q={s_q} and t_kv={t} must both divide")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = (1.0 / hd ** 0.5) if scale is None else scale
    bq, bkv = tiling.attention_blocks(s_q // n, t // n)
    bq = bq if block_q is None else block_q
    bkv = bkv if block_kv is None else block_kv

    # fold the scale into q HERE, outside the custom_vjp — d(scale) flows
    # through the multiply and the ring loops stay scale-free, exactly
    # like the single-device kernel
    qf = q.astype(jnp.float32) * jnp.float32(scale)
    if softmax_impl == "dualmode":
        if return_stats:
            raise ValueError(
                "ring_flash_attention: return_stats is a float (m, l) "
                "residual contract; the dualmode ring folds (m, S, acc) "
                "int partials and does not expose them")
        # the whole-row guard, from the key count BEFORE sharding
        local = functools.partial(
            _ring_fwd_local_int, axis=axis, n_shards=n, t_loc=t // n,
            causal=causal, block_q=bq, block_kv=bkv, interpret=interpret,
            skip_hops=skip_masked_hops,
            guard_shift=max(0, t.bit_length() - 16))
    elif softmax_impl == "float":
        local = functools.partial(
            _ring_local, axis=axis, n_shards=n, t_loc=t // n, causal=causal,
            block_q=bq, block_kv=bkv, interpret=interpret,
            skip_hops=skip_masked_hops, return_stats=return_stats)
    else:
        raise ValueError(
            f"ring_flash_attention softmax_impl={softmax_impl!r}: expected "
            "'float' or 'dualmode'")

    def seq(nd: int, d: int = 1) -> P:
        return P(*[axis if i == d else None for i in range(nd)])

    in_specs = (seq(5), seq(4), seq(4), seq(2), seq(2))
    out_specs = ((seq(5), seq(4, 3), seq(4, 3)) if return_stats
                 else seq(5))
    fn = shard_map_compat(local, mesh, in_specs, out_specs)
    return fn(qf, k, v, q_pos.astype(jnp.int32), kv_valid)


def vmem_plan(s_q: int, t_kv: int, hd: int, hv: int, g: int = 1,
              n_shards: int = 8):
    """Static VMEM residency of the ring's per-hop local kernels.

    The ring never launches a kernel of its own — each hop runs the
    single-device flash kernels on the SHARD-LOCAL extents, so the plan
    delegates to those modules at (s_q/n, t_kv/n) and namespaces the
    calls per hop."""
    from . import flash_attention, flash_attention_int
    s_loc = max(s_q // n_shards, 1)
    t_loc = max(t_kv // n_shards, 1)
    out = {}
    for mod in (flash_attention, flash_attention_int):
        for name, plan in mod.vmem_plan(s_loc, t_loc, hd, hv, g).items():
            out[f"ring_hop_{name}"] = plan
    return out


def _attention_entry(q, k, v, *, q_pos, kv_valid, causal, scale,
                     softmax_impl="float", ring_axis="model"):
    impl = ("dualmode" if softmax_impl in ("dualmode", "dualmode_snap")
            else "float")
    return ring_flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                causal=causal, scale=scale,
                                axis=ring_axis or "model",
                                softmax_impl=impl)


dispatch.register_attention(
    "flash_ring", _attention_entry,
    modes=("float", "dualmode", "dualmode_snap"), grad=True,
    needs_mesh=True, mesh_safe=True,
    note="shard_map ring over the KV axis; requires an ambient mesh")
