"""Dedicated Pallas backward kernels for blocked flash attention.

The forward kernel (``kernels/flash_attention.py``) saves the per-row
online-softmax statistics ``(m, l)`` instead of discarding them, so the
backward never re-runs the whole unfused graph: each tile re-derives the
probabilities from the SAME :func:`repro.kernels.datapath.
online_softmax_update` arithmetic the forward streamed —

    _, _, p, _ = online_softmax_update(m_final, l_final, s)
    p          = online_softmax_finish(l_final, p)          # normalized

(with ``m_final`` the whole-row max, the update's running max is already
saturated, so ``p`` is exactly the forward's probability tile) — plus
Dao et al.'s recompute trick ``D_i = rowsum(dO_i * O_i)``, which turns
the softmax-jacobian term into one per-row scalar:

    dS = P * (dO V^T - D)        dQ = dS K     dK = dS^T Q     dV = P^T dO

``D`` is FUSED into the kernels instead of running as a separate
pre-pass: the dq kernel computes it from the (o, dO) tiles on its first
KV step and carries it in VMEM scratch for the rest of the sweep; the
dk/dv kernel recomputes it per (q tile) visit — a (bq, hv) elementwise
row sum, noise next to the tile matmuls — so neither kernel reads a
third per-row statistic from HBM and no extra XLA pass materializes
``D`` at all.

Standard two-pass split, one kernel per output side:

  * dq:    grid (b, heads, q_tiles, kv_tiles) — stream KV per q tile,
           accumulate dQ in VMEM scratch across the sequential kv dim
           (``attention_blockspecs``' layout, reused verbatim); D lives
           in a second scratch row, computed once at kv step 0.
  * dk/dv: grid (b, kv_heads, kv_tiles, groups, q_tiles) — stream Q per
           kv tile; the G query groups of a KV head and all q tiles
           accumulate into the SAME (bkv, h)/(bkv, hv) scratch, so the
           GQA group-sum happens in VMEM, not HBM.

Masking is :func:`flash_attention.masked_score_block` — the one
definition the forward uses — so forward and backward can never disagree
on which keys are "off".  Masked positions behave exactly like the
reference VJP: their MASK_VALUE probability mass still reaches dV (the
forward really attends that mass), but dS is zeroed where the score was
replaced by the constant — the reference's ``jnp.where`` routes no
gradient into the untaken branch, so dQ/dK see exactly 0 there.  Tiling
phantoms score -inf and contribute to nothing.

``q`` arrives pre-scaled (the traced scale is folded in before the
custom_vjp), so every kernel here is scale-free and dq is the cotangent
of the pre-scaled q — the chain rule through the fold-in multiply is
handled by JAX outside.  Runs on CPU with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import datapath as dp
from . import tiling
from .flash_attention import (attention_blockspecs, masked_score_block,
                              rowstat_blockspec)


def _probs_from_stats(m_row, l_row, s):
    """Forward probability tile from the saved (m, l) row statistics,
    through the forward's own datapath steps."""
    _, _, p, _ = dp.online_softmax_update(m_row, l_row, s)
    return dp.online_softmax_finish(l_row, p)


def _rowsum_do_o(do_ref, o_ref):
    """Dao's per-row scalar D = rowsum(dO ∘ O) from the two output-layout
    tiles — the fused replacement for the old host-side pre-pass."""
    do = do_ref[0, :, 0, 0, :].astype(jnp.float32)        # (bq, hv)
    o = o_ref[0, :, 0, 0, :].astype(jnp.float32)
    return jnp.sum(do * o, axis=-1, keepdims=True)        # (bq, 1)


def _tile_grads(qpos_ref, valid_ref, q_ref, k_ref, v_ref, do_ref, m_ref,
                l_ref, d_row, kv_tile, *, block_kv: int, causal: bool,
                t_kv: int):
    """The shared per-tile recompute of both backward kernels.

    Loads one (q tile, kv tile) operand pair, re-derives the forward
    probability tile p from the saved (m, l), and forms the score
    cotangent dS = P * (dO V^T - D), zeroed where the forward's mask
    replaced the score by the constant MASK_VALUE (matching the reference
    ``jnp.where`` VJP, which routes no gradient into the untaken branch).
    ``d_row`` is the (bq, 1) fused D — from scratch (dq kernel) or
    recomputed in-tile (dk/dv kernel).

    Returns (p, ds, q, kb, do) — everything either kernel body combines.
    """
    q = q_ref[0, :, 0, 0, :].astype(jnp.float32)          # (bq, h) pre-scaled
    kb = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, h)
    vb = v_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, hv)
    do = do_ref[0, :, 0, 0, :].astype(jnp.float32)        # (bq, hv)
    s, mask = masked_score_block(q, kb, qpos_ref, valid_ref, kv_tile,
                                 block_kv=block_kv, causal=causal,
                                 t_kv=t_kv)
    m_row = m_ref[0, 0, 0, :].reshape(-1, 1)              # (bq, 1)
    l_row = l_ref[0, 0, 0, :].reshape(-1, 1)
    p = _probs_from_stats(m_row, l_row, s)                # (bq, bkv)
    dpv = jax.lax.dot_general(do, vb, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    ds = jnp.where(mask, p * (dpv - d_row), 0.0)          # (bq, bkv)
    return p, ds, q, kb, do


def _dq_body(qpos_ref, valid_ref, q_ref, k_ref, v_ref, o_ref, do_ref,
             m_ref, l_ref, dq_ref, dq_acc, d_sc, *, block_kv: int,
             causal: bool, t_kv: int):
    kj = pl.program_id(3)
    hd = q_ref.shape[-1]

    @pl.when(kj == 0)
    def _():
        dq_acc[...] = jnp.zeros_like(dq_acc)
        # fused D: computed once per q tile on the first KV step, carried
        # in VMEM scratch across the sequential kv dim — no pre-pass
        d_sc[...] = jnp.broadcast_to(_rowsum_do_o(do_ref, o_ref),
                                     d_sc.shape)

    _, ds, _, kb, _ = _tile_grads(
        qpos_ref, valid_ref, q_ref, k_ref, v_ref, do_ref, m_ref, l_ref,
        d_sc[:, :1], kj, block_kv=block_kv, causal=causal, t_kv=t_kv)
    dq_acc[:, :hd] = dq_acc[:, :hd] + jnp.dot(
        ds, kb, preferred_element_type=jnp.float32)

    @pl.when(kj == pl.num_programs(3) - 1)
    def _():
        dq_ref[0, :, 0, 0, :] = dq_acc[:, :hd]


def _dkdv_body(qpos_ref, valid_ref, q_ref, k_ref, v_ref, o_ref, do_ref,
               m_ref, l_ref, dk_ref, dv_ref, dk_acc, dv_acc, *,
               block_kv: int, causal: bool, t_kv: int):
    kv_ = pl.program_id(2)
    g_ = pl.program_id(3)
    qi = pl.program_id(4)
    hd = q_ref.shape[-1]
    hv = v_ref.shape[-1]

    @pl.when((g_ == 0) & (qi == 0))
    def _():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    p, ds, q, _, do = _tile_grads(
        qpos_ref, valid_ref, q_ref, k_ref, v_ref, do_ref, m_ref, l_ref,
        _rowsum_do_o(do_ref, o_ref), kv_, block_kv=block_kv,
        causal=causal, t_kv=t_kv)
    dv_acc[:, :hv] = dv_acc[:, :hv] + jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # P^T dO
    dk_acc[:, :hd] = dk_acc[:, :hd] + jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # dS^T Q

    @pl.when((g_ == pl.num_programs(3) - 1)
             & (qi == pl.num_programs(4) - 1))
    def _():
        dk_ref[0, :, 0, :] = dk_acc[:, :hd]
        dv_ref[0, :, 0, :] = dv_acc[:, :hv]


def vmem_plan(s_q: int, t_kv: int, hd: int, hv: int, g: int = 1):
    """Static VMEM residency of the dq and dk/dv backward kernels (see
    ``flash_attention.vmem_plan`` for the contract)."""
    bq, bkv = tiling.attention_blocks(s_q, t_kv)
    common = {
        "in:q_pos": ((1, bq), jnp.int32),
        "in:kv_valid": ((1, bkv), jnp.int32),
        "in:q": ((1, bq, 1, 1, hd), jnp.float32),
        "in:k": ((1, bkv, 1, hd), jnp.float32),
        "in:v": ((1, bkv, 1, hv), jnp.float32),
        "in:o": ((1, bq, 1, 1, hv), jnp.float32),
        "in:do": ((1, bq, 1, 1, hv), jnp.float32),
        "in:m": ((1, 1, 1, bq), jnp.float32),
        "in:l": ((1, 1, 1, bq), jnp.float32),
    }
    return {
        "flash_bwd_dq": dict(
            common,
            **{"out:dq": ((1, bq, 1, 1, hd), jnp.float32),
               "scratch:dq_acc": ((bq, tiling.scratch_lanes(hd)),
                                  jnp.float32),
               "scratch:d": ((bq, tiling.scratch_lanes(1)), jnp.float32)}),
        "flash_bwd_dkdv": dict(
            common,
            **{"out:dk": ((1, bkv, 1, hd), jnp.float32),
               "out:dv": ((1, bkv, 1, hv), jnp.float32),
               "scratch:dk_acc": ((bkv, tiling.scratch_lanes(hd)),
                                  jnp.float32),
               "scratch:dv_acc": ((bkv, tiling.scratch_lanes(hv)),
                                  jnp.float32)}),
    }


def flash_attention_bwd_pallas(q, k, v, o, m, l, do, *, q_pos, kv_valid,
                               causal: bool, block_q: int, block_kv: int,
                               interpret: bool):
    """(dq, dk, dv) in f32 via the dedicated backward kernels.

    q is the PRE-SCALED f32 query; (o, m, l) are the forward's output and
    per-row statistics (m/l laid out (B, K, G, S)); do is the output
    cotangent.  Blocks must match the forward's so padded grids line up.
    """
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    bq, bkv = block_q, block_kv

    # Dao et al.'s D = rowsum(dO∘O) is fused INTO the kernels (dq: first
    # KV step into scratch; dk/dv: per q-tile visit) — only o/do are
    # padded here, no per-row D array ever exists in HBM
    qf, qp, kf, vf, valid = tiling.pad_attention_operands(
        q, q_pos, k, v, kv_valid, bq, bkv)
    of, _ = tiling.pad_dim(o.astype(jnp.float32), 1, bq)
    dof, _ = tiling.pad_dim(do.astype(jnp.float32), 1, bq)
    # phantom q rows: o/dO pad with 0 (so the fused D is 0 there) and l
    # with 1, so the re-derived probabilities stay finite and every
    # phantom contribution is 0
    mf, _ = tiling.pad_dim(m, 3, bq)
    lf, _ = tiling.pad_dim(l, 3, bq, value=1.0)
    s_p, t_p = qf.shape[1], kf.shape[1]

    body = dict(block_kv=bkv, causal=causal, t_kv=t)
    in_specs, out_spec = attention_blockspecs(bq, bkv, g, hd, hv)
    stat = rowstat_blockspec(bq, g)
    dq = pl.pallas_call(
        functools.partial(_dq_body, **body),
        grid=(b, kh * g, s_p // bq, t_p // bkv),
        in_specs=in_specs + [out_spec, out_spec, stat, stat],  # + o, do, m, l
        out_specs=pl.BlockSpec(
            (1, bq, 1, 1, hd),
            lambda b_, h_, qi, kj: (b_, qi, h_ // g, h_ % g, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s_p, kh, g, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, tiling.scratch_lanes(hd)), jnp.float32),
            pltpu.VMEM((bq, tiling.scratch_lanes(1)), jnp.float32)],  # D
        interpret=interpret,
    )(qp, valid, qf, kf, vf, of, dof, mf, lf)

    # dk/dv grid: kv tiles OUTER, (group, q tile) inner — consecutive
    # inner steps revisit the same output block, so the accumulation
    # (incl. the GQA sum over groups) stays in VMEM scratch
    dkdv_specs = [
        pl.BlockSpec((1, bq), lambda b_, kh_, kv_, g_, qi: (b_, qi)),
        pl.BlockSpec((1, bkv), lambda b_, kh_, kv_, g_, qi: (b_, kv_)),
        pl.BlockSpec((1, bq, 1, 1, hd),
                     lambda b_, kh_, kv_, g_, qi: (b_, qi, kh_, g_, 0)),
        pl.BlockSpec((1, bkv, 1, hd),
                     lambda b_, kh_, kv_, g_, qi: (b_, kv_, kh_, 0)),
        pl.BlockSpec((1, bkv, 1, hv),
                     lambda b_, kh_, kv_, g_, qi: (b_, kv_, kh_, 0)),
        pl.BlockSpec((1, bq, 1, 1, hv),                    # o
                     lambda b_, kh_, kv_, g_, qi: (b_, qi, kh_, g_, 0)),
        pl.BlockSpec((1, bq, 1, 1, hv),                    # do
                     lambda b_, kh_, kv_, g_, qi: (b_, qi, kh_, g_, 0)),
    ] + [pl.BlockSpec((1, 1, 1, bq),
                      lambda b_, kh_, kv_, g_, qi: (b_, kh_, g_, qi))] * 2
    dk, dv = pl.pallas_call(
        functools.partial(_dkdv_body, **body),
        grid=(b, kh, t_p // bkv, g, s_p // bq),
        in_specs=dkdv_specs,
        out_specs=[
            pl.BlockSpec((1, bkv, 1, hd),
                         lambda b_, kh_, kv_, g_, qi: (b_, kv_, kh_, 0)),
            pl.BlockSpec((1, bkv, 1, hv),
                         lambda b_, kh_, kv_, g_, qi: (b_, kv_, kh_, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, t_p, kh, hd), jnp.float32),
                   jax.ShapeDtypeStruct((b, t_p, kh, hv), jnp.float32)],
        scratch_shapes=[
            pltpu.VMEM((bkv, tiling.scratch_lanes(hd)), jnp.float32),
            pltpu.VMEM((bkv, tiling.scratch_lanes(hv)), jnp.float32)],
        interpret=interpret,
    )(qp, valid, qf, kf, vf, of, dof, mf, lf)

    return (tiling.unpad(dq, 1, s_q), tiling.unpad(dk, 1, t),
            tiling.unpad(dv, 1, t))
