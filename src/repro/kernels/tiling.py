"""One tiling policy for every Pallas kernel in this package.

Replaces the three divergent per-kernel heuristics the kernels used to
carry (``_row_block`` in dualmode_softmax, ``_tile2d`` there too, ``_pick``
in fused_ffn), each of which searched for an exact divisor of the problem
shape and so degraded to 1-wide blocks on primes / odd sizes.  The policy
here never shrinks a block to fit a remainder: callers PAD the operand up
to a block multiple with :func:`pad_dim` and slice the result back with
:func:`unpad` — blocks stay VPU/MXU aligned for any input shape.

Constants follow the TPU layout rules (pallas guide §Tiling):
lane width 128, f32 sublane 8, ~16 MiB VMEM per core of which we budget
~2 MiB per operand tile.
"""
from __future__ import annotations

import jax.numpy as jnp

LANE = 128            # VPU lane width / MXU edge: last-dim block multiple
SUBLANE = 8           # f32 sublane: second-to-last-dim block multiple
VMEM_TILE_BUDGET = 2 * 1024 * 1024   # bytes per operand tile
VMEM_CORE_BUDGET = 16 * 1024 * 1024  # whole-kernel VMEM per TensorCore:
#   every pallas_call's resident set — double-buffered in/out tiles plus
#   scratch — must fit this; repro.analysis.vmem audits each kernel's
#   declared ``vmem_plan()`` against it over the canonical shape grid


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(n: int, multiple: int) -> int:
    return cdiv(n, multiple) * multiple


def fit_block(n: int, multiple: int, cap: int) -> int:
    """Largest aligned block that divides the minimally padded extent.

    Pads ``n`` only up to the next ``multiple`` (the hardware alignment),
    then picks the largest block <= cap that is a multiple of ``multiple``
    AND divides that padded extent — so block choice never inflates the
    padding beyond alignment (513 cols -> 640 with 128-wide blocks, not
    1024 with a blind 512 block)."""
    padded = round_up(n, multiple)
    cap = max(min(cap - cap % multiple, padded), multiple)
    for b in range(cap, 0, -multiple):
        if padded % b == 0:
            return b
    return multiple


def scratch_lanes(n: int) -> int:
    """Lane extent for a VMEM scratch whose logical minor dim is ``n``.

    Head dims off the 128 lane grid (MLA hv=72 style) must not shrink the
    scratch tile below the hardware lane width — round up and let the
    kernel body address the live ``[:, :n]`` slice.
    """
    return round_up(n, LANE)


def pad_dim(x, axis: int, multiple: int, value=0.0):
    """Pad ``x`` along ``axis`` up to a multiple; returns (padded, pad)."""
    pad = (-x.shape[axis]) % multiple
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths, constant_values=value)
    return x, pad


def unpad(y, axis: int, n: int):
    """Slice ``y`` back to length ``n`` along ``axis``."""
    if y.shape[axis] == n:
        return y
    idx = [slice(None)] * y.ndim
    idx[axis] = slice(0, n)
    return y[tuple(idx)]


def row_block(n_rows: int, n_cols: int, itemsize: int = 4) -> int:
    """Rows per block for whole-row kernels (row reductions need full rows).

    The row length is fixed at ``n_cols`` (pad it to a LANE multiple first);
    rows per block fill the VMEM tile budget, SUBLANE-aligned.  Callers pad
    the row count to a multiple of the returned block.
    """
    rows = max(VMEM_TILE_BUDGET // (max(n_cols, 1) * itemsize), SUBLANE)
    return fit_block(n_rows, SUBLANE, rows)


def tile2d(m: int, n: int, itemsize: int = 4) -> tuple[int, int]:
    """(bm, bn) for elementwise 2D kernels: LANE-wide, budget-bounded."""
    bn = fit_block(n, LANE, 512)
    bm = fit_block(m, SUBLANE,
                   max(VMEM_TILE_BUDGET // (bn * itemsize), SUBLANE))
    return bm, bn


def norm_rows(n_rows: int, n_cols: int, n_streams: int = 4,
              itemsize: int = 4) -> int:
    """Rows per block for the fused norm-seam kernels (fused_norm.py).

    Like :func:`row_block` these are whole-row kernels (the moments need
    the full feature dim), but the residual-norm epilogue keeps FOUR
    (bm, d) streams resident at once — x, the residual, and both outputs
    — so the per-stream budget is halved to keep the double-buffered
    resident set inside VMEM_CORE_BUDGET.
    """
    per_row = max(n_cols, 1) * itemsize * max(n_streams, 1)
    rows = max(2 * VMEM_TILE_BUDGET // per_row, SUBLANE)
    return fit_block(n_rows, SUBLANE, rows)


def matmul_blocks(m: int, f: int, want_m: int = 128,
                  want_f: int = 512) -> tuple[int, int]:
    """(bm, bf) output-tile shape for matmul-epilogue kernels.

    MXU-aligned (multiples of SUBLANE/LANE); blocks divide the minimally
    padded extent instead of forcing a pad up to the wanted block size.
    """
    return fit_block(m, SUBLANE, want_m), fit_block(f, LANE, want_f)


#   split-KV decode ("flash decoding") policy: decode runs s_q=1, so the
#   only parallelism left is over the KEYS — the cache is carved into
#   `num_splits` independent sweeps whose (m, l, o·l) partials merge via
#   datapath.online_softmax_merge_n.  Splitting only pays once each split
#   still streams a meaningful stretch of cache, and more splits than
#   cores just queue.
DECODE_FLASH_MIN_KV = 1024   # below this the s_q=1 'auto' pick stays naive
DECODE_SPLIT_KEYS = 2048     # min keys per split before another split pays
DECODE_MAX_SPLITS = 8        # partial-merge fan-in cap


# TPU generations that expose one TensorCore per chip (no megacore) —
# the device_kind fallback when the runtime doesn't report ``num_cores``.
_SINGLE_CORE_TPU_KINDS = ("lite", "v5e", "v6e")

_CORE_COUNT_CACHE: dict[tuple[str, str], int] = {}


def device_core_count() -> int:
    """Cores on the primary device — the parallelism the split-KV decode
    grid is trying to fill.

    Derived from the actual JAX backend, not the host: on TPU the
    per-chip TensorCore count (``num_cores`` where the runtime exposes
    it, else inferred from the device kind — single-core for the
    inference generations, megacore pair otherwise); on CPU/interpret
    backends a fixed DECODE_MAX_SPLITS rather than ``os.cpu_count()``,
    which over-split on TPU hosts (decode splits sized from a 96-way
    host) and under-split in throttled CI containers.  The lookup is
    cached per (platform, device_kind) — it runs inside the decode-step
    build path."""
    import jax
    try:
        dev = jax.devices()[0]
        key = (dev.platform, str(getattr(dev, "device_kind", "")))
    except Exception:       # pragma: no cover - device probing best-effort
        return DECODE_MAX_SPLITS
    if key not in _CORE_COUNT_CACHE:
        _CORE_COUNT_CACHE[key] = _probe_core_count(dev, key)
    return _CORE_COUNT_CACHE[key]


def _probe_core_count(dev, key: tuple[str, str]) -> int:
    platform, kind = key
    if platform == "tpu":
        n = getattr(dev, "num_cores", None)
        if n:
            return int(n)
        kind_l = kind.lower()
        return 1 if any(s in kind_l for s in _SINGLE_CORE_TPU_KINDS) else 2
    # CPU / GPU-interpret: the decode grid is emulated; a fixed cap keeps
    # split counts deterministic across hosts instead of tracking
    # whatever cpu_count the CI container happens to advertise.
    return DECODE_MAX_SPLITS


def decode_splits(t_kv: int, max_splits: int | None = None) -> int:
    """Split count for the s_q=1 split-KV decode kernel.

    Sized from the cache length (one split per DECODE_SPLIT_KEYS keys)
    and capped by the core count / DECODE_MAX_SPLITS; degenerates to 1
    split — plain blocked streaming — at short caches.
    """
    if max_splits is None:
        max_splits = min(DECODE_MAX_SPLITS, device_core_count())
    return int(max(1, min(max_splits, t_kv // DECODE_SPLIT_KEYS)))


def decode_kv_block(t_kv: int, num_splits: int) -> int:
    """KV tile width for one decode split: LANE-aligned, <= 512 keys, and
    dividing the minimally padded per-split extent."""
    return fit_block(cdiv(t_kv, max(num_splits, 1)), LANE, 512)


#   paged-KV policy: the serve engine's block pool carves the cache into
#   fixed-size blocks addressed through per-request block tables.  The
#   block size is the paged decode kernel's KV tile width — one grid step
#   gathers exactly one block via the scalar-prefetched table — so it
#   must be SUBLANE-aligned (it lands on the second-to-last cache axis)
#   and small enough that short prompts don't strand most of a block.
PAGED_MIN_BLOCK = SUBLANE     # floor: sublane alignment of the seq axis
PAGED_MAX_BLOCK = LANE        # cap: one lane-width tile per grid step


def paged_block_size(max_seq: int) -> int:
    """Tokens per paged-KV block for an engine bounded by ``max_seq``.

    Targets ~16 blocks per maximal sequence (enough table entries for
    prefix sharing to find full-block boundaries, few enough that the
    scalar-prefetch table stays tiny), clamped to the hardware alignment
    window [SUBLANE, LANE]."""
    want = round_up(cdiv(max_seq, 16), SUBLANE)
    return int(max(PAGED_MIN_BLOCK, min(PAGED_MAX_BLOCK, want)))


def attention_blocks(s_q: int, t_kv: int) -> tuple[int, int]:
    """(bq, bkv) for blocked attention: q rows x kv keys per grid step.

    Scores tile is (bq, bkv) f32; 128x512 = 256 KiB, well inside budget,
    with kv LANE-aligned (it is the score tile's minor dim).
    """
    return fit_block(s_q, SUBLANE, 128), fit_block(t_kv, LANE, 512)


def pad_attention_operands(q, q_pos, k, v, kv_valid, bq: int, bkv: int):
    """Pad the five blocked-attention operands up to the (bq, bkv) grid.

    One definition for every flash kernel flavor: q/q_pos pad along the
    query axis, k/v/kv_valid along the kv axis (validity pads with 0 so
    padded keys are invalid).  Returns the padded operands.
    """
    qf, _ = pad_dim(q, 1, bq)
    qp, _ = pad_dim(q_pos.astype(jnp.int32), 1, bq)
    kf, _ = pad_dim(k, 1, bkv)
    vf, _ = pad_dim(v, 1, bkv)
    valid, _ = pad_dim(kv_valid.astype(jnp.int32), 1, bkv, value=0)
    return qf, qp, kf, vf, valid
