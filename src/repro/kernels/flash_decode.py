"""Split-KV Pallas flash-decode kernel — the s_q=1 serving fast path.

Decode is the shape the blocked flash kernels are worst at: one query row
per head means the q-tile grid axis degenerates and the whole KV cache is
streamed by a single sequential sweep.  "Flash decoding" recovers
parallelism from the only dimension left — the KEYS: the cache is carved
into ``num_splits`` independent grid cells, each runs the standard
blocked online-softmax sweep (the same
:func:`repro.kernels.datapath.online_softmax_update` step every other
flash flavor runs) into a self-contained partial state ``(m, l, o·l)``,
and the partials fold with
:func:`repro.kernels.datapath.online_softmax_merge_n` — the vectorized
n-way form of the partial-merge monoid the ring uses, so the merged words
are pinned against ``models/flash.flash_attention_merged`` in tests.

Two decode-specific specializations on top of the generic kernel:

  * The G query groups of a KV head become the score-tile ROWS (the
    single query row broadcast over groups), so GQA decode still feeds
    the MXU a (G, block_kv) tile instead of a 1-row sliver.
  * Ragged continuous batching: each batch row carries its own cache
    depth via ``q_pos`` (the serving engine's per-slot ``pos`` vector).
    Causal KV tiles that start beyond a row's position are skipped with
    ``pl.when`` — a slot at depth 500 in a 64k bucket does ~1 tile of
    work per split, not the longest slot's full bucket.  Skipped tiles
    drop only the exp(MASK_VALUE) ~ 1e-13 relative mass of fully-masked
    keys (the same approximation ring attention's hop skip makes).

Shapes match every other flash flavor, with S pinned to 1:

    q (B, 1, K, G, h)   k (B, T, K, h)   v (B, T, K, hv) -> (B, 1, K, G, hv)

Masking reuses :func:`flash_attention.masked_score_block` — user-invalid
keys take ``datapath.MASK_VALUE``, tiling phantoms take ``-inf`` — so
decode can never disagree with the other implementations on which keys
are "off".  Forward-only: decode never differentiates.  Runs on CPU with
``interpret=True`` (the default off-TPU).

DUAL-MODE decode (``softmax_impl='dualmode'``): the same split-KV grid
runs the snapped-max INT recurrence instead — score words via
``flash_attention_int.int_score_words``, per-tile state update via
``flash_attention_int.snap_tile_update``, and the per-split partial is
the int monoid state ``(m snapped, S buckets, acc)`` folded host-side by
:func:`repro.core.softmax_unit.online_merge_n_int` (the int twin of
``online_softmax_merge_n``).  The causal tile skip carries over: for the
int unit a skipped tile's keys sit >= 16 octaves below any live max, so
they contribute zero words to the normalizer l; only their ~2**-40 f32
numerator mass is dropped (the same order of approximation as the float
path's exp(MASK_VALUE) drop).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import softmax_unit as unit

from . import datapath as dp
from . import dispatch, tiling
from .flash_attention import masked_score_block
from .flash_attention_int import int_score_words, snap_tile_update


def _decode_body(qpos_ref, valid_ref, q_ref, k_ref, v_ref, om_ref, ol_ref,
                 oacc_ref, m_ref, l_ref, acc_ref, *, block_kv: int,
                 inner: int, causal: bool, t_kv: int):
    """One (batch, kv-head, split, kv-tile) grid cell.

    The kv-tile axis is innermost, so the (m, l, acc) VMEM scratch streams
    one split's tiles sequentially; at the split's last tile the UNNORMALIZED
    partial (m, l, acc = o·l) is written out for the host-side n-way fold.
    """
    sp = pl.program_id(2)
    kj = pl.program_id(3)
    g = q_ref.shape[-2]
    hv = oacc_ref.shape[-1]
    kv_tile = sp * inner + kj

    @pl.when(kj == 0)
    def _():
        # empty-split sentinel (MASK_VALUE, 0, 0): splits whose every tile
        # is skipped/phantom emit the merge identity, not garbage
        m_ref[...] = jnp.full_like(m_ref, dp.MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def update():
        q = q_ref[0, 0, 0, :, :].astype(jnp.float32)       # (G, h) pre-scaled
        kb = k_ref[0, :, 0, :].astype(jnp.float32)         # (bkv, h)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)         # (bkv, hv)
        s, _ = masked_score_block(q, kb, qpos_ref, valid_ref, kv_tile,
                                  block_kv=block_kv, causal=causal,
                                  t_kv=t_kv)
        m, l = m_ref[:g, :1], l_ref[:g, :1]                # (G, 1)
        m_new, l_new, p, corr = dp.online_softmax_update(m, l, s)
        acc_ref[:g, :hv] = acc_ref[:g, :hv] * corr + jnp.dot(
            p, vb, preferred_element_type=jnp.float32)
        m_ref[:g, :1] = m_new
        l_ref[:g, :1] = l_new

    if causal:
        # ragged fast path: this row attends to nothing at or beyond its
        # own position, so tiles starting past q_pos are pure MASK_VALUE /
        # phantom mass — skip them entirely (per BATCH row: b is a grid dim)
        pl.when(kv_tile * block_kv <= qpos_ref[0, 0])(update)
    else:
        update()

    @pl.when(kj == inner - 1)
    def _():
        om_ref[0, 0, 0, :] = m_ref[:g, 0]
        ol_ref[0, 0, 0, :] = l_ref[:g, 0]
        oacc_ref[0, 0, 0, :, :] = acc_ref[:g, :hv]


@functools.partial(jax.jit, static_argnames=(
    "causal", "num_splits", "block_kv", "interpret"))
def _flash_decode_jit(q, k, v, q_pos, kv_valid, scale, *, causal: bool,
                      num_splits: int, block_kv: int, interpret: bool):
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    # fold the traced scale into q (one compile across scales, the same
    # contract as flash_attention_pallas)
    qf = q.astype(jnp.float32) * scale

    bkv = block_kv
    inner = tiling.cdiv(tiling.cdiv(t, bkv), num_splits)
    t_pad = num_splits * inner * bkv
    kf, _ = tiling.pad_dim(k, 1, t_pad)
    vf, _ = tiling.pad_dim(v, 1, t_pad)
    valid, _ = tiling.pad_dim(kv_valid.astype(jnp.int32), 1, t_pad, value=0)
    qp = q_pos.astype(jnp.int32)

    in_specs = [
        pl.BlockSpec((1, 1), lambda b_, h_, sp, kj: (b_, 0)),
        pl.BlockSpec((1, bkv),
                     lambda b_, h_, sp, kj: (b_, sp * inner + kj)),
        pl.BlockSpec((1, 1, 1, g, hd), lambda b_, h_, sp, kj: (b_, 0, h_,
                                                               0, 0)),
        pl.BlockSpec((1, bkv, 1, hd),
                     lambda b_, h_, sp, kj: (b_, sp * inner + kj, h_, 0)),
        pl.BlockSpec((1, bkv, 1, hv),
                     lambda b_, h_, sp, kj: (b_, sp * inner + kj, h_, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, 1, g), lambda b_, h_, sp, kj: (b_, sp, h_, 0)),
        pl.BlockSpec((1, 1, 1, g), lambda b_, h_, sp, kj: (b_, sp, h_, 0)),
        pl.BlockSpec((1, 1, 1, g, hv),
                     lambda b_, h_, sp, kj: (b_, sp, h_, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, num_splits, kh, g), jnp.float32),
        jax.ShapeDtypeStruct((b, num_splits, kh, g), jnp.float32),
        jax.ShapeDtypeStruct((b, num_splits, kh, g, hv), jnp.float32),
    ]
    rows = tiling.round_up(g, tiling.SUBLANE)
    part_m, part_l, part_acc = pl.pallas_call(
        functools.partial(_decode_body, block_kv=bkv, inner=inner,
                          causal=causal, t_kv=t),
        grid=(b, kh, num_splits, inner),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((rows, tiling.scratch_lanes(1)), jnp.float32),  # m
            pltpu.VMEM((rows, tiling.scratch_lanes(1)), jnp.float32),  # l
            pltpu.VMEM((rows, tiling.scratch_lanes(hv)), jnp.float32),
        ],
        interpret=interpret,
    )(qp, valid, qf, kf, vf)

    # the tree fold: one vectorized n-way merge over the split axis — the
    # same monoid the ring folds pairwise, so the merged words satisfy the
    # partial-merge contract (pinned vs flash_attention_merged in tests)
    _, l, acc = dp.online_softmax_merge_n(
        part_m[..., None], part_l[..., None], part_acc, axis=1)
    return dp.online_softmax_finish(l, acc).astype(v.dtype)  # (B,1,K,G,hv)


def _decode_body_int(qpos_ref, valid_ref, q_ref, k_ref, v_ref, om_ref,
                     os_ref, oacc_ref, m_ref, s_ref, acc_ref, *,
                     block_kv: int, inner: int, causal: bool, t_kv: int,
                     guard_shift: int):
    """Dual-mode twin of ``_decode_body``: same grid, same tile skip, but
    the per-split partial is the snapped int monoid state (m, S, acc)."""
    sp = pl.program_id(2)
    kj = pl.program_id(3)
    g = q_ref.shape[-2]
    hv = oacc_ref.shape[-1]
    nb = unit.N_SNAP_BUCKETS
    kv_tile = sp * inner + kj

    @pl.when(kj == 0)
    def _():
        # empty-split sentinel (SNAP_MIN, 0, 0) — the int merge identity
        m_ref[...] = jnp.full_like(m_ref, unit.SNAP_MIN)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def update():
        q = q_ref[0, 0, 0, :, :].astype(jnp.float32)       # (G, h) pre-scaled
        kb = k_ref[0, :, 0, :].astype(jnp.float32)         # (bkv, h)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)         # (bkv, hv)
        sq = int_score_words(q, kb, qpos_ref, valid_ref, kv_tile,
                             block_kv=block_kv, causal=causal, t_kv=t_kv)
        m_new, S_new, acc_new = snap_tile_update(
            m_ref[:g, :1], s_ref[:g, :nb], acc_ref[:g, :hv], sq, vb,
            guard_shift)
        m_ref[:g, :1] = m_new
        s_ref[:g, :nb] = S_new
        acc_ref[:g, :hv] = acc_new

    if causal:
        pl.when(kv_tile * block_kv <= qpos_ref[0, 0])(update)
    else:
        update()

    @pl.when(kj == inner - 1)
    def _():
        om_ref[0, 0, 0, :] = m_ref[:g, 0]
        os_ref[0, 0, 0, :, :] = s_ref[:g, :nb]
        oacc_ref[0, 0, 0, :, :] = acc_ref[:g, :hv]


def _finish_decode_int(part_m, part_S, part_acc, out_dtype):
    """Host-side split fold + normalize for dual-mode decode: the int
    n-way merge (axis 1 = splits, keepdims makes it the s_q=1 dim), then
    one f32 division by the bucket-telescoped l word."""
    _, S, acc = unit.online_merge_n_int(
        part_m[..., None], part_S, part_acc, axis=1)
    l = unit.online_finish_int(S)                          # (B, 1, K, G)
    return (acc / l[..., None].astype(jnp.float32)).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "num_splits", "block_kv", "interpret", "guard_shift"))
def _flash_decode_int_jit(q, k, v, q_pos, kv_valid, scale, *, causal: bool,
                          num_splits: int, block_kv: int, interpret: bool,
                          guard_shift: int):
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    nb = unit.N_SNAP_BUCKETS
    qf = q.astype(jnp.float32) * scale

    bkv = block_kv
    inner = tiling.cdiv(tiling.cdiv(t, bkv), num_splits)
    t_pad = num_splits * inner * bkv
    kf, _ = tiling.pad_dim(k, 1, t_pad)
    vf, _ = tiling.pad_dim(v, 1, t_pad)
    valid, _ = tiling.pad_dim(kv_valid.astype(jnp.int32), 1, t_pad, value=0)
    qp = q_pos.astype(jnp.int32)

    in_specs = [
        pl.BlockSpec((1, 1), lambda b_, h_, sp, kj: (b_, 0)),
        pl.BlockSpec((1, bkv),
                     lambda b_, h_, sp, kj: (b_, sp * inner + kj)),
        pl.BlockSpec((1, 1, 1, g, hd), lambda b_, h_, sp, kj: (b_, 0, h_,
                                                               0, 0)),
        pl.BlockSpec((1, bkv, 1, hd),
                     lambda b_, h_, sp, kj: (b_, sp * inner + kj, h_, 0)),
        pl.BlockSpec((1, bkv, 1, hv),
                     lambda b_, h_, sp, kj: (b_, sp * inner + kj, h_, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, 1, g), lambda b_, h_, sp, kj: (b_, sp, h_, 0)),
        pl.BlockSpec((1, 1, 1, g, nb),
                     lambda b_, h_, sp, kj: (b_, sp, h_, 0, 0)),
        pl.BlockSpec((1, 1, 1, g, hv),
                     lambda b_, h_, sp, kj: (b_, sp, h_, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, num_splits, kh, g), jnp.int32),
        jax.ShapeDtypeStruct((b, num_splits, kh, g, nb), jnp.int32),
        jax.ShapeDtypeStruct((b, num_splits, kh, g, hv), jnp.float32),
    ]
    rows = tiling.round_up(g, tiling.SUBLANE)
    part_m, part_S, part_acc = pl.pallas_call(
        functools.partial(_decode_body_int, block_kv=bkv, inner=inner,
                          causal=causal, t_kv=t, guard_shift=guard_shift),
        grid=(b, kh, num_splits, inner),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((rows, tiling.scratch_lanes(1)), jnp.int32),   # m
            pltpu.VMEM((rows, tiling.scratch_lanes(nb)), jnp.int32),  # S
            pltpu.VMEM((rows, tiling.scratch_lanes(hv)), jnp.float32),
        ],
        interpret=interpret,
    )(qp, valid, qf, kf, vf)
    return _finish_decode_int(part_m, part_S, part_acc, v.dtype)


def _paged_decode_body(tab_ref, *refs, **kw):
    """Block-table wrapper: the scalar-prefetched table ref arrives first
    and is consumed entirely by the BlockSpec index maps — the body
    proper is the SAME online-softmax sweep as contiguous decode (the
    physical gather happens in the pipeline, not the arithmetic)."""
    del tab_ref
    _decode_body(*refs, **kw)


@functools.partial(jax.jit, static_argnames=(
    "causal", "num_splits", "interpret"))
def _flash_decode_paged_jit(q, k_pool, v_pool, tables, q_pos, kv_valid,
                            scale, *, causal: bool, num_splits: int,
                            interpret: bool):
    b, s_q, kh, g, hd = q.shape
    bs = k_pool.shape[1]                 # block size == KV tile width
    hv = v_pool.shape[-1]
    nblk = tables.shape[1]
    t = nblk * bs                        # logical cache extent per row
    qf = q.astype(jnp.float32) * scale

    inner = tiling.cdiv(nblk, num_splits)
    # pad the table out to the grid (surplus tiles alias sentinel block 0
    # and are masked off as phantoms by the t_kv check / kv_valid pad)
    tab, _ = tiling.pad_dim(tables.astype(jnp.int32), 1,
                            num_splits * inner, value=0)
    valid, _ = tiling.pad_dim(kv_valid.astype(jnp.int32), 1,
                              num_splits * inner * bs, value=0)
    qp = q_pos.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, num_splits, inner),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h_, sp, kj, tab_: (b_, 0)),
            pl.BlockSpec((1, bs),
                         lambda b_, h_, sp, kj, tab_: (b_, sp * inner + kj)),
            pl.BlockSpec((1, 1, 1, g, hd),
                         lambda b_, h_, sp, kj, tab_: (b_, 0, h_, 0, 0)),
            # THE paged difference: the KV tile index routes through the
            # scalar-prefetched block table instead of a contiguous stride
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b_, h_, sp, kj, tab_:
                         (tab_[b_, sp * inner + kj], 0, h_, 0)),
            pl.BlockSpec((1, bs, 1, hv),
                         lambda b_, h_, sp, kj, tab_:
                         (tab_[b_, sp * inner + kj], 0, h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g),
                         lambda b_, h_, sp, kj, tab_: (b_, sp, h_, 0)),
            pl.BlockSpec((1, 1, 1, g),
                         lambda b_, h_, sp, kj, tab_: (b_, sp, h_, 0)),
            pl.BlockSpec((1, 1, 1, g, hv),
                         lambda b_, h_, sp, kj, tab_: (b_, sp, h_, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((tiling.round_up(g, tiling.SUBLANE),
                        tiling.scratch_lanes(1)), jnp.float32),   # m
            pltpu.VMEM((tiling.round_up(g, tiling.SUBLANE),
                        tiling.scratch_lanes(1)), jnp.float32),   # l
            pltpu.VMEM((tiling.round_up(g, tiling.SUBLANE),
                        tiling.scratch_lanes(hv)), jnp.float32),  # acc
        ],
    )
    part_m, part_l, part_acc = pl.pallas_call(
        functools.partial(_paged_decode_body, block_kv=bs, inner=inner,
                          causal=causal, t_kv=t),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, num_splits, kh, g), jnp.float32),
            jax.ShapeDtypeStruct((b, num_splits, kh, g), jnp.float32),
            jax.ShapeDtypeStruct((b, num_splits, kh, g, hv), jnp.float32),
        ],
        interpret=interpret,
    )(tab, qp, valid, qf, k_pool, v_pool)

    _, l, acc = dp.online_softmax_merge_n(
        part_m[..., None], part_l[..., None], part_acc, axis=1)
    return dp.online_softmax_finish(l, acc).astype(v_pool.dtype)


def _paged_decode_body_int(tab_ref, *refs, **kw):
    """Paged dual-mode: the table is again pure BlockSpec routing — the
    arithmetic is byte-for-byte the contiguous int decode body."""
    del tab_ref
    _decode_body_int(*refs, **kw)


@functools.partial(jax.jit, static_argnames=(
    "causal", "num_splits", "interpret", "guard_shift"))
def _flash_decode_paged_int_jit(q, k_pool, v_pool, tables, q_pos, kv_valid,
                                scale, *, causal: bool, num_splits: int,
                                interpret: bool, guard_shift: int):
    b, s_q, kh, g, hd = q.shape
    bs = k_pool.shape[1]
    hv = v_pool.shape[-1]
    nblk = tables.shape[1]
    t = nblk * bs
    nb = unit.N_SNAP_BUCKETS
    qf = q.astype(jnp.float32) * scale

    inner = tiling.cdiv(nblk, num_splits)
    tab, _ = tiling.pad_dim(tables.astype(jnp.int32), 1,
                            num_splits * inner, value=0)
    valid, _ = tiling.pad_dim(kv_valid.astype(jnp.int32), 1,
                              num_splits * inner * bs, value=0)
    qp = q_pos.astype(jnp.int32)

    rows = tiling.round_up(g, tiling.SUBLANE)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, num_splits, inner),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h_, sp, kj, tab_: (b_, 0)),
            pl.BlockSpec((1, bs),
                         lambda b_, h_, sp, kj, tab_: (b_, sp * inner + kj)),
            pl.BlockSpec((1, 1, 1, g, hd),
                         lambda b_, h_, sp, kj, tab_: (b_, 0, h_, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b_, h_, sp, kj, tab_:
                         (tab_[b_, sp * inner + kj], 0, h_, 0)),
            pl.BlockSpec((1, bs, 1, hv),
                         lambda b_, h_, sp, kj, tab_:
                         (tab_[b_, sp * inner + kj], 0, h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g),
                         lambda b_, h_, sp, kj, tab_: (b_, sp, h_, 0)),
            pl.BlockSpec((1, 1, 1, g, nb),
                         lambda b_, h_, sp, kj, tab_: (b_, sp, h_, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, hv),
                         lambda b_, h_, sp, kj, tab_: (b_, sp, h_, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, tiling.scratch_lanes(1)), jnp.int32),   # m
            pltpu.VMEM((rows, tiling.scratch_lanes(nb)), jnp.int32),  # S
            pltpu.VMEM((rows, tiling.scratch_lanes(hv)), jnp.float32),
        ],
    )
    part_m, part_S, part_acc = pl.pallas_call(
        functools.partial(_paged_decode_body_int, block_kv=bs, inner=inner,
                          causal=causal, t_kv=t, guard_shift=guard_shift),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, num_splits, kh, g), jnp.int32),
            jax.ShapeDtypeStruct((b, num_splits, kh, g, nb), jnp.int32),
            jax.ShapeDtypeStruct((b, num_splits, kh, g, hv), jnp.float32),
        ],
        interpret=interpret,
    )(tab, qp, valid, qf, k_pool, v_pool)
    return _finish_decode_int(part_m, part_S, part_acc, v_pool.dtype)


def flash_decode_paged(q, k_pool, v_pool, *, block_tables, q_pos, kv_valid,
                       causal: bool = True, scale: float | None = None,
                       num_splits: int | None = None,
                       interpret: bool | None = None,
                       softmax_impl: str = "float"):
    """Block-table flash decode: KV gathered through a paged pool.

    ``k_pool``/``v_pool`` are (N_blocks, block_size, K, h|hv) pools and
    ``block_tables`` is (B, max_blocks) int32 mapping each row's logical
    block index to its pool block (sentinel 0 past the row's length; the
    sentinel's mass is masked to exp(MASK_VALUE) by ``kv_valid`` exactly
    like any dense invalid key).  The KV tile width IS the block size, one
    table entry per grid step via scalar prefetch, and everything after
    the gather — masking, the per-row causal tile skip, the
    ``online_softmax_merge_n`` fold — is byte-for-byte the contiguous
    kernel's code path, so the split/parity contracts carry over.

    ``softmax_impl='dualmode'`` runs the snapped-max INT recurrence on the
    same paged grid (see module docstring).
    """
    if q.shape[1] != 1:
        raise ValueError(
            f"flash_decode is the s_q=1 decode kernel; got s_q={q.shape[1]}"
            " — use 'flash'/'flash_pallas' for wide query tiles")
    nblk, bs = block_tables.shape[1], k_pool.shape[1]
    if kv_valid.shape[1] != nblk * bs:
        raise ValueError(
            f"kv_valid covers {kv_valid.shape[1]} keys but the table maps "
            f"{nblk} blocks x {bs} = {nblk * bs}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = (1.0 / q.shape[-1] ** 0.5) if scale is None else scale
    if num_splits is None:
        num_splits = min(tiling.decode_splits(nblk * bs), nblk)
    num_splits = max(1, min(num_splits, nblk))
    if softmax_impl == "dualmode":
        # guard from the LOGICAL cache extent, as the whole-row unit would
        guard_shift = max(0, (nblk * bs).bit_length() - 16)
        return _flash_decode_paged_int_jit(
            q, k_pool, v_pool, block_tables, q_pos, kv_valid,
            jnp.float32(scale), causal=causal, num_splits=num_splits,
            interpret=interpret, guard_shift=guard_shift)
    if softmax_impl != "float":
        raise ValueError(
            f"flash_decode_paged softmax_impl={softmax_impl!r}: expected "
            "'float' or 'dualmode'")
    return _flash_decode_paged_jit(q, k_pool, v_pool, block_tables, q_pos,
                                   kv_valid, jnp.float32(scale),
                                   causal=causal, num_splits=num_splits,
                                   interpret=interpret)


def flash_decode_pallas(q, k, v, *, q_pos, kv_valid, causal: bool = True,
                        scale: float | None = None,
                        num_splits: int | None = None,
                        block_kv: int | None = None,
                        interpret: bool | None = None,
                        softmax_impl: str = "float"):
    """Split-KV flash decode; see module docstring for shapes/masking.

    ``num_splits=None`` picks the :func:`repro.kernels.tiling.
    decode_splits` heuristic (cache length / core count, 1 at short
    caches).  The output is invariant to the split count — WHERE the
    cache is split only changes which partial each key lands in, and the
    merge is the associative monoid fold.  ``softmax_impl='dualmode'``
    swaps in the snapped-max INT recurrence (same grid, int partials,
    :func:`repro.core.softmax_unit.online_merge_n_int` fold).
    """
    if q.shape[1] != 1:
        raise ValueError(
            f"flash_decode is the s_q=1 decode kernel; got s_q={q.shape[1]}"
            " — use 'flash'/'flash_pallas' for wide query tiles")
    t = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = (1.0 / q.shape[-1] ** 0.5) if scale is None else scale
    if num_splits is None:
        num_splits = tiling.decode_splits(t)
    if block_kv is None:
        block_kv = tiling.decode_kv_block(t, num_splits)
    if softmax_impl == "dualmode":
        guard_shift = max(0, t.bit_length() - 16)
        return _flash_decode_int_jit(
            q, k, v, q_pos, kv_valid, jnp.float32(scale), causal=causal,
            num_splits=num_splits, block_kv=block_kv, interpret=interpret,
            guard_shift=guard_shift)
    if softmax_impl != "float":
        raise ValueError(
            f"flash_decode_pallas softmax_impl={softmax_impl!r}: expected "
            "'float' or 'dualmode'")
    return _flash_decode_jit(q, k, v, q_pos, kv_valid, jnp.float32(scale),
                             causal=causal, num_splits=num_splits,
                             block_kv=block_kv, interpret=interpret)


def vmem_plan(t_kv: int, hd: int, hv: int, g: int = 1):
    """Static VMEM residency of the four decode kernels (see
    ``flash_attention.vmem_plan`` for the contract).  The paged variants
    tile by the engine's block size instead of the split-KV block; the
    scalar-prefetched block table lives in SMEM, not VMEM, so it does
    not appear here."""
    num_splits = tiling.decode_splits(t_kv)
    bkv = tiling.decode_kv_block(t_kv, num_splits)
    bs = tiling.paged_block_size(t_kv)
    rows = tiling.round_up(g, tiling.SUBLANE)
    nb = unit.N_SNAP_BUCKETS

    def plan(block, int_mode):
        p = {
            "in:q_pos": ((1, 1), jnp.int32),
            "in:kv_valid": ((1, block), jnp.int32),
            "in:q": ((1, 1, 1, g, hd), jnp.float32),
            "in:k": ((1, block, 1, hd), jnp.float32),
            "in:v": ((1, block, 1, hv), jnp.float32),
            "out:part_m": ((1, 1, 1, g),
                           jnp.int32 if int_mode else jnp.float32),
            "out:part_acc": ((1, 1, 1, g, hv), jnp.float32),
            "scratch:acc": ((rows, tiling.scratch_lanes(hv)), jnp.float32),
        }
        if int_mode:
            p["out:part_s"] = ((1, 1, 1, g, nb), jnp.int32)
            p["scratch:m"] = ((rows, tiling.scratch_lanes(1)), jnp.int32)
            p["scratch:s"] = ((rows, tiling.scratch_lanes(nb)), jnp.int32)
        else:
            p["out:part_l"] = ((1, 1, 1, g), jnp.float32)
            p["scratch:m"] = ((rows, tiling.scratch_lanes(1)), jnp.float32)
            p["scratch:l"] = ((rows, tiling.scratch_lanes(1)), jnp.float32)
        return p

    return {
        "decode_float": plan(bkv, False),
        "decode_int": plan(bkv, True),
        "decode_paged_float": plan(bs, False),
        "decode_paged_int": plan(bs, True),
    }


def _attention_entry(q, k, v, *, q_pos, kv_valid, causal, scale,
                     softmax_impl="float", ring_axis=""):
    # both int contracts route to the snapped int recurrence — a snap
    # request must never silently fall back to the float path
    impl = ("dualmode" if softmax_impl in ("dualmode", "dualmode_snap")
            else "float")
    return flash_decode_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                               causal=causal, scale=scale,
                               softmax_impl=impl)


def _paged_attention_entry(q, k_pool, v_pool, *, block_tables, q_pos,
                           kv_valid, causal, scale, softmax_impl="float",
                           ring_axis=""):
    impl = ("dualmode" if softmax_impl in ("dualmode", "dualmode_snap")
            else "float")
    return flash_decode_paged(q, k_pool, v_pool, block_tables=block_tables,
                              q_pos=q_pos, kv_valid=kv_valid, causal=causal,
                              scale=scale, softmax_impl=impl)


dispatch.register_attention(
    "flash_decode", _attention_entry,
    modes=("float", "dualmode", "dualmode_snap"), grad=False,
    decode_only=True, mesh_safe=False,
    note="split-KV s_q=1 kernel; single-device (gathers sharded KV)")
dispatch.register_paged_attention("flash_decode", _paged_attention_entry)
