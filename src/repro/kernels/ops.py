"""Public jit'd ops over the dual-mode softmax kernels.

These are what the model code calls.  They
  * reshape arbitrary-rank inputs to the kernel's 2D layout,
  * pad rows/cols to kernel-friendly sizes when needed,
  * attach custom VJPs (quantized forward, float surrogate backward — the
    straight-through estimator, so the quantized unit is a trainable
    drop-in), and
  * fall back to the bit-exact jnp path on hosts where Pallas interpret
    would be too slow for full-model shapes (``use_kernel=False``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import softmax_unit as unit
from repro.core.activations import gelu_tanh, silu as silu_float
from . import dualmode_softmax as dk


def _as_2d(x):
    return x.reshape(-1, x.shape[-1]), x.shape


# ---------------- softmax (normal mode) ----------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def softmax(x, precision: str = "int", use_kernel: bool = True,
            interpret: bool = True):
    """Softmax over the last axis through the dual-mode unit."""
    return _softmax_fwd_impl(x, precision, use_kernel, interpret)


def _softmax_fwd_impl(x, precision, use_kernel, interpret):
    if not use_kernel:
        return unit.softmax_dualmode(x, axis=-1).astype(x.dtype)
    x2, shape = _as_2d(x)
    # non-LANE row lengths are padded inside the kernel with MASK_VALUE
    y = dk.softmax_pallas(x2, precision=precision, interpret=interpret)
    return y.reshape(shape)


def _softmax_fwd(x, precision, use_kernel, interpret):
    y = _softmax_fwd_impl(x, precision, use_kernel, interpret)
    return y, y


def _softmax_bwd(precision, use_kernel, interpret, y, g):
    # standard softmax VJP evaluated at the unit's own output
    dot = jnp.sum(g * y, axis=-1, keepdims=True)
    return (y * (g - dot),)


softmax.defvjp(_softmax_fwd, _softmax_bwd)


# ---------------- GELU / SiLU (GELU mode) ----------------

def _pair_act_fwd_impl(z, mode, precision, use_kernel, interpret):
    if not use_kernel:
        f = unit.gelu_dualmode if mode == "gelu" else unit.silu_dualmode
        return f(z).astype(z.dtype)
    z2, shape = _as_2d(z)
    y = dk.pair_act_pallas(z2, mode=mode, precision=precision,
                           interpret=interpret)
    return y.reshape(shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def gelu(z, precision: str = "int", use_kernel: bool = True,
         interpret: bool = True):
    """GELU through the unit's GELU mode (Eq. 8)."""
    return _pair_act_fwd_impl(z, "gelu", precision, use_kernel, interpret)


def _gelu_fwd(z, precision, use_kernel, interpret):
    return gelu(z, precision, use_kernel, interpret), z


def _gelu_bwd(precision, use_kernel, interpret, z, g):
    return (g * jax.grad(lambda t: gelu_tanh(t).sum())(z),)


gelu.defvjp(_gelu_fwd, _gelu_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def silu(z, precision: str = "int", use_kernel: bool = True,
         interpret: bool = True):
    """SiLU through the unit's GELU mode (exact identity, beyond-paper)."""
    return _pair_act_fwd_impl(z, "silu", precision, use_kernel, interpret)


def _silu_fwd(z, precision, use_kernel, interpret):
    return silu(z, precision, use_kernel, interpret), z


def _silu_bwd(precision, use_kernel, interpret, z, g):
    return (g * jax.grad(lambda t: silu_float(t).sum())(z),)


silu.defvjp(_silu_fwd, _silu_bwd)
