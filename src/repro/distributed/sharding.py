"""Sharding rules: every parameter / input / cache leaf -> PartitionSpec.

Axis conventions (DESIGN.md §5):
  'pod'   second-level data parallelism (multi-pod mesh only)
  'data'  data parallelism; FSDP shards params over it; SP shards long
          sequences over it when the batch is too small to split
  'model' tensor parallelism: attention heads, FFN hidden, vocab, and MoE
          experts (expert parallelism when n_experts divides |model|)

Rules are *path-based* over the raw pytrees that ``models/transformer.py``
produces — no module wrappers, so the same rules serve every architecture
(dense / MoE / MLA / mamba / rwkv / enc-dec / VLM).  Stacked-period params
(leading ``n_periods`` axis from the scan-over-periods stack) get a leading
dim that is None by default or 'data' under FSDP (ZeRO-3-style: each data
rank holds a slice of the layer stack, all-gathered by GSPMD per period).

Every axis is applied *guarded*: if the dim is not divisible by the mesh
axis size, the dim stays replicated (GSPMD would pad, but silent padding
wastes memory at 512 devices — explicit is better).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = dict[str, Any]

# 2D weights whose OUTPUT dim is model-sharded (column parallel)
_COL = {"wq", "wk", "wv", "wg", "up", "gate", "in_proj", "dt_proj",
        "wq_b", "wkv_b", "w_lora2"}
# 2D weights whose INPUT dim is model-sharded (row parallel)
_ROW = {"wo", "down", "out_proj", "x_proj"}
# replicated small projections (low-rank a-matrices, routers, ddlerp loras)
_REPL = {"wq_a", "wkv_a", "w_lora1", "dd_w1", "router", "wr", "q_norm",
         "kv_norm", "qn", "kn", "norm1", "norm2", "cross_norm", "final_norm",
         "norm", "cross_gate", "mu", "mu_k", "mu_r", "ln_g", "ln_b",
         "w_base", "pos"}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes of this mesh (('pod','data') or ('data',))."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis(mesh: Mesh, name: str, dim: int):
    """`name` if it shards `dim` evenly on this mesh, else None."""
    if name not in mesh.axis_names:
        return None
    if isinstance(name, tuple):
        size = int(np.prod([mesh.shape[a] for a in name]))
    else:
        size = mesh.shape[name]
    return name if dim % size == 0 else None


def _dp_axis(mesh: Mesh, dim: int):
    """Full data-parallel axis group if it divides `dim`, else fallbacks."""
    dp = data_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in dp]))
    if dp and dim % size == 0:
        return dp if len(dp) > 1 else dp[0]
    if "data" in dp and dim % mesh.shape["data"] == 0:
        return "data"
    return None


def _path_names(path) -> list[str]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"[{e.idx}]")
        elif hasattr(e, "name"):
            names.append(str(e.name))
    return names


def _base_spec(names: list[str], shape: tuple[int, ...], mesh: Mesh,
               n_extra: int) -> P:
    """Spec for the *logical* (unstacked) weight dims shape[n_extra:]."""
    lname = names[-1]
    dims = shape[n_extra:]
    mdl = lambda d: _axis(mesh, "model", d)
    in_ffn = "ffn" in names

    # --- embeddings -------------------------------------------------------
    if lname == "embed":                       # (V, d) vocab-TP
        return P(mdl(dims[0]), None)
    if lname in _REPL:
        return P(*([None] * len(dims)))

    # --- MoE stacked expert weights (E, d_in, d_out) -----------------------
    if in_ffn and lname in ("gate", "up", "down") and len(dims) == 3:
        n_elems = dims[0] * dims[1] * dims[2]
        # small stacks (granite: 38M elems) REPLICATE and the dispatch
        # runs batch-DP over the whole mesh (models/moe.py) — TP'ing a
        # 512-wide expert ffn into 32-wide shards cost a 3.2 GB
        # all-reduce per layer, and GSPMD's sharded-scatter fallback on
        # EP buffers cost 1.27 TB/step (94% of granite's collectives)
        if n_elems <= (1 << 27):
            return P(None, None, None)
        e = _axis(mesh, "model", dims[0])
        if e is not None:                      # expert parallelism
            return P(e, None, None)
        return P(None, None, None)             # uneven EP: replicate

    # --- rwkv channel-mix: wk is (d, ff) col, wv is (ff, d) row ------------
    if in_ffn and lname == "wk" and len(dims) == 2:
        return P(None, mdl(dims[1]))
    if in_ffn and lname == "wv" and len(dims) == 2:
        return P(mdl(dims[0]), None)

    if lname in _COL and len(dims) == 2:
        return P(None, mdl(dims[1]))
    if lname in _ROW and len(dims) == 2:
        return P(mdl(dims[0]), None)

    # 1D biases / gains attached to a model-sharded output (conv_b, D, b of
    # col-parallel linears); `b` of row-parallel outputs stays replicated.
    if lname == "b" and len(dims) == 1:
        parent = names[-2] if len(names) >= 2 else ""
        if parent in _COL:
            return P(mdl(dims[0]))
        return P(None)
    if lname == "w" and len(dims) == 2:        # nested {'w':...} linears
        parent = names[-2] if len(names) >= 2 else ""
        return _base_spec(names[:-1], shape, mesh, n_extra)
    if lname in ("conv_b", "D", "dt_b") and len(dims) == 1:
        return P(mdl(dims[0]))
    if lname == "conv_w":                      # (d_conv, d_inner)
        return P(None, mdl(dims[1]))
    if lname == "A_log":                       # (d_inner, d_state)
        return P(mdl(dims[0]), None)
    if lname == "u":                           # rwkv (H, hd)
        return P(mdl(dims[0]), None)
    if lname == "dd_w2":                       # (5, r, d)
        return P(None, None, mdl(dims[2]))
    if lname == "lm_head":
        return P(None, mdl(dims[1]))
    # default: replicate
    return P(*([None] * len(dims)))


def _fsdp_wrap(spec: P, shape, mesh: Mesh, stacked: bool) -> P:
    """ZeRO-style extra sharding over 'data' on the largest free dim."""
    dsize = mesh.shape["data"]
    parts = list(spec)
    # prefer the stacked-period axis, then the largest unsharded dim
    order = sorted(range(len(parts)),
                   key=lambda i: (-int(i == 0 and stacked), -shape[i]))
    for i in order:
        if parts[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize:
            parts[i] = "data"
            break
    return P(*parts)


def param_pspecs(params: Params, mesh: Mesh, fsdp: bool = False,
                 profile: str = "tp") -> Params:
    """PartitionSpec tree matching a param tree from ``init_lm`` (or its
    eval_shape).  Works on ShapeDtypeStructs — no device data touched.

    profile='tp'  tensor/expert parallelism over 'model' (+FSDP option)
    profile='dp'  small-model profile: weights REPLICATED (FSDP still
                  shards them over 'data' if requested) — at <2B params
                  TP shards are too thin and collectives dominate."""
    def rule(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        # stacked-period / stacked-encoder params carry one leading dim
        stacked = ("periods" in names or "blocks" in names)
        n_extra = 1 if stacked else 0
        if len(shape) == 0:
            return P()
        if profile == "dp":
            spec = P(*([None] * len(shape)))
        else:
            base = _base_spec(names, shape, mesh, n_extra)
            spec = P(*([None] * n_extra + list(base)))
            # pad/trim to rank (defensive)
            parts = (list(spec) + [None] * len(shape))[: len(shape)]
            spec = P(*parts)
        if fsdp and int(np.prod(shape)) >= (1 << 16):
            spec = _fsdp_wrap(spec, shape, mesh, stacked)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params)


def batch_pspec(mesh: Mesh, global_batch: int,
                include_model: bool = False) -> P:
    """Spec for a (B, ...) batch leaf — DP over pod×data when divisible;
    with include_model (dp profile) the idle 'model' axis joins the DP
    group when the batch allows."""
    if include_model and "model" in mesh.axis_names:
        pool = data_axes(mesh) + ("model",)
        size = int(np.prod([mesh.shape[a] for a in pool]))
        if global_batch % size == 0:
            return P(pool)
    return P(_dp_axis(mesh, global_batch))


def logits_pspec(mesh: Mesh, global_batch: int, vocab: int) -> P:
    return P(_dp_axis(mesh, global_batch), None, _axis(mesh, "model", vocab))


def cache_pspecs(caches: Params, mesh: Mesh, global_batch: int,
                 ring_axis: str | None = None) -> Params:
    """Specs for KV/state cache trees (from ``init_caches`` eval_shape).

    Batch shards over DP when divisible.  The *sequence* dim of KV
    caches shards over ``ring_axis`` when given (sequence-parallel ring
    attention: each device owns one contiguous KV block and
    ``kernels/ring_attention.py`` rotates them) — guarded, so a
    non-divisible sequence replicates instead of silently padding — and
    otherwise over 'data' when the batch is unshardable (long_500k B=1):
    attention contractions over the seq dim become GSPMD
    reduce-scatters.  Head / channel dims shard over 'model', except
    when the ring already placed the sequence there — one axis is never
    booked twice in a spec.
    """
    dp = _dp_axis(mesh, global_batch)
    seq_sp = dp is None          # SP fallback for unshardable batch
    batch_axes = (set() if dp is None
                  else set(dp) if isinstance(dp, tuple) else {dp})

    def sq(d):
        """Guarded KV-sequence axis: explicit ring axis first (never
        double-booking a batch axis), then the data-SP fallback."""
        if (ring_axis and ring_axis not in batch_axes
                and _axis(mesh, ring_axis, d)):
            return ring_axis
        if seq_sp:
            return _axis(mesh, "data", d)
        return None

    def rule(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        stacked = "periods" in names
        n_extra = 1 if stacked else 0
        dims = shape[n_extra:]
        lname = names[-1]
        lead = [None] * n_extra
        mdl = lambda d: _axis(mesh, "model", d)
        if lname in ("k", "v") and len(dims) == 4:      # (B,S,K,hd)
            s_ax = sq(dims[1])
            kh = None if s_ax == "model" else mdl(dims[2])
            # kv heads rarely divide a 16-wide axis (GQA: 4-8 heads) —
            # fall back to sharding head_dim, else the 32k-deep caches
            # replicate over 'model' (measured 40 GB/chip at qwen3 decode)
            hd = None if (kh or s_ax == "model") else mdl(dims[3])
            return P(*lead, dp, s_ax, kh, hd)
        if lname == "ckv" and len(dims) == 3:           # (B,S,r) MLA latent
            return P(*lead, dp, sq(dims[1]), None)
        if lname == "krope" and len(dims) == 3:
            return P(*lead, dp, sq(dims[1]), None)
        if lname == "conv" and len(dims) == 3:          # (B,w,di)
            return P(*lead, dp, None, mdl(dims[2]))
        if lname == "ssm" and len(dims) == 3:           # (B,di,ds)
            return P(*lead, dp, mdl(dims[1]), None)
        if lname == "wkv" and len(dims) == 4:           # (B,H,hd,hd)
            return P(*lead, dp, mdl(dims[1]), None, None)
        if lname in ("tm_x", "cm_x") and len(dims) == 2:
            return P(*lead, dp, None)
        # cross_kv k/v handled above; default: batch-shard only
        return P(*lead, dp, *([None] * (len(dims) - 1)))

    return jax.tree_util.tree_map_with_path(rule, caches)


def named(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def spec_tree_summary(specs: Params, shapes: Params) -> str:
    """Human-readable (path, shape, spec) listing — debugging / docs."""
    lines = []

    def visit(path, spec):
        lines.append(f"{'/'.join(_path_names(path)):60s} {spec}")

    jax.tree_util.tree_map_with_path(visit, specs,
                                     is_leaf=lambda x: isinstance(x, P))
    return "\n".join(lines)
