from .sharding import (batch_pspec, cache_pspecs, data_axes, logits_pspec,
                       named, param_pspecs, spec_tree_summary)
