"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Opt-in feature (DESIGN.md §5): the 40 baseline dry-run cells use DP×TP;
this module provides stage parallelism for depth-dominated models at
1000+-node scale, where a pure 2D mesh runs out of useful TP width.

Scheme: the layer stack is split into S contiguous stages along a 'stage'
mesh axis; the global batch is split into M microbatches.  Each step of the
(S + M - 1)-slot schedule runs the resident stage on its current microbatch
and ppermutes activations to the next stage.  Bubble fraction is
(S-1)/(S+M-1) — reported by `bubble_fraction` so launch configs can size M.

The stage body is a user function `stage_fn(stage_params, x) -> x`; stacked
stage params live on the 'stage' axis, so the whole pipeline is one
shard_map with no per-stage python dispatch.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def shard_map_compat(f, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions: top-level (>=0.6, check_vma) vs
    jax.experimental.shard_map (older, check_rep).  Shared by the pipeline
    here and the ring-attention kernel (kernels/ring_attention.py) — the
    one place the version fork lives."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


_shard_map = shard_map_compat


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)


def pipelined(stage_fn: Callable, mesh: Mesh, n_micro: int,
              axis: str = "stage") -> Callable:
    """Wrap `stage_fn` into a GPipe forward over the `axis` mesh axis.

    Returns f(stage_params, x) where
      stage_params : pytree with leading dim = n_stages (sharded over axis)
      x            : (B, ...) global batch, B % n_micro == 0
    """
    n_stages = mesh.shape[axis]

    def run(params, x):
        # inside shard_map: params have the stage dim stripped to local (1,...)
        local = jax.tree.map(lambda a: a[0], params)
        b = x.shape[0]
        mb = b // n_micro
        micro = x.reshape(n_micro, mb, *x.shape[1:])
        stage_id = jax.lax.axis_index(axis)
        n_slots = n_stages + n_micro - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def slot(carry, t):
            state, out = carry                       # (mb,...) in-flight act
            # stage s processes microbatch t-s when 0 <= t-s < n_micro
            mb_idx = t - stage_id
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # stage 0 injects a fresh microbatch; others consume the permuted
            inject = micro[jnp.clip(mb_idx, 0, n_micro - 1)]
            x_in = jnp.where(stage_id == 0, inject, state)
            y = stage_fn(local, x_in)
            y = jnp.where(active, y, state)
            # last stage banks its finished microbatch
            done = active & (stage_id == n_stages - 1)
            out = jax.lax.select(
                done,
                jax.lax.dynamic_update_index_in_dim(
                    out, y, jnp.clip(mb_idx, 0, n_micro - 1), 0),
                out)
            state = jax.lax.ppermute(y, axis, perm)
            return (state, out), None

        state0 = jnp.zeros((mb, *x.shape[1:]), x.dtype)
        out0 = jnp.zeros((n_micro, mb, *x.shape[1:]), x.dtype)
        (_, out), _ = jax.lax.scan(slot, (state0, out0), jnp.arange(n_slots))
        # finished microbatches live on the last stage; broadcast via a
        # masked psum (one all-reduce of the output, GPipe's usual epilogue)
        out = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, out, jnp.zeros_like(out)),
            axis)
        return out.reshape(b, *x.shape[1:])

    def wrapped(stage_params, x):
        in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
        return _shard_map(run, mesh, in_specs, P())(stage_params, x)

    return wrapped
