"""AdamW + warmup-cosine schedule + global-norm clipping — pure JAX.

No optax dependency: the optimizer state is a plain pytree {m, v, step} so
it shards with the same `param_pspecs` rules as the params (ZeRO-1 falls
out of `fsdp=True` for free) and checkpointing is one tree.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class OptState(NamedTuple):
    m: Params
    v: Params
    step: jnp.ndarray          # scalar int32


def adamw_init(params: Params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def wsd_schedule(step, *, lr: float, warmup: int, total: int,
                 min_frac: float = 0.1):
    """Linear warmup -> cosine decay to min_frac*lr."""
    step = step.astype(jnp.float32)
    warm = lr * (step + 1.0) / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree: Params):
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(grads: Params, state: OptState, params: Params, *,
                 lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    """One AdamW step.  `lr` may be a traced scalar (schedule output).

    Returns (new_params, new_state, metrics{grad_norm}).
    Decay applies only to >=2D params (weights), never norms/biases —
    the usual transformer convention.
    """
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / (gn + 1e-6))
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(new_m, new_v, step), {"grad_norm": gn}
