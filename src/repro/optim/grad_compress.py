"""Gradient compression: int8 quantization with error feedback (EF).

At 1000+-node scale the DP all-reduce of bf16 gradients is the dominant
inter-pod collective; int8 halves (vs bf16) the wire bytes.  We use the
standard EF-SGD construction [Seide et al. 2014; Karimireddy et al. 2019]:

    c_t   = Q(g_t + e_{t-1})          # quantize grad + carried residual
    e_t   = (g_t + e_{t-1}) - c_t     # residual stays local
    update uses c_t

Under GSPMD we cannot literally splice int8 into the emitted all-reduce;
instead the quantizer runs on the *local shard before the psum* (jit sees
int8-valued f32 tensors whose reduction is exact in f32), so convergence
behaviour is faithful and the wire-format win is recorded analytically in
the roofline (collective_bytes × 0.5 for 'int8' compression).

Off by default (TrainConfig.grad_compress); convergence parity is asserted
by tests/test_train.py on a toy model.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any
_QMAX = 127.0


def ef_state_init(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_leaf(g, e):
    x = g.astype(jnp.float32) + e
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / _QMAX
    q = jnp.round(x / scale)
    q = jnp.clip(q, -_QMAX, _QMAX)          # int8-valued
    c = q * scale
    return c, x - c


def compress_decompress(grads: Params, ef: Params):
    """(grads, ef) -> (int8-valued grads, new ef residuals)."""
    out = jax.tree.map(_quant_leaf, grads, ef)
    c = jax.tree.map(lambda t: t[0], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return c, new_ef
