from .adamw import (OptState, adamw_init, adamw_update, global_norm,
                    wsd_schedule)
from .grad_compress import compress_decompress, ef_state_init
