"""Mesh-safety pass: no silent whole-cache gather under a sharded KV.

A pallas_call has no SPMD partitioning rule, so lowering a single-device
kernel under a mesh that shards the KV sequence makes XLA all-gather the
FULL cache onto every chip — exactly the per-chip HBM blowup the launch
fit-check guards against, and the reason the 'auto' decode pick is
mesh-gated.  Declarations in the dispatch registry
(``AttentionInfo.mesh_safe``) encode which impls are safe to lower
sharded; this pass verifies the declarations against the compiler.

Mechanics: each non-ring impl is jitted under an emulated 8-device mesh
with the KV operands sharded over the sequence axis and the query
replicated, compiled to post-SPMD HLO, and scanned with the shared
walker (``launch.hlo_analysis.collective_result_bytes``) for all-gather
results at least as large as one full KV operand.  Verdicts:

  declared mesh_safe=True  + whole-cache gather found   -> FAIL
  declared mesh_safe=False + whole-cache gather found   -> ok (honest)
  declared mesh_safe=False + no gather                  -> ok (note only:
                                the declaration is merely conservative)

``flash_ring`` (needs_mesh) is excluded: it IS the sharded composition,
built from shard_map — there is no "lower it under an ambient mesh it
didn't ask for" scenario; resolution never routes a sharded cache to it
implicitly without the ring axis being present.

Requires >= ``N_DEVICES`` emulated devices (the audit CLI sets
XLA_FLAGS before importing jax); under fewer devices the pass reports
status 'skipped' rather than guessing.
"""
from __future__ import annotations

N_DEVICES = 8

# lowering shape: long enough that a whole-cache gather is unambiguous,
# short enough that interpret-mode pallas compiles quickly on CPU
_T_KV = 4096


def _gather_verdict(fn, q, k, v, mesh) -> dict:
    """Compile under the sharded-KV mesh; report the largest all-gather."""
    import jax
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.launch import hlo_analysis as ha

    kv_shard = NamedSharding(mesh, P(None, "kv", None, None))
    rep = NamedSharding(mesh, P())
    text = (jax.jit(fn, in_shardings=(rep, kv_shard, kv_shard))
            .lower(q, k, v).compile().as_text())
    sizes = ha.collective_result_bytes(text, "all-gather")
    full_kv = k.size * k.dtype.itemsize
    return {
        "all_gathers": len(sizes),
        "largest_gather_bytes": max(sizes) if sizes else 0,
        "full_kv_bytes": int(full_kv),
        "whole_cache_gather": bool(sizes) and max(sizes) >= full_kv,
    }


def check_impl(impl: str, *, mesh, declared_safe: bool | None = None
               ) -> dict:
    """Verdict for one registered impl under the sharded-KV mesh."""
    import jax.numpy as jnp

    from repro.kernels import dispatch

    from . import grid

    info = dispatch.attention_info(impl)
    declared = info.mesh_safe if declared_safe is None else declared_safe
    hd, hv, g = grid.HEAD["hd"], grid.HEAD["hv"], grid.HEAD["g"]
    b, kh = 2, 1
    s_q = 1 if info.decode_only else 128
    q = jnp.zeros((b, s_q, kh, g, hd), jnp.float32)
    k = jnp.zeros((b, _T_KV, kh, hd), jnp.float32)
    v = jnp.zeros((b, _T_KV, kh, hv), jnp.float32)
    q_pos = jnp.broadcast_to(
        jnp.arange(s_q, dtype=jnp.int32)[None] + (_T_KV - s_q), (b, s_q))
    kv_valid = jnp.ones((b, _T_KV), bool)
    mode = "float" if "float" in info.modes else sorted(info.modes)[0]
    entry = dispatch.get_attention(impl)

    def fn(q_, k_, v_):
        return entry(q_, k_, v_, q_pos=q_pos, kv_valid=kv_valid,
                     causal=True, scale=None, softmax_impl=mode)

    verdict = _gather_verdict(fn, q, k, v, mesh)
    verdict.update({
        "impl": impl,
        "declared_mesh_safe": declared,
        "ok": not (declared and verdict["whole_cache_gather"]),
    })
    return verdict


def run(impls=None) -> dict:
    """Execute the pass over every non-ring registered impl."""
    import numpy as np

    import jax
    from jax.sharding import Mesh

    from repro.kernels import dispatch

    devs = jax.devices()
    if len(devs) < N_DEVICES:
        return {"status": "skipped",
                "reason": f"needs {N_DEVICES} devices, have {len(devs)} "
                          "(run via python -m repro.analysis.audit, which "
                          "sets XLA_FLAGS before jax imports)",
                "impls": []}
    mesh = Mesh(np.array(devs[:N_DEVICES]).reshape(N_DEVICES), ("kv",))
    if impls is None:
        impls = [i for i in dispatch.attention_impls()
                 if not dispatch.attention_info(i).needs_mesh]
    results, bad = [], 0
    for impl in impls:
        r = check_impl(impl, mesh=mesh)
        bad += 0 if r["ok"] else 1
        results.append(r)
    return {"status": "fail" if bad else "ok", "impls": results}
