"""Kernel auditor CLI: ``python -m repro.analysis.audit [--strict]``.

Runs the four static passes over the live registry —

  int_purity      no float transcendental on the dual-mode word lattice
  vmem            every kernel plan fits 16 MiB/core (+ trace cross-check)
  mesh_safety     no silent whole-cache gather vs declared mesh_safe
  dispatch_table  resolution matrix consistent + docs not drifted

— writes machine-readable AUDIT.json (validated through the shared
``analysis.schema`` engine, the same one the bench smokes use), prints a
human report, and exits non-zero under ``--strict`` when any pass fails.

``--fixture NAME`` swaps one pass's subject for a seeded violation (a
known-bad computation / plan / declaration / registry) — CI runs each to
prove the auditor still catches what it claims to catch.  ``--write-docs``
regenerates the dispatch tables embedded in ``kernels/dispatch.py`` and
ARCHITECTURE.md.

XLA_FLAGS must be set BEFORE jax is first imported for the emulated
8-device mesh to exist; this module arranges that itself as long as
nothing imported jax earlier in the process (the package ``__init__`` is
deliberately import-free for this reason).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

PASSES = ("int_purity", "vmem", "mesh_safety", "dispatch_table")
FIXTURES = ("int_purity", "vmem", "mesh", "dispatch", "norm")


def _ensure_devices(n: int = 8) -> None:
    """Emulate ``n`` host devices — only effective before jax import."""
    if "jax" in sys.modules:
        return
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


# ---------------------------------------------------------------------------
# seeded violations: each proves one pass still detects its failure mode
# ---------------------------------------------------------------------------


def _fixture_int_purity() -> dict:
    """exp computed on the word lattice (quantize -> exp -> requantize)."""
    import jax.numpy as jnp

    from . import int_purity

    def bad(x):
        words = (x * 127.0).astype(jnp.int32)           # quantize
        f = words.astype(jnp.float32) * (1.0 / 127.0)
        e = jnp.exp(f)                                  # forbidden here
        return (e * 127.0).astype(jnp.int32)            # requantize

    x = jnp.zeros((8, 128), jnp.float32)
    v = int_purity.audit_fn(bad, (x,), "fixture:exp_requantize")
    return {"status": "fail" if v else "ok",
            "checked": ["fixture:exp_requantize"],
            "violations": [x.as_dict() for x in v]}


def _fixture_vmem() -> dict:
    """A plan whose single input tile alone oversubscribes the core."""
    from repro.kernels import tiling

    from . import vmem

    plan = {"in:x": ((4096, 4096), "float32")}   # 64 MiB, doubled to 128
    fp = vmem.plan_footprint(plan)
    budget = tiling.VMEM_CORE_BUDGET
    ok = fp <= budget
    return {"status": "ok" if ok else "fail",
            "over_budget": 0 if ok else 1, "trace_mismatches": [],
            "cells": [{"kernel": "fixture", "call": "oversubscribed",
                       "cell": "one 4096x4096 f32 tile", "bytes": fp,
                       "budget": budget, "ok": ok}]}


def _fixture_mesh() -> dict:
    """flash_decode re-audited as if it had declared mesh_safe=True."""
    import numpy as np

    import jax
    from jax.sharding import Mesh

    from . import mesh_safety

    devs = jax.devices()
    if len(devs) < mesh_safety.N_DEVICES:
        return {"status": "skipped",
                "reason": f"needs {mesh_safety.N_DEVICES} devices",
                "impls": []}
    mesh = Mesh(np.array(devs[:mesh_safety.N_DEVICES])
                .reshape(mesh_safety.N_DEVICES), ("kv",))
    r = mesh_safety.check_impl("flash_decode", mesh=mesh,
                               declared_safe=True)
    return {"status": "ok" if r["ok"] else "fail", "impls": [r]}


def _fixture_dispatch() -> dict:
    """An impl poked into the registry without AttentionInfo metadata."""
    from repro.kernels import dispatch

    from . import dispatch_table

    dispatch._load_attention_providers()
    dispatch._ATTENTION["rogue"] = lambda *a, **k: None
    try:
        return dispatch_table.run()
    finally:
        dispatch._ATTENTION.pop("rogue", None)


def _fixture_norm() -> dict:
    """A fused-norm provider registered with only ONE of the three
    NORM_SEAMS callables — the half-fused block the provider contract
    exists to refuse."""
    from repro.kernels import dispatch

    from . import dispatch_table

    dispatch.get_norm("fused_pallas")    # real providers loaded first
    dispatch._NORM["rogue"] = {"residual_norm": lambda *a, **k: None}
    try:
        return dispatch_table.run()
    finally:
        dispatch._NORM.pop("rogue", None)


_FIXTURE_RUNNERS = {
    "int_purity": ("int_purity", _fixture_int_purity),
    "vmem": ("vmem", _fixture_vmem),
    "mesh": ("mesh_safety", _fixture_mesh),
    "dispatch": ("dispatch_table", _fixture_dispatch),
    "norm": ("dispatch_table", _fixture_norm),
}


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def _report(audit: dict) -> str:
    lines = ["kernel audit"]
    p = audit["passes"]

    ip = p["int_purity"]
    lines.append(f"  [{ip['status']:>7}] int_purity: "
                 f"{len(ip.get('checked', []))} paths, "
                 f"{len(ip.get('violations', []))} violations")
    for v in ip.get("violations", []):
        lines.append(f"            {v['path']}: {v['prim']} at {v['where']}")

    vm = p["vmem"]
    cells = vm.get("cells", [])
    worst = max(cells, key=lambda c: c["bytes"], default=None)
    lines.append(f"  [{vm['status']:>7}] vmem: {len(cells)} cells, "
                 f"{vm.get('over_budget', 0)} over budget, "
                 f"{len(vm.get('trace_mismatches', []))} trace mismatches")
    if worst:
        lines.append(f"            worst: {worst['kernel']}/{worst['call']} "
                     f"{worst['bytes'] // 1024} KiB of "
                     f"{worst['budget'] // 1024} KiB "
                     f"({worst['cell']})")
    for c in cells:
        if not c["ok"]:
            lines.append(f"            OVER: {c['kernel']}/{c['call']} "
                         f"{c['bytes'] // 1024} KiB ({c['cell']})")
    for m in vm.get("trace_mismatches", []):
        lines.append(f"            {m}")

    ms = p["mesh_safety"]
    lines.append(f"  [{ms['status']:>7}] mesh_safety: "
                 f"{len(ms.get('impls', []))} impls"
                 + (f" ({ms['reason']})" if ms.get("reason") else ""))
    for r in ms.get("impls", []):
        tag = "ok" if r["ok"] else "FAIL"
        gather = ("whole-cache gather "
                  f"({r['largest_gather_bytes']}B >= {r['full_kv_bytes']}B)"
                  if r["whole_cache_gather"] else "no whole-cache gather")
        lines.append(f"            [{tag}] {r['impl']}: declared "
                     f"mesh_safe={r['declared_mesh_safe']}, {gather}")

    dt = p["dispatch_table"]
    lines.append(f"  [{dt['status']:>7}] dispatch_table: "
                 f"{dt.get('cells', 0)} cells, "
                 f"{len(dt.get('problems', []))} problems, "
                 f"{len(dt.get('drift', []))} doc drift")
    for msg in dt.get("problems", []) + dt.get("drift", []):
        lines.append(f"            {msg}")

    lines.append(f"  => {'OK' if audit['ok'] else 'FAIL'}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="static kernel auditor (int purity, VMEM budgets, "
                    "mesh safety, dispatch-table truth)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when any pass fails")
    ap.add_argument("--out", default="AUDIT.json",
                    help="where to write the machine-readable artifact")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help=f"comma-separated subset of {','.join(PASSES)}")
    ap.add_argument("--fixture", choices=FIXTURES,
                    help="swap one pass's subject for a seeded violation "
                         "(self-test: the run must then FAIL)")
    ap.add_argument("--write-docs", action="store_true",
                    help="regenerate the dispatch tables in dispatch.py "
                         "and ARCHITECTURE.md, then re-audit")
    args = ap.parse_args(argv)

    _ensure_devices()

    selected = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = sorted(set(selected) - set(PASSES))
    if unknown:
        ap.error(f"unknown passes {unknown}; have {list(PASSES)}")

    from . import dispatch_table, int_purity, mesh_safety, schema, vmem

    if args.write_docs:
        for path in dispatch_table.write_docs():
            print(f"wrote dispatch tables into {path}")

    runners = {"int_purity": int_purity.run, "vmem": vmem.run,
               "mesh_safety": mesh_safety.run,
               "dispatch_table": dispatch_table.run}
    if args.fixture:
        key, fn = _FIXTURE_RUNNERS[args.fixture]
        runners[key] = fn
        if key not in selected:
            selected.append(key)

    passes = {}
    for name in PASSES:
        if name in selected:
            passes[name] = runners[name]()
        else:
            passes[name] = {"status": "skipped",
                            "reason": "not selected",
                            **({"checked": [], "violations": []}
                               if name == "int_purity" else {}),
                            **({"cells": [], "over_budget": 0,
                                "trace_mismatches": []}
                               if name == "vmem" else {}),
                            **({"impls": []}
                               if name == "mesh_safety" else {}),
                            **({"cells": 0, "problems": [], "drift": []}
                               if name == "dispatch_table" else {})}

    audit = {
        "generated_by": "python -m repro.analysis.audit",
        "strict": bool(args.strict),
        "ok": all(p["status"] != "fail" for p in passes.values()),
        "passes": passes,
    }
    schema.validate(audit, schema.AUDIT_SPEC, schema.AUDIT_RULES,
                    "AUDIT.json")
    with open(args.out, "w") as fh:
        json.dump(audit, fh, indent=2, sort_keys=True)
        fh.write("\n")

    print(_report(audit))
    print(f"wrote {args.out}")
    if args.fixture and audit["ok"]:
        print(f"fixture {args.fixture!r} was NOT detected — "
              "the auditor has gone blind", file=sys.stderr)
        return 2
    if args.fixture:
        print(f"fixture {args.fixture!r} detected as intended")
        return 1
    return 1 if (args.strict and not audit["ok"]) else 0


if __name__ == "__main__":
    raise SystemExit(main())
