"""VMEM-budget pass: every kernel's resident set fits one TensorCore.

Each Pallas kernel module exports a static ``vmem_plan()`` — the block
shapes and dtypes of its in/out tiles and scratch buffers at a given
problem shape (mirroring the BlockSpecs it actually passes to
pallas_call).  This pass prices each plan over the canonical shape grid:

    footprint = 2 x (input tiles + output tiles)  +  scratch
                ^^^ double-buffered by the pipeline ^^^

and fails any cell above ``tiling.VMEM_CORE_BUDGET`` (16 MiB/core).

Declarations can lie, so the pass also CROSS-CHECKS them against the
kernels themselves: it traces representative pallas_calls and asserts
every kernel ref aval (shape, dtype) — inputs, outputs, scratch — is
accounted for in the module's declared plan at the same shape.  A kernel
that grows a new scratch buffer without updating its plan fails here,
not in production.
"""
from __future__ import annotations

FOOTPRINT_BUFFERING = 2   # in/out tiles are double-buffered by the pipeline


def _nbytes(entry) -> int:
    import jax.numpy as jnp
    shape, dtype = entry
    n = 1
    for d in shape:
        n *= int(d)
    return n * jnp.dtype(dtype).itemsize


def plan_footprint(plan: dict) -> int:
    """Bytes resident for one pallas_call's plan ({ref: (shape, dtype)})."""
    io = sum(_nbytes(v) for k, v in plan.items()
             if not k.startswith("scratch:"))
    scratch = sum(_nbytes(v) for k, v in plan.items()
                  if k.startswith("scratch:"))
    return FOOTPRINT_BUFFERING * io + scratch


def iter_cells():
    """(kernel_module_name, call_name, cell_desc, plan) over the grid."""
    from repro.kernels import (dualmode_softmax, flash_attention,
                               flash_attention_bwd, flash_attention_int,
                               flash_decode, fused_ffn, fused_norm,
                               ring_attention)

    from . import grid

    for cell in grid.attention_cells():
        shape = (cell["s_q"], cell["t_kv"], cell["hd"], cell["hv"],
                 cell["g"])
        desc = f"{cell['phase']} s_q={cell['s_q']} t={cell['t_kv']}"
        if cell["s_q"] == 1:
            for call, plan in flash_decode.vmem_plan(
                    cell["t_kv"], cell["hd"], cell["hv"], cell["g"]).items():
                yield "flash_decode", call, desc, plan
            continue
        for mod in (flash_attention, flash_attention_int,
                    flash_attention_bwd, ring_attention):
            for call, plan in mod.vmem_plan(*shape).items():
                yield mod.__name__.rsplit(".", 1)[-1], call, desc, plan

    f = grid.FFN_CELL
    for call, plan in fused_ffn.vmem_plan(f["m"], f["k"], f["f"]).items():
        yield "fused_ffn", call, f"m={f['m']} k={f['k']} f={f['f']}", plan
    s = grid.SOFTMAX_CELL
    for call, plan in dualmode_softmax.vmem_plan(
            s["rows"], s["cols"]).items():
        yield "dualmode_softmax", call, \
            f"rows={s['rows']} cols={s['cols']}", plan
    n = grid.NORM_CELL
    for call, plan in fused_norm.vmem_plan(n["m"], n["d"], n["f"]).items():
        yield "fused_norm", call, f"m={n['m']} d={n['d']} f={n['f']}", plan


# ---------------------------------------------------------------------------
# declared-vs-traced cross-check
# ---------------------------------------------------------------------------


def _kernel_ref_avals(closed_jaxpr):
    """[(shape, dtype_str)] of every pallas kernel ref in the trace."""
    from jax._src import core as jcore

    refs = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            subs = []
            for val in eqn.params.values():
                items = val if isinstance(val, (list, tuple)) else [val]
                for item in items:
                    if isinstance(item, jcore.ClosedJaxpr):
                        subs.append(item.jaxpr)
                    elif isinstance(item, jcore.Jaxpr):
                        subs.append(item)
            if eqn.primitive.name == "pallas_call":
                for sub in subs:
                    for var in sub.invars:
                        aval = var.aval
                        refs.append((tuple(aval.shape), str(aval.dtype)))
            else:
                for sub in subs:
                    walk(sub)

    walk(closed_jaxpr.jaxpr)
    return refs


def cross_check() -> list[str]:
    """Trace representative kernels; every traced kernel ref must appear
    in the module's declared plan at the same shape.  Returns problems.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import flash_attention, flash_attention_int

    from . import grid

    s_q, t = grid.TRACE_SQ, grid.TRACE_T
    hd, hv, g = grid.HEAD["hd"], grid.HEAD["hv"], grid.HEAD["g"]
    b, kh = 1, 1
    q = jnp.zeros((b, s_q, kh, g, hd), jnp.float32)
    k = jnp.zeros((b, t, kh, hd), jnp.float32)
    v = jnp.zeros((b, t, kh, hv), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s_q, dtype=jnp.int32)[None],
                             (b, s_q))
    kv_valid = jnp.ones((b, t), bool)

    targets = [
        ("flash_attention", "flash_fwd",
         lambda: flash_attention.flash_attention_pallas(
             q, k, v, q_pos=q_pos, kv_valid=kv_valid, interpret=True),
         flash_attention.vmem_plan(s_q, t, hd, hv, g)),
        ("flash_attention_int", "flash_int_onesweep",
         lambda: flash_attention_int.flash_attention_pallas_int(
             q, k, v, q_pos=q_pos, kv_valid=kv_valid, interpret=True),
         flash_attention_int.vmem_plan(s_q, t, hd, hv, g)),
    ]
    problems = []
    for mod_name, call_name, thunk, plans in targets:
        traced = _kernel_ref_avals(jax.make_jaxpr(thunk)())
        if not traced:
            problems.append(f"{mod_name}: no pallas_call found in trace")
            continue
        declared = {}
        for entry in plans[call_name].values():
            shape, dtype = entry
            key = (tuple(int(d) for d in shape), str(jnp.dtype(dtype)))
            declared[key] = declared.get(key, 0) + 1
        seen: dict = {}
        for key in traced:
            seen[key] = seen.get(key, 0) + 1
            if seen[key] > declared.get(key, 0):
                problems.append(
                    f"{mod_name}.{call_name}: traced kernel ref "
                    f"{key[1]}{list(key[0])} not declared in vmem_plan()")
    return problems


def run(budget: int | None = None) -> dict:
    """Execute the pass: budget every grid cell + cross-check traces."""
    from repro.kernels import tiling

    budget = tiling.VMEM_CORE_BUDGET if budget is None else budget
    cells, over = [], 0
    for mod, call, desc, plan in iter_cells():
        fp = plan_footprint(plan)
        ok = fp <= budget
        over += 0 if ok else 1
        cells.append({"kernel": mod, "call": call, "cell": desc,
                      "bytes": fp, "budget": budget, "ok": ok})
    mismatches = cross_check()
    status = "fail" if (over or mismatches) else "ok"
    return {"status": status, "cells": cells, "over_budget": over,
            "trace_mismatches": mismatches}
