"""Int-purity pass: no float transcendental on the dual-mode word path.

The paper's claim is that GELU and softmax run on the SAME int unit —
shift/add/compare arithmetic on quantized words.  The repo-wide
invariant is therefore: in any path executed under
``softmax_impl='dualmode'/'dualmode_snap'``, no ``exp``/``log``/``erf``/
``tanh``/``div``/... primitive may compute ON the word lattice (the int
region between quantize and dequantize).  Float transcendentals are fine
OUTSIDE it — the blocked kernels' finishing ``acc / l`` divide happens
after the words are done and feeds only the f32 output.

Mechanically: flatten the closed jaxpr of each audited path
interprocedurally (pjit/cond/custom-vjp bodies inlined positionally,
pallas kernel bodies mapped through the ref calling convention,
scan/while folded conservatively all-to-all), then

  tainted      = forward closure from every integer-dtype var
  feeds_words  = backward closure from every integer-dtype var
  violation    = forbidden primitive with a tainted input AND an output
                 in feeds_words  (i.e. the op sits int -> op -> int)

which flags an ``exp`` whose result is requantized into words, but not
the finishing divide (its quotient never reaches an int var).
"""
from __future__ import annotations

from dataclasses import dataclass

# primitives that have no business on a shift/add word lattice
FORBIDDEN = frozenset({
    "exp", "exp2", "log", "log2", "log1p", "erf", "erf_inv", "erfc",
    "tanh", "logistic", "div", "pow", "integer_pow", "rsqrt", "sqrt",
    "cbrt", "sin", "cos", "atan2",
})


@dataclass
class Violation:
    path: str          # audited path name, e.g. "attn:flash_pallas_int"
    prim: str          # offending primitive
    where: str         # source location if the trace kept one

    def as_dict(self) -> dict:
        return {"path": self.path, "prim": self.prim, "where": self.where}


class _Graph:
    """Flattened dataflow graph over global var ids."""

    def __init__(self):
        self.n = 0
        self.fwd: dict[int, set[int]] = {}
        self.bwd: dict[int, set[int]] = {}
        self.int_vars: set[int] = set()
        # (prim, in_ids, out_ids, where) for forbidden eqns only
        self.suspects: list[tuple[str, list[int], list[int], str]] = []

    def new_id(self, aval) -> int:
        i = self.n
        self.n += 1
        if _is_int(aval):
            self.int_vars.add(i)
        return i

    def edge(self, a: int, b: int) -> None:
        self.fwd.setdefault(a, set()).add(b)
        self.bwd.setdefault(b, set()).add(a)

    def closure(self, seeds: set[int], edges: dict[int, set[int]]
                ) -> set[int]:
        seen = set(seeds)
        stack = list(seeds)
        while stack:
            for nxt in edges.get(stack.pop(), ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return seen


def _is_int(aval) -> bool:
    import numpy as np
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and np.issubdtype(dtype, np.integer)


def _sub_jaxprs(params):
    """(key, jaxpr) for every jaxpr-valued param (lists/tuples included)."""
    from jax._src import core as jcore
    for key, val in params.items():
        items = val if isinstance(val, (list, tuple)) else [val]
        for item in items:
            if isinstance(item, jcore.ClosedJaxpr):
                yield key, item.jaxpr
            elif isinstance(item, jcore.Jaxpr):
                yield key, item


def _var_id(g: _Graph, env: dict, var) -> int:
    from jax._src import core as jcore
    if isinstance(var, jcore.Literal):
        return g.new_id(var.aval)       # fresh node, no history
    if var not in env:
        env[var] = g.new_id(var.aval)
    return env[var]


def _where(eqn) -> str:
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "?"


def _walk(g: _Graph, jaxpr, env: dict) -> None:
    for eqn in jaxpr.eqns:
        in_ids = [_var_id(g, env, v) for v in eqn.invars]
        out_ids = [_var_id(g, env, v) for v in eqn.outvars]
        name = eqn.primitive.name

        # default dataflow: every input may reach every output
        for a in in_ids:
            for b in out_ids:
                g.edge(a, b)

        if name in FORBIDDEN:
            g.suspects.append((name, in_ids, out_ids, _where(eqn)))

        # stores: the written value flows INTO the ref operand, so later
        # reads of the ref pick it up (swap: (ref, val, *idx) -> old)
        if name in ("swap", "addupdate", "masked_swap") and len(in_ids) >= 2:
            g.edge(in_ids[1], in_ids[0])

        subs = list(_sub_jaxprs(eqn.params))
        if not subs:
            continue

        if name == "pallas_call":
            # kernel invars follow the ref convention: inputs, then
            # outputs, then scratch.  Refs carry data both ways.
            for _, kj in subs:
                sub_env: dict = {}
                kin = [_var_id(g, sub_env, v) for v in kj.invars]
                n_in = len(in_ids)
                for i, kid in enumerate(kin):
                    if i < n_in:
                        g.edge(in_ids[i], kid)
                        g.edge(kid, in_ids[i])
                    elif i - n_in < len(out_ids):
                        g.edge(kid, out_ids[i - n_in])
                        g.edge(out_ids[i - n_in], kid)
                _walk(g, kj, sub_env)
        elif name in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                      "custom_vjp_call", "custom_vjp_call_jaxpr",
                      "remat_call", "checkpoint"):
            for _, sub in subs:
                sub_env = {}
                sin = [_var_id(g, sub_env, v) for v in sub.invars]
                sout = [_var_id(g, sub_env, v) for v in sub.outvars]
                # positional when arities line up (the common case)
                if len(sin) == len(in_ids):
                    for a, b in zip(in_ids, sin):
                        g.edge(a, b)
                else:
                    for a in in_ids:
                        for b in sin:
                            g.edge(a, b)
                if len(sout) == len(out_ids):
                    for a, b in zip(sout, out_ids):
                        g.edge(a, b)
                else:
                    for a in sout:
                        for b in out_ids:
                            g.edge(a, b)
                _walk(g, sub, sub_env)
        elif name == "cond":
            rest = in_ids[1:]          # in_ids[0] is the branch predicate
            for _, sub in subs:
                sub_env = {}
                sin = [_var_id(g, sub_env, v) for v in sub.invars]
                sout = [_var_id(g, sub_env, v) for v in sub.outvars]
                src = rest if len(sin) == len(rest) else in_ids
                if len(sin) == len(src):
                    for a, b in zip(src, sin):
                        g.edge(a, b)
                else:
                    for a in src:
                        for b in sin:
                            g.edge(a, b)
                for a, b in zip(sout, out_ids):
                    g.edge(a, b)
                _walk(g, sub, sub_env)
        else:
            # scan / while / shard_map / anything else carrying jaxprs:
            # conservative all-to-all at the boundary — taint may spread
            # wider than reality, never narrower
            for _, sub in subs:
                sub_env = {}
                sin = [_var_id(g, sub_env, v) for v in sub.invars]
                sout = [_var_id(g, sub_env, v) for v in sub.outvars]
                for a in in_ids:
                    for b in sin:
                        g.edge(a, b)
                for a in sout:
                    for b in out_ids:
                        g.edge(a, b)
                _walk(g, sub, sub_env)


def audit_jaxpr(closed_jaxpr, path: str) -> list[Violation]:
    """All int-path purity violations in one traced computation."""
    g = _Graph()
    env: dict = {}
    jaxpr = closed_jaxpr.jaxpr
    for v in jaxpr.invars + jaxpr.constvars:
        _var_id(g, env, v)
    _walk(g, jaxpr, env)

    tainted = g.closure(set(g.int_vars), g.fwd)
    feeds_words = g.closure(set(g.int_vars), g.bwd)
    out = []
    for prim, in_ids, out_ids, where in g.suspects:
        if (any(i in tainted for i in in_ids)
                and any(o in feeds_words for o in out_ids)):
            out.append(Violation(path=path, prim=prim, where=where))
    return out


def audit_fn(fn, args, path: str, **kwargs) -> list[Violation]:
    import jax
    closed = jax.make_jaxpr(lambda *xs: fn(*xs, **kwargs))(*args)
    return audit_jaxpr(closed, path)


# ---------------------------------------------------------------------------
# the audited paths: every registered dual-mode word path
# ---------------------------------------------------------------------------


def _attention_args(s_q: int, t_kv: int):
    import jax.numpy as jnp
    from . import grid
    hd, hv, g = grid.HEAD["hd"], grid.HEAD["hv"], grid.HEAD["g"]
    b, kh = 1, 1
    q = jnp.zeros((b, s_q, kh, g, hd), jnp.float32)
    k = jnp.zeros((b, t_kv, kh, hd), jnp.float32)
    v = jnp.zeros((b, t_kv, kh, hv), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s_q, dtype=jnp.int32)[None]
                             + (t_kv - s_q), (b, s_q))
    kv_valid = jnp.ones((b, t_kv), bool)
    return q, k, v, q_pos, kv_valid


def iter_paths():
    """(name, fn, args, kwargs) for every dual-mode path to audit."""
    import jax.numpy as jnp

    from repro.core import softmax_unit as unit
    from repro.kernels import dispatch, dualmode_softmax

    from . import grid

    x = jnp.zeros((8, 128), jnp.float32)
    yield ("softmax:dualmode", dispatch.get_softmax("dualmode"), (x,), {})
    yield ("softmax:dualmode_snap", dispatch.get_softmax("dualmode_snap"),
           (x,), {})
    yield ("gelu:dualmode", unit.gelu_dualmode, (x,), {})
    yield ("silu:dualmode", unit.silu_dualmode, (x,), {})
    # the norm residents: rsqrt is FORBIDDEN on the lattice, so these
    # paths prove the exp2(-0.5*log2(.)) shift/add route actually holds
    g = jnp.ones((128,), jnp.float32)
    b = jnp.zeros((128,), jnp.float32)
    yield ("rmsnorm:dualmode", unit.rmsnorm_dualmode, (x, g),
           {"eps": 1e-6})
    yield ("layernorm:dualmode", unit.layernorm_dualmode, (x, g, b),
           {"eps": 1e-6})
    yield ("softmax_pallas:int",
           lambda a: dualmode_softmax.softmax_pallas(
               a, precision="int", interpret=True), (x,), {})
    yield ("pair_act_pallas:int",
           lambda a: dualmode_softmax.pair_act_pallas(
               a, mode="gelu", precision="int", interpret=True), (x,), {})

    s_q, t = grid.TRACE_SQ, grid.TRACE_T
    for impl in dispatch.attention_impls():
        info = dispatch.attention_info(impl)
        int_modes = sorted(info.modes & {"dualmode", "dualmode_snap"})
        if not int_modes or info.needs_mesh:
            # the ring's per-hop body IS the single-device int kernel
            # audited here; shard_map tracing needs live mesh devices
            continue
        sq = 1 if info.decode_only else s_q
        q, k, v, q_pos, kv_valid = _attention_args(sq, t)
        for mode in int_modes:
            yield (f"attn:{impl}:{mode}", dispatch.get_attention(impl),
                   (q, k, v),
                   dict(q_pos=q_pos, kv_valid=kv_valid, causal=True,
                        scale=None, softmax_impl=mode))


def run() -> dict:
    """Execute the pass over every registered dual-mode path."""
    checked, violations = [], []
    for name, fn, args, kwargs in iter_paths():
        checked.append(name)
        violations.extend(v.as_dict()
                          for v in audit_fn(fn, args, name, **kwargs))
    return {"status": "fail" if violations else "ok",
            "checked": checked, "violations": violations}
