"""Dispatch-table truth: the resolution matrix, enumerated and generated.

The (attn_impl x softmax_impl x phase x mesh) matrix is resolved
exhaustively through the live registry:

  * every EXPLICIT impl either resolves (to itself) or raises a
    ValueError, identically across phases and meshes — explicit picks
    are shape/mesh independent by design (the ring upgrade lives only in
    the 'auto' branch), and a cell that varies is an audit failure;
  * refusal is two-sided: an entry must also RAISE when handed a mode
    outside its declared ``AttentionInfo.modes`` (metadata that merely
    decorates is worthless — it must match the callable's behavior);
  * every impl present in the registry carries metadata — an impl poked
    into ``_ATTENTION`` without registering declarations is a failure;
  * the 'auto' cells resolve per (phase, mesh, mode) under
    ``dispatch.analysis_mesh`` — no emulated devices needed.

The same enumeration GENERATES the human tables embedded between marker
lines in ``kernels/dispatch.py``'s docstring and ARCHITECTURE.md.
``check_docs()`` diffs generated-vs-committed (doc drift = CI failure);
``python -m repro.analysis.audit --write-docs`` rewrites both in place.
"""
from __future__ import annotations

import os
import re

DISPATCH_MARK = ("[dispatch-table:begin]", "[dispatch-table:end]")
MD_MARK = ("<!-- dispatch-table:begin -->", "<!-- dispatch-table:end -->")


def _resolve_cell(impl: str, mode: str, s_q: int, t_kv: int,
                  mesh_axes, ring_axis: str) -> str:
    """'-> name' when resolution succeeds, 'raise' on the intentional
    ValueError.  Anything else propagates — an unintentional failure."""
    from repro.kernels import dispatch

    def go():
        try:
            return "-> " + dispatch.resolve_attention(
                impl, s_q, t_kv, softmax_impl=mode, ring_axis=ring_axis)
        except ValueError:
            return "raise"

    if mesh_axes is None:
        return go()
    with dispatch.analysis_mesh(mesh_axes):
        return go()


def enumerate_matrix() -> dict:
    """Resolve every cell; collect per-impl and 'auto' outcomes plus any
    consistency problems."""
    from repro.kernels import dispatch

    from . import grid

    problems: list[str] = []
    dispatch._load_attention_providers()
    undeclared = sorted(set(dispatch._ATTENTION)
                        - set(dispatch._ATTENTION_INFO))
    for name in undeclared:
        problems.append(
            f"impl {name!r} is in the registry without AttentionInfo "
            "metadata (registered by poking _ATTENTION directly?)")

    impls = dispatch.attention_impls()
    explicit: dict[str, dict[str, str]] = {}
    for impl in impls:
        if impl in undeclared:
            continue
        row: dict[str, str] = {}
        for mode in grid.MODES:
            outcomes = set()
            for phase, (s_q, t_kv) in grid.PHASES.items():
                for mesh_name, axes in grid.MESHES.items():
                    ring = grid.RING_AXIS if axes else ""
                    outcomes.add(_resolve_cell(impl, mode, s_q, t_kv,
                                               axes, ring))
            if len(outcomes) != 1:
                problems.append(
                    f"explicit impl {impl!r} mode {mode!r} resolves "
                    f"inconsistently across phases/meshes: "
                    f"{sorted(outcomes)}")
            out = sorted(outcomes)[0]
            declared = mode in dispatch.attention_info(impl).modes
            if declared and out == "raise":
                problems.append(
                    f"{impl!r} declares mode {mode!r} but resolution "
                    "raises")
            if not declared and out != "raise":
                problems.append(
                    f"{impl!r} does not declare mode {mode!r} but "
                    "resolution passes it through")
            row[mode] = "ok" if out != "raise" else "raise"
        explicit[impl] = row

    auto: dict[tuple[str, str, str], str] = {}
    for phase, (s_q, t_kv) in grid.PHASES.items():
        for mesh_name, axes in grid.MESHES.items():
            ring = grid.RING_AXIS if axes else ""
            for mode in grid.MODES:
                out = _resolve_cell("auto", mode, s_q, t_kv, axes, ring)
                if out == "raise":
                    problems.append(
                        f"'auto' raised at phase={phase} mesh={mesh_name} "
                        f"mode={mode} — auto must always resolve")
                auto[(phase, mesh_name, mode)] = out.removeprefix("-> ")

    problems.extend(_entry_refusals())
    norm_problems, norm_cells = _norm_contract()
    problems.extend(norm_problems)
    cells = (len(explicit) * len(grid.MODES) * len(grid.PHASES)
             * len(grid.MESHES)
             + len(grid.PHASES) * len(grid.MESHES) * len(grid.MODES)
             + norm_cells)
    return {"explicit": explicit, "auto": auto, "problems": problems,
            "cells": cells}


def _norm_contract() -> tuple[list[str], int]:
    """The fused-norm provider contract, enumerated through the live
    registry: every registered provider must carry ALL of
    ``dispatch.NORM_SEAMS`` as callables (a provider that fuses only
    some seams would silently fall back mid-block), and every norm/ffn
    impl string — explicit and 'auto' — must resolve to a registered
    name.  Returns (problems, cells_checked)."""
    from repro.kernels import dispatch

    problems: list[str] = []
    cells = 0
    dispatch.get_norm("fused_pallas")    # load the fused provider
    for name in sorted(dispatch._NORM):
        prov = dispatch._NORM[name]
        if prov is None:
            continue                     # 'dense' = the unfused path
        for seam in dispatch.NORM_SEAMS:
            cells += 1
            if not callable(prov.get(seam)):
                problems.append(
                    f"norm provider {name!r} is missing seam {seam!r} — "
                    "a provider must carry every NORM_SEAMS entry or the "
                    "block would silently fall half-fused")
    for impl in sorted(dispatch._NORM) + ["auto"]:
        cells += 1
        resolved = dispatch.resolve_norm(impl)
        if resolved not in dispatch._NORM:
            problems.append(
                f"norm_impl {impl!r} resolves to unregistered "
                f"{resolved!r}")
    for impl in sorted(dispatch._FFN) + ["auto"]:
        cells += 1
        try:
            dispatch.get_ffn(dispatch.resolve_ffn(impl))
        except ValueError as exc:
            problems.append(f"ffn_impl {impl!r} fails to resolve: {exc}")
    return problems, cells


def _entry_refusals() -> list[str]:
    """Every entry must raise ValueError on modes OUTSIDE its declared
    set — the guard the resolver's metadata promises exists."""
    import jax.numpy as jnp

    from repro.kernels import dispatch

    from . import grid

    b, s, kh, g, hd = 1, 8, 1, 1, 8
    q = jnp.zeros((b, s, kh, g, hd), jnp.float32)
    k = jnp.zeros((b, s, kh, hd), jnp.float32)
    v = jnp.zeros((b, s, kh, hd), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    kv_valid = jnp.ones((b, s), bool)

    problems = []
    for impl in dispatch.attention_impls():
        info = dispatch._ATTENTION_INFO.get(impl)
        if info is None:
            continue       # already reported as undeclared by the caller
        if info.needs_mesh:
            continue                      # entry needs a live mesh to run
        entry = dispatch.get_attention(impl)
        for mode in grid.MODES:
            if mode in info.modes:
                continue
            try:
                entry(q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=True,
                      scale=None, softmax_impl=mode)
            except ValueError:
                continue
            except Exception as exc:       # pragma: no cover - diagnostics
                problems.append(
                    f"{impl!r} entry raised {type(exc).__name__} (not "
                    f"ValueError) on undeclared mode {mode!r}")
                continue
            problems.append(
                f"{impl!r} entry silently accepted undeclared "
                f"softmax_impl={mode!r} — the word contract can be "
                "dropped")
    return problems


# ---------------------------------------------------------------------------
# table generation + doc drift
# ---------------------------------------------------------------------------


def generate_tables() -> str:
    """The canonical generated block (shared verbatim by both docs)."""
    from repro.kernels import dispatch

    from . import grid

    matrix = enumerate_matrix()
    lines = [
        "Explicit `attn_impl` x `softmax_impl` — identical across phases",
        "and meshes (the ring upgrade exists only inside 'auto').",
        "'raise' cells are intentional ValueErrors: a dual-mode word",
        "contract is never silently dropped.",
        "",
        "| attn_impl | float | dualmode | dualmode_snap | grad "
        "| constraints |",
        "|---|---|---|---|---|---|",
    ]
    for impl in sorted(matrix["explicit"]):
        row = matrix["explicit"][impl]
        info = dispatch.attention_info(impl)
        cons = [c for c, on in (("s_q=1 only", info.decode_only),
                                ("needs mesh", info.needs_mesh),
                                ("mesh-safe", info.mesh_safe)) if on]
        lines.append(
            f"| {impl} | {row['float']} | {row['dualmode']} "
            f"| {row['dualmode_snap']} | {'yes' if info.grad else 'no'} "
            f"| {', '.join(cons) or '-'} |")
    lines += [
        "",
        "`attn_impl='auto'` by (phase, mesh), resolved on the cpu/",
        "interpret backend — on TPU the blocked float pick is",
        "'flash_pallas' (``models.flash.blocked_impl``); everything else",
        "is backend-independent.",
        "",
        "| phase | mesh | float | dualmode | dualmode_snap |",
        "|---|---|---|---|---|",
    ]
    for phase, (s_q, t_kv) in grid.PHASES.items():
        for mesh_name in grid.MESHES:
            cells = [matrix["auto"][(phase, mesh_name, m)]
                     for m in grid.MODES]
            lines.append(f"| {phase} ({s_q}x{t_kv}) | {mesh_name} "
                         f"| {cells[0]} | {cells[1]} | {cells[2]} |")
    lines += [
        "",
        "`norm_impl` providers — a fused provider must carry ALL three",
        "block seams (``dispatch.NORM_SEAMS``); 'unfused' rows run the",
        "reference norms in models/layers.py.  'auto' resolves to",
        "'fused_pallas' on TPU and 'dense' elsewhere, for `norm_impl`",
        "and `ffn_impl` alike (dispatch.resolve_norm / resolve_ffn).",
        "",
        "| norm_impl | residual_norm | norm_linear | norm_glu |",
        "|---|---|---|---|",
    ]
    dispatch.get_norm("fused_pallas")    # load the fused provider
    for name in sorted(dispatch._NORM):
        prov = dispatch._NORM[name]
        if prov is None:
            seam_cells = ["unfused"] * len(dispatch.NORM_SEAMS)
        else:
            seam_cells = ["ok" if callable(prov.get(s)) else "MISSING"
                          for s in dispatch.NORM_SEAMS]
        lines.append(f"| {name} | " + " | ".join(seam_cells) + " |")
    return "\n".join(lines)


def _doc_targets() -> list[tuple[str, tuple[str, str]]]:
    from repro.kernels import dispatch as dispatch_mod

    # <root>/src/repro/kernels/dispatch.py -> <root>  (repro is a
    # namespace package, so repro.__file__ is None — walk up from here)
    dispatch_path = os.path.abspath(dispatch_mod.__file__)
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(dispatch_path))))
    return [
        (dispatch_path, DISPATCH_MARK),
        (os.path.join(root, "ARCHITECTURE.md"), MD_MARK),
    ]


def _extract(text: str, marks: tuple[str, str], path: str) -> str:
    begin, end = marks
    pattern = re.escape(begin) + r"\n(.*?)" + re.escape(end)
    m = re.search(pattern, text, re.DOTALL)
    if not m:
        raise ValueError(f"{path}: markers {begin!r}/{end!r} not found")
    return m.group(1).rstrip("\n")


def check_docs() -> list[str]:
    """Drift between the generated block and each committed doc."""
    want = generate_tables()
    drift = []
    for path, marks in _doc_targets():
        with open(path) as f:
            text = f.read()
        try:
            have = _extract(text, marks, path)
        except ValueError as exc:
            drift.append(str(exc))
            continue
        if have.strip() != want.strip():
            drift.append(
                f"{os.path.basename(path)}: committed dispatch table "
                "differs from the live registry — regenerate with "
                "`python -m repro.analysis.audit --write-docs`")
    return drift


def write_docs() -> list[str]:
    """Rewrite the generated block in both docs; returns paths touched."""
    want = generate_tables()
    touched = []
    for path, (begin, end) in _doc_targets():
        with open(path) as f:
            text = f.read()
        pattern = re.escape(begin) + r"\n.*?" + re.escape(end)
        repl = f"{begin}\n{want}\n{end}"
        new, n = re.subn(pattern, lambda _m: repl, text, flags=re.DOTALL)
        if not n:
            raise ValueError(f"{path}: markers not found")
        if new != text:
            with open(path, "w") as f:
                f.write(new)
            touched.append(path)
    return touched


def run() -> dict:
    """Execute the pass: enumerate + doc drift."""
    matrix = enumerate_matrix()
    drift = check_docs()
    problems = matrix["problems"]
    status = "fail" if (problems or drift) else "ok"
    return {"status": status, "cells": matrix["cells"],
            "problems": problems, "drift": drift,
            "auto": {f"{p}/{m}/{mode}": impl
                     for (p, m, mode), impl in matrix["auto"].items()}}
