"""Static kernel auditor — proofs over jaxprs and lowered HLO.

Four passes, one verdict (``python -m repro.analysis.audit``):

  int_purity      no float transcendental (exp/log/erf/tanh/div/...)
                  computes on the dual-mode WORD lattice — the int
                  region between quantize and dequantize — in any
                  registered dualmode/dualmode_snap path.  Walked
                  interprocedurally over closed jaxprs, pallas kernel
                  bodies included.
  vmem            every pallas_call's static VMEM residency — the
                  kernel modules' declared ``vmem_plan()`` descriptors,
                  priced as 2x(in+out tiles) + scratch — fits
                  ``tiling.VMEM_CORE_BUDGET`` at every canonical grid
                  cell, and the declarations match the traced kernels'
                  actual ref avals.
  mesh_safety     each impl lowered under an emulated 8-device mesh
                  with a sequence-sharded KV cache must not all-gather
                  the whole cache per chip unless it DECLARED
                  ``mesh_safe=False`` (shared HLO walker:
                  ``launch.hlo_analysis.collective_result_bytes``).
  dispatch_table  the (attn_impl x softmax_impl x phase x mesh)
                  resolution matrix enumerates without surprise — every
                  cell resolves or raises intentionally, every registry
                  entry carries metadata, and the GENERATED table
                  embedded in ``kernels/dispatch.py`` and
                  ARCHITECTURE.md matches the live registry verbatim
                  (doc drift is a failing cell).

This package must import without jax so ``python -m
repro.analysis.audit`` can set XLA_FLAGS (emulated devices for the mesh
pass) before jax initializes — keep this ``__init__`` import-free; the
pass modules import jax lazily at call time.
"""
