"""One declarative validator for every machine-readable artifact.

The per-bench ``check_*_schema`` functions in benchmarks/bench_kernels.py
and the AUDIT.json check used to be (or would have become) N hand-rolled
assertion walks; this module is the single engine they all share.  A
schema is data:

  type            isinstance check (bool is NOT an int here)
  {k: spec}       dict with at least these keys, each value checked;
                  extra keys are allowed (artifacts may grow)
  [spec]          list/tuple, every element checked
  ("keys", spec)  dict with arbitrary keys, every VALUE checked
  ("any_of", *s)  first matching alternative wins
  ("eq", v)       exact value
  ("in", vs)      membership
  callable        predicate(value) -> True, or False/str (the error)

Cross-field invariants that don't fit a tree walk ride along as
``rules``: (description, predicate(whole_obj)) pairs.

``validate`` collects EVERY error and raises one AssertionError listing
them — a CI failure names all the drifted fields at once.
"""
from __future__ import annotations

import json

NUM = ("any_of", int, float)


def check(obj, spec, path: str = "$") -> list[str]:
    """All schema violations of ``obj`` against ``spec`` (empty = ok)."""
    if isinstance(spec, type):
        if spec in (int, float) and isinstance(obj, bool):
            return [f"{path}: expected {spec.__name__}, got bool"]
        if spec is float and isinstance(obj, int):
            return []
        if not isinstance(obj, spec):
            return [f"{path}: expected {spec.__name__}, "
                    f"got {type(obj).__name__}"]
        return []
    if isinstance(spec, tuple):
        tag = spec[0]
        if tag == "any_of":
            fails = []
            for alt in spec[1:]:
                errs = check(obj, alt, path)
                if not errs:
                    return []
                fails.extend(errs)
            return [f"{path}: no alternative matched "
                    f"({'; '.join(fails)})"]
        if tag == "eq":
            return ([] if obj == spec[1]
                    else [f"{path}: expected {spec[1]!r}, got {obj!r}"])
        if tag == "in":
            return ([] if obj in spec[1]
                    else [f"{path}: {obj!r} not in {sorted(spec[1])!r}"])
        if tag == "keys":
            if not isinstance(obj, dict):
                return [f"{path}: expected dict, got {type(obj).__name__}"]
            out = []
            for k, v in obj.items():
                out.extend(check(v, spec[1], f"{path}.{k}"))
            return out
        raise ValueError(f"unknown spec tag {tag!r} at {path}")
    if isinstance(spec, dict):
        if not isinstance(obj, dict):
            return [f"{path}: expected dict, got {type(obj).__name__}"]
        out = []
        for k, sub in spec.items():
            if k not in obj:
                out.append(f"{path}: missing key {k!r}")
            else:
                out.extend(check(obj[k], sub, f"{path}.{k}"))
        return out
    if isinstance(spec, list):
        if not isinstance(obj, (list, tuple)):
            return [f"{path}: expected list, got {type(obj).__name__}"]
        out = []
        for i, item in enumerate(obj):
            out.extend(check(item, spec[0], f"{path}[{i}]"))
        return out
    if callable(spec):
        try:
            res = spec(obj)
        except Exception as exc:
            return [f"{path}: predicate raised {exc!r}"]
        if res is True or res is None:
            return []
        return [f"{path}: {res if isinstance(res, str) else 'predicate failed'}"]
    raise ValueError(f"unintelligible spec {spec!r} at {path}")


def validate(obj, spec, rules=(), name: str = "object") -> None:
    """Raise AssertionError listing every schema/rule violation."""
    errors = check(obj, spec, "$")
    for desc, pred in rules:
        try:
            ok = pred(obj)
        except Exception as exc:
            ok = False
            desc = f"{desc} (rule raised {exc!r})"
        if not ok:
            errors.append(f"rule failed: {desc}")
    assert not errors, f"{name} schema violations:\n  " + "\n  ".join(errors)


def validate_file(path: str, spec, rules=(), name: str | None = None):
    with open(path) as fh:
        obj = json.load(fh)
    validate(obj, spec, rules, name or path)
    return obj


# ---------------------------------------------------------------------------
# bench artifact schemas (shared with benchmarks/bench_kernels.py)
# ---------------------------------------------------------------------------

FLASH_INT_SPEC = {
    "backend": str,
    "us_per_call": {"flash_pallas_int": NUM, "flash_pallas_int3": NUM},
    "sweeps_rows": [{"sweeps": int, "word_parity_residual": NUM}],
}
FLASH_INT_RULES = [
    ("both sweep counts {1, 3} present",
     lambda d: {r["sweeps"] for r in d["sweeps_rows"]} == {1, 3}),
    ("kernel words match the whole-row unit exactly (residual 0)",
     lambda d: all(float(r["word_parity_residual"]) == 0.0
                   for r in d["sweeps_rows"])),
]

DECODE_SPEC = {
    "backend": str,
    "cache_lens": [int],
    "splits": [int],
    "us_per_token": {"naive": ("keys", NUM),
                     "flash_decode": ("keys", ("keys", NUM))},
    "parity_max_abs_vs_naive": ("keys", NUM),
    "engine": {"tokens_per_s": {"naive": NUM, "flash_decode": NUM}},
}
DECODE_RULES = [
    ("at least one cache length swept", lambda d: len(d["cache_lens"]) > 0),
    ("at least one split count swept", lambda d: len(d["splits"]) > 0),
    ("naive timed at every cache length",
     lambda d: all(str(t) in d["us_per_token"]["naive"]
                   for t in d["cache_lens"])),
    ("flash_decode timed at every (cache length, split)",
     lambda d: all(str(n) in d["us_per_token"]["flash_decode"][str(t)]
                   for t in d["cache_lens"] for n in d["splits"])),
    ("split-KV decode matches naive to 1e-5 at every length",
     lambda d: all(float(d["parity_max_abs_vs_naive"][str(t)]) <= 1e-5
                   for t in d["cache_lens"])),
    ("both engine impls made positive tokens/sec",
     lambda d: all(v > 0 for v in d["engine"]["tokens_per_s"].values())),
]

_MODE_SPEC = {"tokens": int, "tokens_per_s": NUM, "cache_copies": int,
              "concurrent_hwm": int}
_PRESSURE_MODE_SPEC = {"tokens": int, "tokens_per_s": NUM,
                       "concurrent_hwm": int, "preemptions": int,
                       "unterminated": int, "leaked_blocks": int}
SERVE_SPEC = {
    "backend": str,
    "interpret": bool,
    "equal_hbm_tokens": int,
    "modes": {"paged": _MODE_SPEC, "contiguous": _MODE_SPEC},
    "mixed_phase": {"tokens": int, "tokens_per_s": NUM,
                    "decode_attn_impl": ("eq", "flash_decode"),
                    "decode_softmax_impl": ("eq", "dualmode"),
                    "prefill_softmax_impl": ("eq", "float")},
    "pressure": {"num_blocks": int, "worst_case_demand": int,
                 "modes": {"worst_case": _PRESSURE_MODE_SPEC,
                           "reactive": _PRESSURE_MODE_SPEC}},
}
SERVE_RULES = [
    ("both modes produced tokens at positive throughput",
     lambda d: all(m["tokens"] > 0 and m["tokens_per_s"] > 0
                   for m in d["modes"].values())),
    ("paged and contiguous ran the same workload",
     lambda d: d["modes"]["paged"]["tokens"]
     == d["modes"]["contiguous"]["tokens"]),
    ("paged admission never copied a cache",
     lambda d: d["modes"]["paged"]["cache_copies"] == 0),
    ("contiguous admission did copy (the cost paged removes)",
     lambda d: d["modes"]["contiguous"]["cache_copies"] > 0),
    ("paged out-batches contiguous at equal HBM",
     lambda d: d["modes"]["paged"]["concurrent_hwm"]
     > d["modes"]["contiguous"]["concurrent_hwm"]),
    ("block pool actually used",
     lambda d: (d["modes"]["paged"].get("blocks_hwm") or 0) > 0),
    ("prefix sharing found at least one shared block",
     lambda d: (d["modes"]["paged"].get("shared_blocks") or 0) > 0),
    ("decode does not stall during chunked prefill",
     lambda d: (d["modes"]["paged"].get("decode_ticks_per_prefill_step")
                or 0) >= 1.0),
    ("mixed-phase engine produced tokens",
     lambda d: d["mixed_phase"]["tokens"] > 0
     and d["mixed_phase"]["tokens_per_s"] > 0),
    ("pressure pool really was under worst-case demand",
     lambda d: d["pressure"]["num_blocks"]
     < d["pressure"]["worst_case_demand"]),
    ("reactive+preempt reaches strictly higher concurrency than "
     "worst-case reservation at the same pool",
     lambda d: d["pressure"]["modes"]["reactive"]["concurrent_hwm"]
     > d["pressure"]["modes"]["worst_case"]["concurrent_hwm"]),
    ("preemption invisible in output: equal tokens under pressure",
     lambda d: d["pressure"]["modes"]["reactive"]["tokens"]
     == d["pressure"]["modes"]["worst_case"]["tokens"]),
    ("every request terminated under pressure, zero blocks leaked",
     lambda d: all(m["unterminated"] == 0 and m["leaked_blocks"] == 0
                   for m in d["pressure"]["modes"].values())),
    ("pressure actually bit: reactive preempted or blocked admission",
     lambda d: (d["pressure"]["modes"]["reactive"]["preemptions"]
                + d["pressure"]["modes"]["reactive"].get("admit_blocked",
                                                         0)) > 0),
]

_SEAM_SPEC = {"dense_hbm_bytes": int, "fused_hbm_bytes": int,
              "saved_bytes": int, "us_dense": NUM, "us_fused": NUM,
              "parity_max_abs": NUM}
BLOCK_SPEC = {
    "backend": str,
    "interpret": bool,
    "shape": {"m": int, "d": int, "f": int},
    "norm_kind": ("in", {"rms", "layer"}),
    "seams": {"attn_qkv_prologue": _SEAM_SPEC,
              "attn_out_epilogue": _SEAM_SPEC,
              "ffn_glu_prologue": _SEAM_SPEC},
    "block_total": {"dense_hbm_bytes": int, "fused_hbm_bytes": int,
                    "saved_bytes": int, "saved_frac": NUM},
}
# parity bars: the residual-add epilogue is pure elementwise after the
# norm, so it holds the pinned 1e-5 dense-contract bar; the matmul
# prologues reassociate the contraction inside the kernel, so their bar
# is the small-ULP 5e-5 (same reasoning as the fused-FFN parity bar)
BLOCK_RULES = [
    ("every fused seam saves HBM traffic (saved_bytes > 0)",
     lambda d: all(s["saved_bytes"] > 0 for s in d["seams"].values())),
    ("saved_bytes = dense - fused per seam",
     lambda d: all(s["saved_bytes"]
                   == s["dense_hbm_bytes"] - s["fused_hbm_bytes"]
                   for s in d["seams"].values())),
    ("residual+norm epilogue holds the pinned dense contract (<= 1e-5)",
     lambda d: float(d["seams"]["attn_out_epilogue"]["parity_max_abs"])
     <= 1e-5),
    ("matmul prologues within small-ULP reassociation (<= 5e-5)",
     lambda d: all(float(d["seams"][s]["parity_max_abs"]) <= 5e-5
                   for s in ("attn_qkv_prologue", "ffn_glu_prologue"))),
    ("block totals are the sum of the seam rows",
     lambda d: d["block_total"]["saved_bytes"]
     == sum(s["saved_bytes"] for s in d["seams"].values())
     and d["block_total"]["dense_hbm_bytes"]
     == sum(s["dense_hbm_bytes"] for s in d["seams"].values())),
    ("saved fraction consistent and positive",
     lambda d: 0.0 < float(d["block_total"]["saved_frac"]) < 1.0),
]


def check_block_json(path: str) -> dict:
    """Validate BENCH_block.json (the per-seam HBM-traffic artifact the
    block bench writes) through the shared engine."""
    return validate_file(path, BLOCK_SPEC, BLOCK_RULES, "BENCH_block.json")


# ---------------------------------------------------------------------------
# AUDIT.json (the auditor's own artifact goes through the same engine)
# ---------------------------------------------------------------------------

_STATUS = ("in", {"ok", "fail", "skipped"})
AUDIT_SPEC = {
    "generated_by": str,
    "strict": bool,
    "ok": bool,
    "passes": {
        "int_purity": {"status": _STATUS, "checked": [str],
                       "violations": [{"path": str, "prim": str,
                                       "where": str}]},
        "vmem": {"status": _STATUS, "over_budget": int,
                 "trace_mismatches": [str],
                 "cells": [{"kernel": str, "call": str, "cell": str,
                            "bytes": int, "budget": int, "ok": bool}]},
        "mesh_safety": {"status": _STATUS,
                        "impls": [{"impl": str, "ok": bool,
                                   "declared_mesh_safe": bool,
                                   "whole_cache_gather": bool,
                                   "largest_gather_bytes": int,
                                   "full_kv_bytes": int}]},
        "dispatch_table": {"status": _STATUS, "cells": int,
                           "problems": [str], "drift": [str]},
    },
}
# coverage floors apply only to passes CLAIMING "ok" — a failing pass
# (e.g. a seeded --fixture run over one subject) already did its job
AUDIT_RULES = [
    ("ok iff no pass failed",
     lambda a: a["ok"] == all(p["status"] != "fail"
                              for p in a["passes"].values())),
    ("an ok purity pass walked at least the unit + kernel paths",
     lambda a: a["passes"]["int_purity"]["status"] != "ok"
     or len(a["passes"]["int_purity"]["checked"]) >= 6),
    ("an ok vmem pass priced the whole grid",
     lambda a: a["passes"]["vmem"]["status"] != "ok"
     or len(a["passes"]["vmem"]["cells"]) >= 10),
    ("an ok dispatch pass enumerated the full matrix",
     lambda a: a["passes"]["dispatch_table"]["status"] != "ok"
     or a["passes"]["dispatch_table"]["cells"] >= 100),
]


def check_audit_json(path: str) -> dict:
    """Validate AUDIT.json through the shared engine (bench smokes call
    this with ``--check-audit``)."""
    return validate_file(path, AUDIT_SPEC, AUDIT_RULES, "AUDIT.json")
