"""Canonical shape grid the audit passes sweep.

One place pins WHICH shapes count as "the" workload, so every pass
audits the same cells and the report rows line up: the three serving
phases (encoder block, long prefill, single-row decode against a long
cache) crossed with the two mesh situations (single device, 8-way KV
ring).  Head extents stay small — the passes prove STRUCTURAL facts
(taint, residency, collectives, resolution), none of which depend on
the head dim, and small heads keep the jaxpr traces fast on CPU.
"""
from __future__ import annotations

# (s_q, t_kv) per serving phase — the resolution-relevant extents.
# decode crosses tiling.DECODE_FLASH_MIN_KV so 'auto' actually reaches
# the split-KV kernel; prefill crosses the use_flash threshold.
PHASES: dict[str, tuple[int, int]] = {
    "enc": (128, 128),
    "prefill": (4096, 4096),
    "decode": (1, 65536),
}

# mesh name -> axis sizes for dispatch.analysis_mesh (None = no mesh)
MESHES: dict[str, dict[str, int] | None] = {
    "none": None,
    "ring8": {"ring": 8},
}

RING_AXIS = "ring"

MODES = ("float", "dualmode", "dualmode_snap")

# head geometry shared by every attention cell (GQA group of 2 so the
# g-dependent scratch rows are exercised, MLA-style hv == hd kept equal
# for simplicity — vmem_plan is audited per (hd, hv) pair anyway)
HEAD = {"hd": 8, "hv": 8, "g": 2}

# trace cell: small extents for make_jaxpr-based passes (purity, the
# vmem declared-vs-traced cross-check).  Big enough that the blocked
# kernels take their real multi-tile grid (bq=128, bkv=256).
TRACE_SQ, TRACE_T = 256, 256

# FFN / row-softmax / norm-seam cells for the vmem pass
FFN_CELL = {"m": 4096, "k": 1024, "f": 4096}
SOFTMAX_CELL = {"rows": 4096, "cols": 4096}
NORM_CELL = {"m": 4096, "d": 1024, "f": 4096}


def attention_cells() -> list[dict]:
    """One vmem-audit cell per (phase, head geometry)."""
    return [dict(phase=name, s_q=sq, t_kv=t, **HEAD)
            for name, (sq, t) in PHASES.items()]
