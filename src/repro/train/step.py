"""Loss + train-step factory: remat, microbatch accumulation, grad
compression, and sharding-aware jit wiring.

The step is a pure function (TrainState, batch) -> (TrainState, metrics);
all distribution comes from the in/out shardings installed by
`jit_train_step` (GSPMD turns the data-parallel gradient mean into
reduce-scatter/all-reduce, tensor-parallel matmuls into collective
schedules — nothing torch.distributed-like lives in the step itself).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.distributed import batch_pspec, data_axes, param_pspecs
from repro.models.accounting import pick_profile
from repro.models.transformer import (encoder_apply, init_lm, lm_apply,
                                      lm_head_weight)
from repro.optim import (OptState, adamw_init, adamw_update,
                         compress_decompress, ef_state_init, wsd_schedule)

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt: OptState
    ef: Params          # grad-compression residuals ({} when disabled)


def chunked_ce(h, head_w, labels, *, target_chunks: int = 8,
               dp=None, sp=None, dp_size: int = 1):
    """Mean next-token CE without materializing (B,S,vocab) logits.

    At train_4k the full logits tensor is global_batch·seq·vocab ~ 1e11
    floats — hundreds of TB; it CANNOT exist at any sharding.  We scan
    over BATCH chunks (not sequence chunks: splitting the seq dim would
    break its 'model' sequence-parallel sharding and every device would
    recompute the full global head — measured 50x flops bloat).  Chunk
    size stays divisible by the dp group (`dp_size`) so the split is
    shard-aligned, and explicit constraints keep (dp, sp) pinned inside
    the scan.  Peak extra memory: chunk·S·vocab / n_devices, freed per
    scan step (the jax.checkpoint recomputes logits in backward).
    """
    b, s, d = h.shape
    nc = min(target_chunks, b)
    while b % nc or (b // nc) % dp_size:
        nc -= 1
    bc = b // nc
    hc = h.reshape(nc, bc, s, d)
    lc = labels.reshape(nc, bc, s)
    if dp is not None or sp is not None:
        hc = jax.lax.with_sharding_constraint(hc, P(None, dp, sp, None))
        lc = jax.lax.with_sharding_constraint(lc, P(None, dp, sp))

    @jax.checkpoint
    def one(tot, xs):
        hh, ll = xs
        logits = (hh @ head_w).astype(jnp.float32)           # (bc,S,V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    tot, _ = jax.lax.scan(one, jnp.zeros((), jnp.float32), (hc, lc))
    return tot / (b * s)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh | None = None,
                 aux_weight: float = 0.01):
    """Cross-entropy (+ MoE load-balance aux) over a batch dict.

    With a mesh given, the residual stream is pinned to
    (dp, 'model', None) — Megatron-style sequence parallelism, so the
    per-period remat carry is stored seq-sharded."""
    profile = (pick_profile(cfg) if tcfg.profile == "auto"
               else tcfg.profile)

    def loss_fn(params, batch):
        act_pspec, dp_ax, sp_ax, dp_size = None, None, None, 1
        if mesh is not None and "model" in mesh.axis_names:
            b, s = batch["tokens"].shape
            pools = []
            if profile == "dp":      # idle 'model' joins the DP group
                pools.append(data_axes(mesh) + ("model",))
            pools.append(data_axes(mesh))
            for pool in pools:
                dsize = 1
                for a in pool:
                    dsize *= mesh.shape[a]
                if pool and b % dsize == 0:
                    dp_ax = pool if len(pool) > 1 else pool[0]
                    dp_size = dsize
                    break
            # SP whenever 'model' is not already consumed by the batch —
            # under the dp profile an idle model axis would otherwise
            # DUPLICATE the compute on every model rank (measured 6-16x)
            model_free = not (isinstance(dp_ax, tuple) and "model" in dp_ax)
            if (s % mesh.shape["model"] == 0 and tcfg.seq_shard
                    and model_free):
                sp_ax = "model"
            act_pspec = P(dp_ax, sp_ax, None)
        cross_src = None
        if "frames" in batch:                      # enc-dec stub frontend
            cross_src = encoder_apply(params, cfg, batch["frames"])
        elif "image_embeds" in batch:              # VLM stub frontend
            cross_src = batch["image_embeds"]
        h, _, aux = lm_apply(params, cfg, batch["tokens"],
                             cross_src=cross_src, remat=tcfg.remat,
                             act_pspec=act_pspec, return_hidden=True,
                             inner_pins=tcfg.inner_pins,
                             remat_mode=tcfg.remat_mode)
        ce = chunked_ce(h, lm_head_weight(params, cfg), batch["labels"],
                        dp=dp_ax, sp=sp_ax, dp_size=dp_size)
        return ce + aux_weight * aux, (ce, aux)
    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    mesh: Mesh | None = None):
    loss_fn = make_loss_fn(cfg, tcfg, mesh)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        lr = wsd_schedule(state.opt.step, lr=tcfg.lr,
                          warmup=tcfg.warmup_steps, total=tcfg.total_steps)
        if tcfg.microbatch:
            b = batch["tokens"].shape[0]
            n_acc = b // tcfg.microbatch
            micro = jax.tree.map(
                lambda x: x.reshape(n_acc, tcfg.microbatch, *x.shape[1:]),
                batch)

            def acc(carry, mb):
                g_sum, ce_sum, aux_sum = carry
                (_, (ce, aux)), g = grad_fn(state.params, mb)
                g_sum = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_sum, g)
                return (g_sum, ce_sum + ce, aux_sum + aux), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (grads, ce, aux), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros(()), jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / n_acc, grads)
            ce, aux = ce / n_acc, aux / n_acc
        else:
            (_, (ce, aux)), grads = grad_fn(state.params, batch)

        ef = state.ef
        if tcfg.grad_compress:
            grads, ef = compress_decompress(grads, ef)

        params, opt, om = adamw_update(
            grads, state.opt, state.params, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        metrics = {"loss": ce + 0.01 * aux, "ce": ce, "aux": aux,
                   "grad_norm": om["grad_norm"], "lr": lr}
        return TrainState(params, opt, ef), metrics

    return train_step


# ---------------- sharding-aware state construction ----------------

def state_pspecs(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                 dtype=jnp.float32) -> tuple[TrainState, TrainState]:
    """(state ShapeDtypeStructs, state PartitionSpecs) — no allocation.

    'dp' profile (small models): params replicated, optimizer moments
    ZeRO-1-sharded over 'data' (they are 4x the bf16 params and have no
    per-step latency role)."""
    profile = (pick_profile(cfg) if tcfg.profile == "auto"
               else tcfg.profile)
    p_sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg, dtype))
    p_spec = param_pspecs(p_sds, mesh, fsdp=tcfg.fsdp, profile=profile)
    # optimizer moments are always ZeRO-1 sharded over 'data' on top of
    # the param layout: they are 4x the bf16 params, off the latency path
    m_spec = param_pspecs(p_sds, mesh, fsdp=True, profile=profile)
    opt_sds = jax.eval_shape(adamw_init, p_sds)
    opt_spec = OptState(m=m_spec, v=m_spec, step=P())
    ef_sds = jax.eval_shape(ef_state_init, p_sds) if tcfg.grad_compress else {}
    ef_spec = m_spec if tcfg.grad_compress else {}
    return (TrainState(p_sds, opt_sds, ef_sds),
            TrainState(p_spec, opt_spec, ef_spec))


def make_train_state(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                     seed: int | None = None, dtype=jnp.float32):
    """Allocate a sharded TrainState on `mesh` (jit'd init -> no host copy).

    Returns (state, state_shardings)."""
    seed = tcfg.seed if seed is None else seed
    _, spec = state_pspecs(cfg, tcfg, mesh, dtype)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                             is_leaf=lambda x: isinstance(x, P))

    def build():
        params = init_lm(jax.random.PRNGKey(seed), cfg, dtype)
        ef = ef_state_init(params) if tcfg.grad_compress else {}
        return TrainState(params, adamw_init(params), ef)

    with mesh:
        state = jax.jit(build, out_shardings=shardings)()
    return state, shardings


def jit_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                   global_batch: int, dtype=jnp.float32):
    """jit the step with explicit in/out shardings + donated state."""
    _, spec = state_pspecs(cfg, tcfg, mesh, dtype)
    bspec = batch_pspec(mesh, global_batch)
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                            is_leaf=lambda x: isinstance(x, P))
    step = make_train_step(cfg, tcfg, mesh)
    return jax.jit(step,
                   in_shardings=(state_sh, NamedSharding(mesh, bspec)),
                   out_shardings=(state_sh, None),
                   donate_argnums=(0,))
