from .step import TrainState, make_loss_fn, make_train_step, make_train_state
from .trainer import Trainer
