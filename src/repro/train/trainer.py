"""Trainer: the fault-tolerant training driver.

Responsibilities (DESIGN.md §5):
  * checkpoint/restart — async sharded saves every `checkpoint_every`
    steps; on construction the trainer auto-resumes from the newest
    complete checkpoint in `tcfg.checkpoint_dir` (crash -> relaunch ->
    continue, with the data pipeline replaying deterministically from the
    restored step).
  * straggler monitor  — per-step wall time vs a P50 watermark (EMA);
    steps slower than `straggler_factor`x are counted and logged.  On a
    real fleet this signal feeds the launcher's replace-node path; here it
    is surfaced in metrics and asserted on by tests.
  * elastic remesh     — `Trainer.from_checkpoint(new_mesh)` restores any
    checkpoint onto a different mesh/device count (gathered-leaf store +
    fresh `param_pspecs` = resharding on restore).
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.checkpoint import CheckpointStore, latest_step
from repro.configs.base import ModelConfig, TrainConfig
from repro.data import SyntheticLM
from repro.distributed import batch_pspec
from repro.launch.mesh import auto_mesh
from .step import (TrainState, jit_train_step, make_train_state,
                   state_pspecs)


def default_mesh() -> Mesh:
    return auto_mesh((len(jax.devices()),), ("data",))


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 global_batch: int, seq_len: int, *,
                 mesh: Mesh | None = None, dtype=jnp.float32,
                 data: SyntheticLM | None = None,
                 straggler_factor: float = 1.5,
                 log: Callable[[str], None] = print,
                 resume: bool = True):
        self.cfg, self.tcfg = cfg, tcfg
        self.mesh = mesh or default_mesh()
        self.dtype = dtype
        self.global_batch, self.seq_len = global_batch, seq_len
        self.data = data or SyntheticLM(vocab=cfg.vocab, seq_len=seq_len,
                                        global_batch=global_batch,
                                        seed=tcfg.seed)
        self.log = log
        self.straggler_factor = straggler_factor
        self.store = CheckpointStore(tcfg.checkpoint_dir)
        self.step_fn = jit_train_step(cfg, tcfg, self.mesh, global_batch,
                                      dtype)
        self._bsharding = NamedSharding(self.mesh,
                                        batch_pspec(self.mesh, global_batch))
        self.start_step = 0
        if resume and latest_step(tcfg.checkpoint_dir) is not None:
            self.state, self.start_step = self._restore()
            self.log(f"[trainer] resumed from step {self.start_step}")
        else:
            self.state, _ = make_train_state(cfg, tcfg, self.mesh,
                                             dtype=dtype)
        # telemetry
        self.step_times: list[float] = []
        self.straggler_steps: list[int] = []
        self._ema: float | None = None

    # ---------------- fault tolerance ----------------

    def _restore(self) -> tuple[TrainState, int]:
        sds, spec = state_pspecs(self.cfg, self.tcfg, self.mesh, self.dtype)
        sh = jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec,
                          is_leaf=lambda x: isinstance(x, PartitionSpec))
        state, step, _ = self.store.restore(sds, shardings=sh)
        return state, step

    @classmethod
    def from_checkpoint(cls, cfg, tcfg, global_batch, seq_len, *,
                        mesh: Mesh, **kw) -> "Trainer":
        """Elastic restart: restore the latest checkpoint onto a NEW mesh
        (different device count / axis shape)."""
        return cls(cfg, tcfg, global_batch, seq_len, mesh=mesh, resume=True,
                   **kw)

    def save(self, step: int, block: bool = True) -> None:
        self.store.save(step, self.state, block=block,
                        extra={"arch": self.cfg.name})

    # ---------------- main loop ----------------

    def run(self, n_steps: int | None = None) -> dict[str, Any]:
        end = self.tcfg.total_steps if n_steps is None \
            else self.start_step + n_steps
        metrics = {}
        for step in range(self.start_step, end):
            tokens, labels = self.data.batch(step)
            batch = {"tokens": jax.device_put(tokens, self._bsharding),
                     "labels": jax.device_put(labels, self._bsharding)}
            t0 = time.perf_counter()
            with self.mesh:     # sharding constraints resolve at trace time
                self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self._watch_straggler(step, dt)
            if (step + 1) % self.tcfg.checkpoint_every == 0:
                self.save(step + 1, block=False)
            if step % 10 == 0 or step == end - 1:
                self.log(f"[trainer] step {step} loss={metrics['loss']:.4f} "
                         f"gnorm={metrics['grad_norm']:.2f} {dt*1e3:.0f}ms")
        self.store.wait()
        self.start_step = end
        return metrics

    def _watch_straggler(self, step: int, dt: float) -> None:
        self.step_times.append(dt)
        if self._ema is None:
            self._ema = dt
            return
        if dt > self.straggler_factor * self._ema and len(self.step_times) > 3:
            self.straggler_steps.append(step)
            self.log(f"[trainer] STRAGGLER step {step}: {dt*1e3:.0f}ms vs "
                     f"EMA {self._ema*1e3:.0f}ms")
        self._ema = 0.9 * self._ema + 0.1 * dt
