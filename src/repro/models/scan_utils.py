"""Chunked sequential scans for recurrent mixers (Mamba / RWKV).

A plain `jax.lax.scan` over S timesteps saves its carry at EVERY step for
the backward pass — for Mamba's (B, d_inner, d_state) f32 state at
train_4k that is S x 134 MB ~ 0.5 TB per layer, which no sharding can
absorb.  The standard fix is two-level: scan over S/Q chunks whose body
(a Q-step inner scan) is `jax.checkpoint`ed.  Saved residuals drop to the
S/Q chunk-boundary states; the inner Q steps are recomputed during
backward (the same compute/memory trade Mamba's chunked CUDA kernels
make — this is the TPU/XLA-native expression of it).
"""
from __future__ import annotations

import jax


def chunked_time_scan(step, h0, xs, chunk: int = 64):
    """scan(step, h0, xs) with chunk-boundary checkpointing.

    xs: pytree of time-major (S, ...) arrays; returns (h_final, ys) with
    ys time-major, exactly like jax.lax.scan.  Falls back to a plain scan
    when S is small or indivisible.
    """
    s_len = jax.tree.leaves(xs)[0].shape[0]
    if s_len <= chunk or s_len % chunk:
        return jax.lax.scan(step, h0, xs)
    nc = s_len // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape(nc, chunk, *a.shape[1:]), xs)

    @jax.checkpoint
    def body(h, xc):
        return jax.lax.scan(step, h, xc)

    h, ys = jax.lax.scan(body, h0, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(s_len, *a.shape[2:]), ys)
    return h, ys
