"""Analytic parameter / flop accounting (shared by launch, train, bench)."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig, ShapeCell
from .transformer import init_lm


def count_params(cfg: ModelConfig) -> dict[str, float]:
    """Analytic param counts from the init tree (no allocation).

    n_matmul: params that participate in matmuls (excl. embed/pos gathers,
              incl. the tied head once as a matmul operand)
    n_active: n_matmul with routed-expert stacks scaled to top_k experts
    """
    p_sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    n_total = n_matmul = n_active = 0.0

    def visit(path, leaf):
        nonlocal n_total, n_matmul, n_active
        names = [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]
        n = 1.0
        for d in leaf.shape:
            n *= d
        n_total += n
        if leaf.ndim < 2 or names[-1] in ("embed", "pos"):
            return
        n_matmul += n
        moe = cfg.moe
        stack_sizes = {moe.n_experts, moe.ep_pad} if moe else set()
        # expert dim is leaf dim 0, or dim 1 under the stacked-period axis
        e_dim = next((d for d in leaf.shape[:2] if d in stack_sizes), None)
        if (moe and "ffn" in names and names[-1] in ("gate", "up", "down")
                and leaf.ndim >= 3 and e_dim):
            # top_k live experts out of the (possibly padded) stack
            n_active += n * (moe.top_k / e_dim)
        else:
            n_active += n

    jax.tree_util.tree_map_with_path(visit, p_sds)
    if cfg.tie_embeddings:           # tied head IS a matmul operand
        n_matmul += cfg.vocab * cfg.d_model
        n_active += cfg.vocab * cfg.d_model
    return {"n_total": n_total, "n_matmul": n_matmul, "n_active": n_active}


def analytic_model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """GLOBAL 'useful' flops per step: 6·N_active·D train / 2·N_active·D
    inference (D = tokens this step).  Attention's quadratic term is
    deliberately excluded — the MODEL_FLOPS/HLO_FLOPs ratio then exposes
    both remat recompute AND quadratic-attention overhead."""
    n = count_params(cfg)["n_active"]
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch          # decode: one token/row


# params below this replicate rather than TP: at 0.1-2B the tensor-
# parallel shards are too thin (d/16 < 512) and every step drowns in
# layer-wise all-gathers — measured 12-30x collective overhead on
# qwen1.5-0.5b / whisper-base (EXPERIMENTS.md §Perf).
DP_PROFILE_MAX_PARAMS = 1.7e9


def pick_profile(cfg: ModelConfig) -> str:
    return "dp" if count_params(cfg)["n_total"] <= DP_PROFILE_MAX_PARAMS \
        else "tp"
