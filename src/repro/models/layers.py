"""Primitive layers — pure-JAX pytree modules (init fn + apply fn).

Conventions:
  * params are nested dicts of jnp arrays; init fns take (key, ...) and a
    dtype; apply fns are pure.
  * activations / softmax go through ``repro.core`` selections so the
    paper's dual-mode unit is a config switch, not a code fork.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.activations import get_activation
from repro.kernels import datapath as dp
from repro.kernels import dispatch
from repro.kernels import fused_ffn as _fused_ffn  # noqa: F401  (registers)

Params = dict[str, Any]


# ---------------- init helpers ----------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / math.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------- linear ----------------

def linear_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    p = {"w": dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------- norms ----------------
#
# Thin wrappers over the datapath's single float definitions
# (kernels/datapath.rmsnorm / .layernorm).  The numeric contract lives
# there: moments AND gain/bias entirely in f32, ONE downcast on the
# finished result (applied here).  ``eps`` is required — call sites must
# thread cfg.norm_eps so nothing drifts from the config value.

def rmsnorm_init(d: int, dtype) -> Params:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x, eps: float):
    return dp.rmsnorm(x, p["g"], eps).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x, eps: float):
    return dp.layernorm(x, p["g"], p["b"], eps).astype(x.dtype)


def make_norm(kind: str):
    if kind == "rms":
        return rmsnorm_init, rmsnorm
    if kind == "layer":
        return layernorm_init, layernorm
    raise ValueError(kind)


# ---------------- rotary embedding ----------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd) rotate-half RoPE; positions: (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                   # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv       # (..,S,hd/2)
    cos = jnp.cos(ang)[..., None, :]                              # (..,S,1,hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(n_pos: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------- softmax selection ----------------

def softmax_fn(impl: str):
    """Attention-softmax implementation switch (kernels/dispatch registry)."""
    return dispatch.get_softmax(impl)


# ---------------- MLPs ----------------

def mlp_init(key, d: int, d_ff: int, dtype, gated: bool = True,
             bias: bool = False) -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": linear_init(ks[0], d, d_ff, dtype, bias=bias),
         "down": linear_init(ks[1], d_ff, d, dtype, bias=bias)}
    if gated:
        p["gate"] = linear_init(ks[2], d, d_ff, dtype, bias=bias)
    return p


# activations the fused epilogue (datapath.pair_act, float log-domain
# form) agrees with MATHEMATICALLY — gelu_tanh is the tanh-form identity
# tanh(k) = 2*sigma(2k)-1 of the same curve, not the same instruction
# sequence, so fused-vs-dense parity is a small-ULP tolerance, not
# bitwise (pinned per entry in tests/test_fused_ffn.py).  Anything else —
# relu2, the bit-accurate dualmode/igelu variants, erf-exact GELU — must
# stay on the dense path rather than be silently approximated.
_FUSABLE_ACT = {"gelu_tanh": "gelu", "gelu_via_softmax": "gelu",
                "silu": "silu", "silu_via_softmax": "silu"}


def mlp(p: Params, x, activation: str = "silu", impl: str = "dense",
        prenorm=None, norm_impl: str = "dense"):
    """(Gated) MLP.  For gated GLU the activation applies to the gate path —
    this is where the dual-mode unit's GELU/SiLU mode is used.

    ``impl`` resolves through the kernel registry: 'dense' is the plain
    XLA graph; 'fused_pallas' runs the bias-free gated pair through the
    fused matmul+epilogue kernel (kernels/fused_ffn.py) when the
    activation is one the fused epilogue computes exactly; 'auto' picks
    'fused_pallas' on TPU and 'dense' elsewhere (dispatch.resolve_ffn).

    ``prenorm=(norm_params, kind, eps)`` makes this sublayer own its norm
    seam: with a fused norm provider (``norm_impl``, fusable activation,
    bias-free gate/up) the norm->gate/up prologue runs as ONE Pallas
    kernel (kernels/fused_norm.norm_glu); otherwise the dense norm is
    applied here and the body proceeds unchanged."""
    fused = dispatch.get_ffn(dispatch.resolve_ffn(impl))
    mode = _FUSABLE_ACT.get(activation)
    if prenorm is not None:
        np_, kind, eps = prenorm
        nprov = dispatch.get_norm(dispatch.resolve_norm(norm_impl))
        if (nprov is not None and mode is not None and "gate" in p
                and "b" not in p["gate"] and "b" not in p["up"]):
            h = nprov["norm_glu"](x, np_["g"], np_.get("b"),
                                  p["gate"]["w"], p["up"]["w"],
                                  kind=kind, eps=eps, mode=mode)
            return linear(p["down"], h)
        x = (rmsnorm if kind == "rms" else layernorm)(np_, x, eps)
    if (fused is not None and mode is not None and "gate" in p
            and "b" not in p["gate"] and "b" not in p["up"]):
        x2 = x.reshape(-1, x.shape[-1])
        h = fused(x2, p["gate"]["w"], p["up"]["w"], mode)
        return linear(p["down"], h.reshape(*x.shape[:-1], h.shape[-1]))
    act = get_activation(activation)
    up = linear(p["up"], x)
    if "gate" in p:
        h = act(linear(p["gate"], x)) * up
    else:
        h = act(up)
    return linear(p["down"], h)
