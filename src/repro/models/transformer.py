"""Unified transformer stack: every assigned architecture runs through this
one scan-over-periods decoder (plus an encoder stack for enc-dec models).

The repeating unit is `cfg.pattern` (a tuple of LayerSpec); parameters for
the `n_periods` repetitions are stacked on a leading axis and consumed by
`jax.lax.scan`, which keeps HLO size O(period) instead of O(layers) — this
is what makes 62-layer MiniCPM3 / 40-layer Qwen3 lower-and-compile fast for
the 80-cell dry-run matrix.

Modes:
  train   — no caches, full causal (or bidirectional for encoders)
  prefill — writes KV/state caches from position 0, returns caches
  decode  — consumes one new token per call at traced position `pos`
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.kernels import dispatch
from .attention import (AttnSpec, MLASpec, cross_apply, cross_init, cross_kv,
                        gqa_apply, gqa_cache_init, gqa_init, mla_apply,
                        mla_cache_init, mla_init)
from .layers import (Params, embed_init, linear_init, make_norm, mlp,
                     mlp_init, sinusoidal_pos_emb)
from .mamba import MambaSpec, mamba_apply, mamba_init, mamba_state_init
from .moe import MoESpec, moe_apply, moe_init
from .rwkv import (RWKVSpec, rwkv_channel_mix, rwkv_cm_init, rwkv_state_init,
                   rwkv_time_mix, rwkv_tm_init)


# ---------------- spec builders ----------------

def attn_spec(cfg: ModelConfig, causal: bool | None = None) -> AttnSpec:
    return AttnSpec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                    qk_norm=cfg.qk_norm, qkv_bias=cfg.qkv_bias,
                    rope_theta=cfg.rope_theta, softmax_impl=cfg.softmax_impl,
                    causal=cfg.causal if causal is None else causal,
                    use_rope=cfg.use_rope, attn_impl=cfg.attn_impl,
                    ring_axis=cfg.ring_axis, norm_eps=cfg.norm_eps)


def mla_spec(cfg: ModelConfig) -> MLASpec:
    m = cfg.mla
    return MLASpec(cfg.d_model, cfg.n_heads, m.q_lora_rank, m.kv_lora_rank,
                   m.nope_dim, m.rope_dim, m.v_dim,
                   rope_theta=cfg.rope_theta, softmax_impl=cfg.softmax_impl,
                   attn_impl=cfg.attn_impl, ring_axis=cfg.ring_axis,
                   norm_eps=cfg.norm_eps)


def mamba_spec(cfg: ModelConfig) -> MambaSpec:
    m = cfg.mamba
    return MambaSpec(cfg.d_model, m.d_inner, m.d_state, m.d_conv, m.dt_rank)


def rwkv_spec(cfg: ModelConfig) -> RWKVSpec:
    return RWKVSpec(cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.rwkv_lora_r)


def moe_spec(cfg: ModelConfig) -> MoESpec:
    m = cfg.moe
    return MoESpec(cfg.d_model, m.d_ff, m.n_experts, m.top_k, m.n_shared,
                   m.capacity_factor, cfg.activation, cfg.ffn_impl,
                   cfg.moe_dispatch, ep_pad=m.ep_pad)


# ---------------- block ----------------

def block_init(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> Params:
    norm_init, _ = make_norm(cfg.norm)
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": norm_init(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["mixer"] = gqa_init(ks[0], attn_spec(cfg), dtype)
    elif spec.mixer == "mla":
        p["mixer"] = mla_init(ks[0], mla_spec(cfg), dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_init(ks[0], mamba_spec(cfg), dtype)
    elif spec.mixer == "rwkv":
        p["mixer"] = rwkv_tm_init(ks[0], rwkv_spec(cfg), dtype)
    elif spec.mixer != "none":
        raise ValueError(spec.mixer)
    if spec.cross:
        p["cross_norm"] = norm_init(cfg.d_model, dtype)
        p["cross"] = cross_init(ks[1], attn_spec(cfg, causal=False), dtype)
        p["cross_gate"] = jnp.zeros((), dtype)     # tanh-gated (llama-vision)
    if spec.ffn != "none":
        p["norm2"] = norm_init(cfg.d_model, dtype)
    if spec.ffn == "mlp":
        p["ffn"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype,
                            gated=cfg.gated_mlp)
    elif spec.ffn == "moe":
        p["ffn"] = moe_init(ks[2], moe_spec(cfg), dtype)
    elif spec.ffn == "rwkv_cm":
        p["ffn"] = rwkv_cm_init(ks[2], rwkv_spec(cfg), dtype)
    elif spec.ffn != "none":
        raise ValueError(spec.ffn)
    return p


def block_cache_init(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_seq: int, dtype) -> Params:
    c: Params = {}
    if spec.mixer == "attn":
        c["kv"] = gqa_cache_init(attn_spec(cfg), batch, max_seq, dtype)
    elif spec.mixer == "mla":
        c["kv"] = mla_cache_init(mla_spec(cfg), batch, max_seq, dtype)
    elif spec.mixer == "mamba":
        c["state"] = mamba_state_init(mamba_spec(cfg), batch, dtype)
    elif spec.mixer == "rwkv":
        c["state"] = rwkv_state_init(rwkv_spec(cfg), batch, dtype)
    if spec.cross:
        n_ctx = cfg.n_img_tokens or cfg.n_frames
        shape = (batch, n_ctx, cfg.n_kv_heads, cfg.hd)
        c["cross_kv"] = {"k": jnp.zeros(shape, dtype),
                         "v": jnp.zeros(shape, dtype)}
    return c


class Ctx(NamedTuple):
    positions: Any            # (B, S) absolute positions
    pos: Any                  # scalar: cache write offset
    cross_src: Any = None     # (B, T_ctx, d) encoder/image states, or None
    cached: bool = False      # prefill/decode (threads caches)
    # Megatron-style sequence parallelism: the residual stream lives
    # S-sharded over 'model' (pin_sp); sublayer inputs are gathered to
    # full-S so tensor-parallel weights apply cleanly (pin_full).  GSPMD
    # realizes the pair as the classic all-gather/reduce-scatter schedule.
    pin_sp: Any = None        # callable | None: (dp, 'model', None)
    pin_full: Any = None      # callable | None: (dp, None, None)
    moe_axes: Any = None      # (dp_axis, ep_axis) for MoE dispatch pins
    # paged KV: (B, max_blocks) int32 block tables, or None (contiguous).
    # When set, attention caches are (N, bs, ...) pools shared across
    # requests and writes/reads route through the table (serve engine).
    paged: Any = None


def _pin(ctx: Ctx, x, kind: str):
    fn = ctx.pin_sp if kind == "sp" else ctx.pin_full
    return fn(x) if fn is not None else x


def block_apply(p: Params, cfg: ModelConfig, spec: LayerSpec, x, cache,
                ctx: Ctx):
    _, norm = make_norm(cfg.norm)
    new_cache: Params = {}
    aux = jnp.zeros((), jnp.float32)
    b = x.shape[0]

    # Fused norm seams (cfg.norm_impl -> kernels/fused_norm.py): gated to
    # pins-off — the Megatron inner pins must observe the residual stream
    # and the normed stream as SEPARATE shardable values, which is exactly
    # what fusing removes.  With a provider:
    #   * mixer 'attn': norm1 fuses into the QKV projection (prologue),
    #   * residual-add + norm2 fuse into one epilogue after the mixer
    #     (covers mlp AND moe — the epilogue is activation-independent),
    #   * a cross-less 'none'-mixer block fuses norm2 into the gate/up
    #     prologue inside mlp() instead.
    # The FFN-residual + NEXT block's norm1 seam is covered by that next
    # block's prologue, so every seam is one HBM round-trip shorter.
    nprov = dispatch.get_norm(dispatch.resolve_norm(cfg.norm_impl))
    fuse = (nprov is not None and ctx.pin_full is None
            and ctx.pin_sp is None)

    o = None
    if spec.mixer == "attn":
        if fuse:
            h, pn = x, (p["norm1"], cfg.norm, cfg.norm_eps, nprov)
        else:
            h, pn = _pin(ctx, norm(p["norm1"], x, cfg.norm_eps), "full"), None
        o, kv = gqa_apply(p["mixer"], attn_spec(cfg), h,
                          positions=ctx.positions,
                          cache=cache.get("kv") if ctx.cached else None,
                          pos=ctx.pos, paged=ctx.paged, prenorm=pn)
        if ctx.cached:
            new_cache["kv"] = kv
    elif spec.mixer == "mla":
        h = _pin(ctx, norm(p["norm1"], x, cfg.norm_eps), "full")
        o, kv = mla_apply(p["mixer"], mla_spec(cfg), h,
                          positions=ctx.positions,
                          cache=cache.get("kv") if ctx.cached else None,
                          pos=ctx.pos, paged=ctx.paged)
        if ctx.cached:
            new_cache["kv"] = kv
    elif spec.mixer == "mamba":
        h = _pin(ctx, norm(p["norm1"], x, cfg.norm_eps), "full")
        st = (cache["state"] if ctx.cached
              else mamba_state_init(mamba_spec(cfg), b, x.dtype))
        # NOTE: axes-pins measured NEUTRAL-to-negative here (EXPERIMENTS.md
        # §Perf jamba iterations) — GSPMD's own choice wins; knob retained.
        o, st = mamba_apply(p["mixer"], mamba_spec(cfg), h, state=st)
        if ctx.cached:
            new_cache["state"] = st
    elif spec.mixer == "rwkv":
        h = _pin(ctx, norm(p["norm1"], x, cfg.norm_eps), "full")
        st = (cache["state"] if ctx.cached
              else rwkv_state_init(rwkv_spec(cfg), b, x.dtype))
        o, tm_st = rwkv_time_mix(p["mixer"], rwkv_spec(cfg), h, state=st)
        if ctx.cached:
            new_cache["state"] = {**st, **tm_st}

    # mixer residual add — fused with norm2 when the next consumer is the
    # FFN norm (no cross sublayer in between)
    h_ffn = None
    if o is not None:
        if fuse and spec.ffn != "none" and not spec.cross:
            x, h_ffn = nprov["residual_norm"](
                x, o, p["norm2"]["g"], p["norm2"].get("b"),
                kind=cfg.norm, eps=cfg.norm_eps)
        else:
            x = x + o
        x = _pin(ctx, x, "sp")

    if spec.cross:
        h = _pin(ctx, norm(p["cross_norm"], x, cfg.norm_eps), "full")
        if ctx.cross_src is not None:
            ckv = cross_kv(p["cross"], attn_spec(cfg, causal=False),
                           ctx.cross_src)
        else:
            ckv = cache["cross_kv"]
        if ctx.cached:
            new_cache["cross_kv"] = jax.tree.map(
                lambda a, b_: a.astype(b_.dtype), ckv, cache["cross_kv"])
        o = cross_apply(p["cross"], attn_spec(cfg, causal=False), h, ckv)
        x = _pin(ctx, x + jnp.tanh(p["cross_gate"]) * o, "sp")

    if spec.ffn != "none":
        if h_ffn is None and spec.ffn == "mlp" and fuse:
            # no epilogue produced h (mixer 'none' or a cross sublayer
            # re-touched x): fuse norm2 into the gate/up prologue instead
            x = x + mlp(p["ffn"], x, cfg.activation, impl=cfg.ffn_impl,
                        prenorm=(p["norm2"], cfg.norm, cfg.norm_eps),
                        norm_impl=cfg.norm_impl)
        else:
            h = (h_ffn if h_ffn is not None
                 else _pin(ctx, norm(p["norm2"], x, cfg.norm_eps), "full"))
            if spec.ffn == "mlp":
                x = x + mlp(p["ffn"], h, cfg.activation, impl=cfg.ffn_impl)
            elif spec.ffn == "moe":
                o, aux = moe_apply(p["ffn"], moe_spec(cfg), h,
                                   dropless=ctx.cached, axes=ctx.moe_axes)
                x = x + o
            elif spec.ffn == "rwkv_cm":
                st = (cache["state"] if ctx.cached
                      else rwkv_state_init(rwkv_spec(cfg), b, x.dtype))
                o, cm_st = rwkv_channel_mix(p["ffn"], rwkv_spec(cfg), h,
                                            state=st)
                if ctx.cached:
                    new_cache["state"] = {**new_cache.get("state", st),
                                          **cm_st}
                x = x + o
        x = _pin(ctx, x, "sp")
    return x, new_cache, aux


# ---------------- full model ----------------

def init_lm(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    norm_init, _ = make_norm(cfg.norm)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(keys[1], cfg.d_model, cfg.vocab, dtype)
    if cfg.pos_emb == "learned":
        params["pos"] = embed_init(keys[2], min(cfg.max_seq, 1 << 16),
                                   cfg.d_model, dtype)
    if cfg.prefix:
        pk = jax.random.split(keys[3], len(cfg.prefix))
        params["prefix"] = [block_init(pk[i], cfg, s, dtype)
                            for i, s in enumerate(cfg.prefix)]
    period_keys = jax.random.split(keys[4], cfg.n_periods)

    def one_period(k):
        sk = jax.random.split(k, len(cfg.pattern))
        return [block_init(sk[j], cfg, s, dtype)
                for j, s in enumerate(cfg.pattern)]

    params["periods"] = jax.vmap(one_period)(period_keys)
    if cfg.enc_layers:
        ek = jax.random.split(keys[5], cfg.enc_layers)
        enc_spec = LayerSpec(mixer="attn", ffn="mlp")
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: block_init(k, _enc_cfg(cfg), enc_spec, dtype))(ek),
            "norm": norm_init(cfg.d_model, dtype),
        }
    return params


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    return cfg.replace(causal=False, pattern=(LayerSpec(),), prefix=())


def init_caches(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.float32) -> Params:
    caches: Params = {}
    if cfg.prefix:
        caches["prefix"] = [block_cache_init(cfg, s, batch, max_seq, dtype)
                            for s in cfg.prefix]
    one = [block_cache_init(cfg, s, batch, max_seq, dtype)
           for s in cfg.pattern]
    caches["periods"] = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), one)
    return caches


def paged_supported(cfg: ModelConfig) -> bool:
    """Whether every cached layer of ``cfg`` can live in a paged pool.

    Paged KV covers the attention caches (GQA rows, MLA latents); mixers
    whose state is NOT a per-position sequence (mamba conv/ssm state,
    rwkv time-mix state) and cross-attention context caches have nothing
    to page — those architectures stay on the contiguous engine."""
    specs = tuple(cfg.prefix) + tuple(cfg.pattern)
    return (not cfg.enc_layers and
            all(s.mixer in ("attn", "mla", "none") and not s.cross
                for s in specs))


def _block_paged_cache_init(cfg: ModelConfig, spec: LayerSpec,
                            num_blocks: int, block_size: int,
                            dtype) -> Params:
    c: Params = {}
    if spec.mixer == "attn":
        s = attn_spec(cfg)
        shape = (num_blocks, block_size, s.n_kv_heads, s.head_dim)
        c["kv"] = {"k": jnp.zeros(shape, dtype),
                   "v": jnp.zeros(shape, dtype)}
    elif spec.mixer == "mla":
        m = mla_spec(cfg)
        c["kv"] = {"ckv": jnp.zeros((num_blocks, block_size,
                                     m.kv_lora_rank), dtype),
                   "krope": jnp.zeros((num_blocks, block_size, m.rope_dim),
                                      dtype)}
    elif spec.mixer != "none" or spec.cross:
        raise ValueError(f"mixer {spec.mixer!r} has no paged cache form")
    return c


def init_paged_caches(cfg: ModelConfig, num_blocks: int, block_size: int,
                      dtype=jnp.float32) -> Params:
    """Paged twin of :func:`init_caches`: per-layer (N, bs, ...) pools.

    Every layer gets its own pool but all layers share ONE block table
    per request (allocation is in lockstep across the stack), so the
    serve engine threads a single (B, max_blocks) table through
    ``lm_apply(..., paged=tables)``.  Block 0 of every pool is the write
    sentinel — the allocator never hands it out."""
    if not paged_supported(cfg):
        raise ValueError(
            "paged KV requires attention-only cached layers (no "
            "mamba/rwkv state, no cross-attention, no encoder)")
    caches: Params = {}
    if cfg.prefix:
        caches["prefix"] = [
            _block_paged_cache_init(cfg, s, num_blocks, block_size, dtype)
            for s in cfg.prefix]
    one = [_block_paged_cache_init(cfg, s, num_blocks, block_size, dtype)
           for s in cfg.pattern]
    caches["periods"] = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), one)
    return caches


def encoder_apply(params: Params, cfg: ModelConfig, frames):
    """Whisper-style encoder over stub frame embeddings (B, T, d)."""
    _, norm = make_norm(cfg.norm)
    x = frames + sinusoidal_pos_emb(frames.shape[1], cfg.d_model,
                                    frames.dtype)
    ecfg = _enc_cfg(cfg)
    spec = LayerSpec(mixer="attn", ffn="mlp")
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1])[None, :],
                           frames.shape[:2])
    ctx = Ctx(positions=pos, pos=0)

    def body(x, bp):
        x, _, _ = block_apply(bp, ecfg, spec, x, {}, ctx)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return norm(params["encoder"]["norm"], x, cfg.norm_eps)


def _best_group(n: int) -> int:
    """Divisor of n nearest sqrt(n) — two-level remat group count."""
    best, target = 1, max(int(n ** 0.5), 1)
    for g in range(1, n + 1):
        if n % g == 0 and abs(g - target) < abs(best - target):
            best = g
    return best


def lm_apply(params: Params, cfg: ModelConfig, tokens, *, pos=0,
             caches: Params | None = None, cross_src=None,
             remat: bool = False, last_pos=None, act_pspec=None,
             return_hidden: bool = False, inner_pins: bool = False,
             remat_mode: str = "period", paged=None):
    """tokens (B,S) -> (logits, new_caches, aux).

    caches=None  : train mode (full forward, no state threading)
    caches given : prefill (pos=0, S=seq) or decode (S=1, pos=offset)
    remat        : activation-checkpoint each scan period (train mode) —
                   activations are recomputed in backward, so live memory
                   is O(1 period) instead of O(n_layers)
    last_pos     : optional (B,) positions — compute logits ONLY at these
                   rows (prefill: avoids the (B,S,vocab) logits tensor,
                   which at 32k×150k vocab would dwarf the model itself)
    act_pspec    : optional PartitionSpec pinned onto the (B,S,d) residual
                   stream at every period boundary — sequence parallelism:
                   the remat'd scan carry is stored S/|model|-sharded, and
                   GSPMD all-gathers only transiently inside blocks
    return_hidden: skip the LM head, return final-norm hidden states (the
                   chunked-CE loss applies the head itself)
    paged        : optional (B, max_blocks) int32 block tables — caches
                   are :func:`init_paged_caches` pools and attention
                   writes/reads route through the tables (serve engine's
                   zero-copy admission path)
    """
    _, norm = make_norm(cfg.norm)
    b, sl = tokens.shape
    x = params["embed"][tokens]
    # pos may be scalar (lockstep) or (B,) (continuous batching)
    off = pos if jnp.ndim(pos) == 0 else pos[:, None]
    positions = jnp.broadcast_to(off + jnp.arange(sl)[None, :], (b, sl))
    if cfg.pos_emb == "learned":
        x = x + params["pos"][jnp.clip(positions, 0,
                                       params["pos"].shape[0] - 1)]
    elif cfg.pos_emb == "sinusoid":
        x = x + sinusoidal_pos_emb(sl, cfg.d_model, x.dtype)[None]

    cached = caches is not None
    pin_sp = pin_full = None
    if act_pspec is not None and inner_pins:
        # Megatron-style AG/RS pins inside blocks.  Measured on this
        # toolchain they LOSE to the boundary-only pin (EXPERIMENTS.md
        # §Perf: jamba 153 vs 127 GiB/chip) — kept as an opt-in knob.
        full_spec = type(act_pspec)(act_pspec[0], None, None)
        pin_sp = lambda h: jax.lax.with_sharding_constraint(h, act_pspec)
        pin_full = lambda h: jax.lax.with_sharding_constraint(h, full_spec)
    moe_axes = None
    if act_pspec is not None:
        dp_ax = act_pspec[0]
        in_dp = ("model" in dp_ax) if isinstance(dp_ax, tuple) else \
            (dp_ax == "model")
        if not in_dp:                # 'model' free to serve as the EP axis
            moe_axes = (dp_ax, act_pspec[1] if len(act_pspec) > 1
                        and act_pspec[1] else "model")
    ctx = Ctx(positions=positions, pos=pos, cross_src=cross_src,
              cached=cached, pin_sp=pin_sp, pin_full=pin_full,
              moe_axes=moe_axes, paged=paged)
    pin = ((lambda h: jax.lax.with_sharding_constraint(h, act_pspec))
           if act_pspec is not None else (lambda h: h))
    x = pin(x)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Params = {}

    if cfg.prefix:
        new_caches["prefix"] = []
        for i, spec in enumerate(cfg.prefix):
            c = caches["prefix"][i] if cached else {}
            x, nc, aux = block_apply(params["prefix"][i], cfg, spec, x, c, ctx)
            new_caches["prefix"].append(nc)
            aux_total = aux_total + aux

    if cached:
        def body(carry, xs):
            x, aux_acc = carry
            pp, pc = xs
            ncs = []
            for j, spec in enumerate(cfg.pattern):
                bp = jax.tree.map(lambda a: a, pp[j])
                x, nc, aux = block_apply(bp, cfg, spec, x, pc[j], ctx)
                ncs.append(nc)
            return (pin(x), aux_acc + aux), ncs

        (x, aux_total), period_caches = jax.lax.scan(
            body, (x, aux_total), (params["periods"], caches["periods"]))
        new_caches["periods"] = period_caches
    else:
        def body(carry, pp):
            x, aux_acc = carry
            for j, spec in enumerate(cfg.pattern):
                x, _, aux = block_apply(pp[j], cfg, spec, x, {}, ctx)
                aux_acc = aux_acc + aux
            return (pin(x), aux_acc), None

        n_p = cfg.n_periods
        g = _best_group(n_p) if remat_mode == "two_level" else 1
        if remat and 1 < g < n_p:
            # two-level (sqrt-L) remat: outer scan saves G boundaries, the
            # inner scan recomputes its P/G periods during backward —
            # stored residual-stream copies drop from P to G + P/G without
            # sequence-sharding the activations (EXPERIMENTS.md §Perf)
            stacked = jax.tree.map(
                lambda a: a.reshape(g, n_p // g, *a.shape[1:]),
                params["periods"])
            inner = jax.checkpoint(body)

            @jax.checkpoint
            def outer(carry, pg):
                c, _ = jax.lax.scan(inner, carry, pg)
                return c, None

            (x, aux_total), _ = jax.lax.scan(outer, (x, aux_total), stacked)
        else:
            if remat:
                body = jax.checkpoint(body)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                             params["periods"])

    if last_pos is not None:
        x = jnp.take_along_axis(x, last_pos[:, None, None], axis=1)
    x = norm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, (new_caches if cached else None), aux_total
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]["w"]
    return logits, (new_caches if cached else None), aux_total


def lm_head_weight(params: Params, cfg: ModelConfig):
    """(d, vocab) head matrix (transposed embed when tied)."""
    return (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]["w"])
