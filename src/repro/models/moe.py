"""Mixture-of-Experts: token-choice top-k routing with two dispatch paths.

  'sort'  — production path, GROUP-LOCAL: every sequence routes its own S
            tokens (sort by expert id within the sequence, scatter into a
            per-sequence (E, C_g, d) capacity buffer, batched expert FFN,
            gather back).  Because the group axis is the batch axis, the
            sort/scatter never crosses a data shard — GSPMD keeps dispatch
            local and the only collective is the einsum-aligned exchange
            with the expert-parallel weights over 'model'.  (A global sort
            over the 1M-token train_4k batch measured 170s of all-gather
            per step at 256 chips — group-local dispatch removes it.)
            Capacity is per group: C_g = ceil(S*k/E * cf), the per-batch
            balance modern MoE trainers use.
  'dense' — reference path: compute every expert for every token, weight by
            gates.  Exact (no capacity drops); used by tests as the oracle
            and by tiny smoke configs.

Includes shared experts (DeepSeek-V2) and the standard load-balance aux
loss.  Expert FFNs use the configured activation, so the paper's dual-mode
unit serves MoE experts too.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.activations import get_activation
from .layers import Params, dense_init, mlp, mlp_init


def _ambient_axis_size(axis) -> int:
    """Size of a mesh axis from the ambient `with mesh:` context (1 if
    no mesh / unknown axis — pins become no-risk no-ops)."""
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        if not pm.empty:
            total = 1
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                total *= dict(pm.shape).get(a, 1)
            return total
    except Exception:  # noqa: BLE001 — defensive: pins are advisory
        pass
    return 1


class MoESpec(NamedTuple):
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0         # DeepSeek-style always-on experts
    capacity_factor: float = 1.25
    activation: str = "silu"
    ffn_impl: str = "dense"   # shared-expert MLP execution (dispatch registry)
    dispatch: str = "sort"    # 'sort' | 'dense'
    ep_pad: int = 0           # padded stack size (0 = n_experts)
    # inference capacity: truly dropless (cap=S) is exact for short
    # sequences (decode, engine tests) but at 32k-token prefill the
    # worst-case buffer is S/E-fold oversized (hundreds of TB) — beyond
    # this length we bound capacity at inference_cf x the balanced load,
    # the standard serving trade-off.
    dropless_max_seq: int = 1024
    inference_cf: float = 2.0


def moe_init(key, s: MoESpec, dtype) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    kg, ku, kd = jax.random.split(ke, 3)
    e = max(s.ep_pad, s.n_experts)       # padded experts are dead weight
    p = {
        "router": dense_init(kr, s.d_model, s.n_experts, dtype,
                             scale=0.02),
        "gate": _stack_init(kg, e, s.d_model, s.d_ff, dtype),
        "up": _stack_init(ku, e, s.d_model, s.d_ff, dtype),
        "down": _stack_init(kd, e, s.d_ff, s.d_model, dtype),
    }
    if s.n_shared:
        p["shared"] = mlp_init(ks, s.d_model, s.d_ff * s.n_shared, dtype,
                               gated=True)
    return p


def _stack_init(key, e: int, d_in: int, d_out: int, dtype):
    return (jax.random.normal(key, (e, d_in, d_out))
            * (1.0 / math.sqrt(d_in))).astype(dtype)


def _route(p: Params, s: MoESpec, x):
    """(B,S,d) -> gates (B,S,k), expert idx (B,S,k), aux loss.

    Routing stays in batch-major layout end to end — a flattened (T,E)
    router forces GSPMD to all-gather the global token set for top_k
    (measured 10.7 GB/step at granite train_4k)."""
    logits = (x @ p["router"]).astype(jnp.float32)           # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, s.top_k)               # (B,S,k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # aux loss: E * sum_e f_e * p_e   (Switch Transformer eq. 4); counts
    # via one-hot sums (shard-local), not a global scatter
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.sum(jax.nn.one_hot(idx, s.n_experts, dtype=jnp.float32),
                 axis=(0, 1, 2))
    ce = ce / (x.shape[0] * x.shape[1] * s.top_k)
    aux = s.n_experts * jnp.sum(me * ce)
    return gates.astype(x.dtype), idx, aux


def _expert_ffn(p: Params, s: MoESpec, xb):
    """Batched expert FFN over buffers xb: (E, C, d) -> (E, C, d)."""
    act = get_activation(s.activation)
    g = jnp.einsum("ecd,edf->ecf", xb, p["gate"])
    u = jnp.einsum("ecd,edf->ecf", xb, p["up"])
    return jnp.einsum("ecf,efd->ecd", act(g) * u, p["down"])


# ---------------- custom-VJP dispatch/combine ----------------
# Autodiff transposes a gather into a GENERIC scatter-add; GSPMD lowers
# those with its replicate+mask+all-reduce fallback (measured 0.4-6.6 TB
# of backward collectives per MoE train step).  These custom VJPs keep
# BOTH directions in the forms GSPMD partitions cleanly, and every float
# gather/scatter is TOKEN-MAJOR 2D-indexed ((t,k) -> (e, rank) tables) —
# float permutation-gathers in expert-sorted order measured 6.6 TB of
# all-reduce at granite train_4k; only the int rank tables are sorted.

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _dispatch(x_flat, idx, rank_tok, cap: int, e_buf: int):
    """(t,d) tokens -> (e_buf, cap, d) expert buffer.

    idx/rank_tok: (t,k) expert id and within-expert rank per slot; the
    (e, rank) pairs are unique; rank >= cap drops (capacity)."""
    t, k = idx.shape
    d = x_flat.shape[-1]
    xk = jnp.broadcast_to(x_flat[:, None, :], (t, k, d))
    buf = jnp.zeros((e_buf, cap, d), x_flat.dtype)
    return buf.at[idx.reshape(-1), rank_tok.reshape(-1)].set(
        xk.reshape(t * k, d), mode="drop", unique_indices=True)


def _dispatch_fwd(x_flat, idx, rank_tok, cap, e_buf):
    return _dispatch(x_flat, idx, rank_tok, cap, e_buf), (idx, rank_tok)


def _dispatch_bwd(cap, e_buf, res, dbuf):
    idx, rank_tok = res
    # token-major gather of each slot's grad, summed over the k slots
    slots = dbuf.at[idx, rank_tok].get(mode="fill", fill_value=0)
    return slots.sum(axis=1).astype(dbuf.dtype), None, None


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _combine(h, gk_tok, idx, rank_tok):
    """y (t,d) = sum_k gk[t,k] * h[idx[t,k], rank_tok[t,k]]."""
    slots = h.at[idx, rank_tok].get(mode="fill", fill_value=0)  # (t,k,d)
    return jnp.sum(slots * gk_tok[..., None], axis=1)


def _combine_fwd(h, gk_tok, idx, rank_tok):
    return _combine(h, gk_tok, idx, rank_tok), (h, gk_tok, idx, rank_tok)


def _combine_bwd(res, dy):
    h, gk_tok, idx, rank_tok = res
    t, k = idx.shape
    dyk = jnp.broadcast_to(dy[:, None, :], (t, k, dy.shape[-1]))
    dh = jnp.zeros_like(h).at[idx.reshape(-1), rank_tok.reshape(-1)].set(
        (dyk * gk_tok[..., None]).reshape(t * k, -1).astype(h.dtype),
        mode="drop", unique_indices=True)
    slots = h.at[idx, rank_tok].get(mode="fill", fill_value=0)
    dgk = jnp.sum(dyk * slots, axis=-1).astype(gk_tok.dtype)
    return dh, dgk, None, None


_combine.defvjp(_combine_fwd, _combine_bwd)


def _moe_sort_local(p: Params, s: MoESpec, x_flat, gates, idx, cap: int,
                    e_buf: int | None = None):
    """One group's dispatch: x_flat (S,d), gates/idx (S,k) -> buffers.

    Only INT arrays are sorted (to compute each slot's within-expert
    rank); all float traffic moves through the token-major custom-VJP
    dispatch/combine above."""
    t, d = x_flat.shape
    n_slots = t * s.top_k

    flat_e = idx.reshape(-1)                                  # (S*k,)
    order = jnp.argsort(flat_e)                               # stable
    e_sorted = flat_e[order]
    unsort = jnp.argsort(order)

    # rank within expert = position - start offset of that expert
    counts = jnp.zeros((s.n_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                      # exclusive
    rank = jnp.arange(n_slots) - starts[e_sorted]
    rank_tok = rank[unsort].reshape(t, s.top_k)               # token-major
    gk_tok = gates * (rank_tok < cap)

    buf = _dispatch(x_flat, idx, rank_tok, cap, e_buf or s.n_experts)
    return buf, (gk_tok, rank_tok)


def _moe_sort(p: Params, s: MoESpec, x, gates, idx, dropless=False,
              axes=None):
    """Group-local dispatch over the batch axis.  x (B,S,d) -> (B,S,d).

    `axes` = (dp_axis, ep_axis) mesh-axis names: explicit sharding pins on
    the dispatch buffers — GSPMD loses the batch sharding through the
    batched scatter otherwise (measured: full-B f32 buffers replicated on
    every chip, 60+ GiB at jamba train_4k)."""
    b, sl, d = x.shape
    k = s.top_k
    if dropless and sl <= s.dropless_max_seq:
        cap = sl       # an expert can receive at most S slots: zero drops
    else:
        cf = s.inference_cf if dropless else s.capacity_factor
        cap = int(math.ceil(sl * k / s.n_experts * cf))
        cap = min(cap, sl)

    e_buf = max(s.ep_pad, s.n_experts)
    # Two dispatch layouts (chosen at trace time from shapes + mesh):
    #  * batch-DP: expert stacks are SMALL (granite: 80 MB/layer) ->
    #    replicate the weights and shard the batch-group dim over the
    #    WHOLE mesh.  Every scatter/gather is shard-local; GSPMD's
    #    sharded-scatter fallback (measured 1.27 TB of all-reduce per
    #    granite train step — 94% of its collectives) never fires.
    #  * EP: big stacks shard over 'model'; the buffer resharding becomes
    #    the expert all-to-all.
    small_stacks = (p["gate"].size * p["gate"].dtype.itemsize) <= (1 << 28)
    if axes is not None:
        dp, ep = axes
        dp_t = tuple(dp) if isinstance(dp, tuple) else (dp,)
        full = dp_t + ((ep,) if ep and ep not in dp_t else ())
        if small_stacks and b % _ambient_axis_size(full) == 0:
            dp, ep = (full if len(full) > 1 else full[0]), None
        elif e_buf % _ambient_axis_size(ep) != 0:
            ep = None            # uneven EP would pad-communicate
        axes = (dp, ep)
    pin = (lambda t, spec: jax.lax.with_sharding_constraint(t, spec)) \
        if axes is not None else (lambda t, spec: t)
    if axes is not None:
        from jax.sharding import PartitionSpec as P
        x = pin(x, P(dp, None, None))
        gates = pin(gates, P(dp, None, None))
        idx = pin(idx, P(dp, None, None))

    bufs, meta = jax.vmap(
        lambda xg, gg, ig: _moe_sort_local(p, s, xg, gg, ig, cap, e_buf))(
            x, gates, idx)                     # bufs: (B, E, C, d)
    if axes is not None:
        # the (dp,None)->(dp,ep) pin pair reads as a redundant reshard
        # but measured BETTER than the single pin (deepseek 18.1 vs 21.9s
        # t_n): the batch-local stop keeps the scatter unsharded on E, so
        # its lowering never hits GSPMD's replicate+all-reduce fallback.
        bufs = pin(bufs, P(dp, None, None, None))
        bufs = pin(bufs, P(dp, ep, None, None))
    h = jnp.einsum("becd,edf->becf", bufs, p["gate"])
    u = jnp.einsum("becd,edf->becf", bufs, p["up"])
    act = get_activation(s.activation)
    h = jnp.einsum("becf,efd->becd", act(h) * u, p["down"])   # (B,E,C,d)
    if axes is not None:
        h = pin(h, P(dp, ep, None, None))
        h = pin(h, P(dp, None, None, None))    # back to batch-local

    def gather_back(hg, m, ig):
        gk_tok, rank_tok = m
        return _combine(hg, gk_tok, ig, rank_tok)

    return jax.vmap(gather_back)(h, meta, idx)


def _moe_dense(p: Params, s: MoESpec, x_flat, gates, idx):
    # (T,d) through every expert: (E,T,d); weight by scattered gates
    act = get_activation(s.activation)
    g = jnp.einsum("td,edf->etf", x_flat, p["gate"])
    u = jnp.einsum("td,edf->etf", x_flat, p["up"])
    h = jnp.einsum("etf,efd->etd", act(g) * u, p["down"])     # (E,T,d)
    w = jnp.zeros((x_flat.shape[0], p["gate"].shape[0]), x_flat.dtype)
    w = jax.vmap(lambda wi, ii, gi: wi.at[ii].add(gi))(w, idx, gates)
    return jnp.einsum("etd,te->td", h, w)


def moe_apply(p: Params, s: MoESpec, x, dropless: bool = False, axes=None):
    """x: (B,S,d) -> (y, aux_loss).

    dropless=True (inference): no token drops up to `dropless_max_seq`
    (capacity-bounded routing is a *training* throughput device and would
    make decode outputs depend on what else shares the batch); longer
    prefills fall back to inference_cf-bounded capacity."""
    b, sl, d = x.shape
    gates, idx, aux = _route(p, s, x)
    if s.dispatch == "dense":
        y = _moe_dense(p, s, x.reshape(-1, d), gates.reshape(-1, s.top_k),
                       idx.reshape(-1, s.top_k)).reshape(b, sl, d)
    else:
        y = _moe_sort(p, s, x, gates, idx, dropless=dropless, axes=axes)
    if s.n_shared:
        y = y + mlp(p["shared"], x, s.activation, impl=s.ffn_impl)
    return y, aux
