"""Pure-JAX model zoo (no flax): every assigned architecture + BERT."""
