"""Blocked online-softmax attention (flash attention, pure JAX).

Why it exists: the naive path materializes (B,H,S,T) scores — at the
assigned train_4k/prefill_32k shapes that is 10s of GB per chip and can
never fit VMEM/HBM.  The blocked form streams KV in chunks and keeps only
(B,H,S,block) live.

Faithfulness note (DESIGN.md §2): the paper's softmax normalizes in the
LOG domain (Eq. 10), y = 2^(t_i - m - log2 Σ 2^(t_j - m)).  That form
telescopes exactly into the online-softmax recurrence (Milakov &
Gimelshein [22], the same family the paper's adder-tree architecture
cites): carrying (m, l) per row IS the streaming evaluation of Eq. 10.
We therefore compute every exponential as exp2((s - m) * log2e) — the
2^u·2^v decomposition the hardware unit uses — so the blocked path is the
unit's own arithmetic, streamed.  (The bit-accurate int path needs whole
rows and stays on the naive path used for short T.)

Shapes: q (B,S,K,G,h), k (B,T,K,h), v (B,T,K,hv) -> out (B,S,K,G,hv).
hv may differ from h (MLA).  Masking: kv position t attends iff
kv_valid[b,t] and (not causal or t <= q_pos[b,s]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_LOG2E = 1.4426950408889634
_NEG = -1e30


def flash_attention(q, k, v, *, q_pos, kv_valid, causal: bool = True,
                    block: int = 1024, scale: float | None = None):
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    block = min(block, t)
    while t % block:                      # largest power-of-2-ish divisor
        block //= 2
    assert block >= 1
    nb = t // block
    scale = (1.0 / hd ** 0.5) if scale is None else scale

    qf = q.astype(jnp.float32) * scale
    t_idx = jnp.arange(block)

    def body(carry, i):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * block, block, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * block, block, 1)
        validb = jax.lax.dynamic_slice_in_dim(kv_valid, i * block, block, 1)
        # scores for this block: (B,K,G,S,block)
        sc = jnp.einsum("bskgh,btkh->bkgst", qf, kb.astype(jnp.float32))
        pos_b = i * block + t_idx                              # (block,)
        mask = validb[:, None, :]                              # (B,1,block)
        if causal:
            mask = mask & (pos_b[None, None, :] <= q_pos[:, :, None])
        sc = jnp.where(mask[:, None, None, :, :], sc, _NEG)
        # online log-domain update (Eq. 10 streamed; exp as 2^((s-m)·log2e))
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp2((sc - m_new[..., None]) * _LOG2E)         # (B,K,G,S,blk)
        corr = jnp.exp2((m - m_new) * _LOG2E)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, kh, g, s_q), _NEG, jnp.float32)
    l0 = jnp.zeros((b, kh, g, s_q), jnp.float32)
    acc0 = jnp.zeros((b, kh, g, s_q, hv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(nb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]               # (B,K,G,S,hv)
    return jnp.moveaxis(out, 3, 1).astype(v.dtype)             # (B,S,K,G,hv)


def use_flash(s_q: int, t: int, threshold: int = 1 << 22) -> bool:
    """Blocked path when the scores tensor would exceed ~16 MB f32/head."""
    return s_q * t > threshold and t % 512 == 0
