"""Blocked online-softmax attention (flash attention, pure JAX).

Why it exists: the naive path materializes (B,H,S,T) scores — at the
assigned train_4k/prefill_32k shapes that is 10s of GB per chip and can
never fit VMEM/HBM.  The blocked form streams KV in chunks and keeps only
(B,H,S,block) live.

Faithfulness note (DESIGN.md §2): the paper's softmax normalizes in the
LOG domain (Eq. 10), y = 2^(t_i - m - log2 Σ 2^(t_j - m)).  That form
telescopes exactly into the online-softmax recurrence (Milakov &
Gimelshein [22], the same family the paper's adder-tree architecture
cites): carrying (m, l) per row IS the streaming evaluation of Eq. 10.
The inner step is therefore ``repro.kernels.datapath.
online_softmax_update`` — the unit's own arithmetic, streamed, and the
SAME function the Pallas kernel body executes (kernels/flash_attention.py
is this loop with a Pallas grid around it).  (This module is the FLOAT
form; the bit-accurate int unit streams through the snapped one-sweep
kernel in kernels/flash_attention_int.py, with the three-sweep
'flash_pallas_int3' kept as its oracle — dispatch never pairs 'dualmode'
with this float path.)

Shapes: q (B,S,K,G,h), k (B,T,K,h), v (B,T,K,hv) -> out (B,S,K,G,hv).
hv may differ from h (MLA).  Masking: kv position t attends iff
kv_valid[b,t] and (not causal or t <= q_pos[b,s]); masked scores take
``datapath.MASK_VALUE`` so every attention implementation masks
identically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import datapath as dp
from repro.kernels import dispatch, tiling


def flash_attention(q, k, v, *, q_pos, kv_valid, causal: bool = True,
                    block: int = 1024, scale: float | None = None,
                    return_stats: bool = False):
    """Blocked online-softmax attention (see module docstring).

    ``return_stats=True`` additionally returns the per-row online-softmax
    statistics ``(m, l)`` laid out (B, K, G, S): the running max and
    normalizer of the PRE-SCALED masked scores.  This is the residual
    contract the Pallas forward kernel saves for its backward kernels
    (``kernels/flash_attention_bwd.py``) — exposed here so parity tests
    can pin the kernel's saved statistics against the pure-JAX blocked
    reference.
    """
    b, s_q, kh, g, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]
    block = min(block, t)
    # non-divisible T: pad KV up to a block multiple (tiling policy) with
    # invalid keys, instead of shrinking the block toward a 1-wide scan
    k, _ = tiling.pad_dim(k, 1, block)
    v, _ = tiling.pad_dim(v, 1, block)
    kv_valid, _ = tiling.pad_dim(kv_valid, 1, block, value=False)
    nb = k.shape[1] // block
    scale = (1.0 / hd ** 0.5) if scale is None else scale

    qf = q.astype(jnp.float32) * scale
    t_idx = jnp.arange(block)

    def body(carry, i):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * block, block, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * block, block, 1)
        validb = jax.lax.dynamic_slice_in_dim(kv_valid, i * block, block, 1)
        # scores for this block: (B,K,G,S,block)
        sc = jnp.einsum("bskgh,btkh->bkgst", qf, kb.astype(jnp.float32))
        pos_b = i * block + t_idx                              # (block,)
        mask = validb[:, None, :]                              # (B,1,block)
        if causal:
            mask = mask & (pos_b[None, None, :] <= q_pos[:, :, None])
        sc = jnp.where(mask[:, None, None, :, :], sc, dp.MASK_VALUE)
        if k.shape[1] != t:
            # pad-introduced phantom keys must carry NO mass (-inf), unlike
            # user-invalid keys which keep the finite MASK_VALUE for bit
            # parity with the naive path's masking
            sc = jnp.where(pos_b[None, None, None, None, :] < t, sc,
                           -jnp.inf)
        # online log-domain update (Eq. 10 streamed, shared datapath step)
        m, l, p, corr = dp.online_softmax_update(m, l, sc)
        acc = acc * corr + jnp.einsum(
            "bkgst,btkh->bkgsh", p, vb.astype(jnp.float32))
        return (m, l, acc), None

    m0 = jnp.full((b, kh, g, s_q, 1), dp.MASK_VALUE, jnp.float32)
    l0 = jnp.zeros((b, kh, g, s_q, 1), jnp.float32)
    acc0 = jnp.zeros((b, kh, g, s_q, hv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(nb))
    out = dp.online_softmax_finish(l, acc)                     # (B,K,G,S,hv)
    out = jnp.moveaxis(out, 3, 1).astype(v.dtype)              # (B,S,K,G,hv)
    if return_stats:
        return out, m[..., 0], l[..., 0]                       # (B,K,G,S)
    return out


def flash_attention_merged(q, k, v, *, q_pos, kv_valid, n_splits: int,
                           causal: bool = True, scale: float | None = None,
                           block: int = 1024):
    """Ring-attention oracle on ONE host: split KV into ``n_splits``
    contiguous shards, run the blocked reference per shard (each shard
    sees shard-local key positions, so ``q_pos`` is shifted by the
    shard's offset — exactly what a ring hop does), convert each
    finished shard back to its unnormalized partial ``(m, l, o*l)`` and
    fold with :func:`repro.kernels.datapath.online_softmax_merge`.

    This is the pure-JAX home of the partial-merge contract: the Pallas
    ring kernel (``kernels/ring_attention.py``) is this fold run across
    devices, and the merge's split-point invariance — the output must
    not depend on ``n_splits`` — is what the property tests pin.
    """
    t = k.shape[1]
    assert t % n_splits == 0, (t, n_splits)
    t_loc = t // n_splits
    scale = (1.0 / q.shape[-1] ** 0.5) if scale is None else scale
    qf = q.astype(jnp.float32) * scale

    part = None
    for i in range(n_splits):
        sl = slice(i * t_loc, (i + 1) * t_loc)
        o_i, m_i, l_i = flash_attention(
            qf, k[:, sl], v[:, sl], q_pos=q_pos - i * t_loc,
            kv_valid=kv_valid[:, sl], causal=causal, scale=1.0,
            block=min(block, t_loc), return_stats=True)
        # (B,K,G,S) stats -> (B,S,K,G,1) merge layout; o*l recovers the
        # shard's unnormalized accumulator
        m_i = jnp.moveaxis(m_i, 3, 1)[..., None]
        l_i = jnp.moveaxis(l_i, 3, 1)[..., None]
        part_i = (m_i, l_i, o_i.astype(jnp.float32) * l_i)
        part = part_i if part is None else dp.online_softmax_merge(
            part, part_i)
    _, l, acc = part
    return dp.online_softmax_finish(l, acc).astype(v.dtype)


def flash_attention_paged_ref(q, k_pool, v_pool, *, block_tables, q_pos,
                              kv_valid, causal: bool = True,
                              scale: float | None = None):
    """Paged fold oracle: one python loop over LOGICAL blocks, each block
    gathered from the pool through the table, scored+masked exactly like
    the dense paths, reduced to its ``(m, l, o·l)`` partial with
    :func:`repro.kernels.datapath.online_softmax_partial` and folded with
    :func:`repro.kernels.datapath.online_softmax_merge`.

    This is the block-table twin of :func:`flash_attention_merged` — the
    pure-JAX home of the paged kernel's contract: the Pallas block-table
    gather must produce the same words as this fold, and the fold itself
    is split-invariant (one block per partial is the finest split).  The
    table's physical permutation must be invisible: only the LOGICAL
    block index enters the mask arithmetic.
    """
    b, s_q = q.shape[:2]
    nblk, bs = block_tables.shape[1], k_pool.shape[1]
    scale = (1.0 / q.shape[-1] ** 0.5) if scale is None else scale
    qf = q.astype(jnp.float32) * scale

    part = None
    for j in range(nblk):
        kb = k_pool[block_tables[:, j]].astype(jnp.float32)  # (B,bs,K,h)
        vb = v_pool[block_tables[:, j]].astype(jnp.float32)  # (B,bs,K,hv)
        s = jnp.einsum("bskgh,btkh->bskgt", qf, kb,
                       preferred_element_type=jnp.float32)
        kv_pos = j * bs + jnp.arange(bs)
        mask = kv_valid[:, j * bs:(j + 1) * bs][:, None, None, None, :]
        if causal:
            mask = mask & (kv_pos[None, None, None, None, :]
                           <= q_pos[:, :, None, None, None])
        s = jnp.where(mask, s, dp.MASK_VALUE)
        # (B,bs,K,hv) -> (B,1,K,1,bs,hv): broadcast over S and G
        part_j = dp.online_softmax_partial(
            s, jnp.moveaxis(vb, 1, 2)[:, None, :, None])
        part = part_j if part is None else dp.online_softmax_merge(
            part, part_j)
    _, l, acc = part
    return dp.online_softmax_finish(l, acc).astype(v_pool.dtype)


def use_flash(s_q: int, t: int, threshold: int = 1 << 22) -> bool:
    """Blocked path when the scores tensor would exceed ~16 MB f32/head.

    (No divisibility condition: non-divisible T pads to the block grid.)"""
    return s_q * t > threshold


def blocked_impl(backend: str | None = None) -> str:
    """The 'auto' rule's blocked pick, backend-aware.

    On TPU the compiled Pallas kernel is the fast path; on CPU/interpret
    backends the Pallas kernel runs the interpreter and loses badly to
    the pure-JAX blocked graph (BENCH_flash.json: 207ms interpret-mode
    Pallas vs 81ms flash_jax at the same shape), so 'auto' prefers
    'flash' there.  Explicit impl strings are never rewritten — this
    only shapes the 'auto' resolution.
    """
    backend = backend or jax.default_backend()
    return "flash_pallas" if backend == "tpu" else "flash"


def _auto_rule(s_q: int, t: int) -> str:
    """impl='auto': naive for short rows, blocked when the score tensor
    would blow VMEM, and the split-KV decode kernel for the generative-
    inference shape — one query row against a long KV cache.

    The decode pick is MESH-GATED: flash_decode is a single-device
    kernel, and a pallas_call has no partitioning rule — lowered under
    an ambient mesh that shards the KV cache (launch/sharding
    cache_pspecs over a ring axis, the 512-device dry-run cells) it
    would gather every slot's full cache per chip, which is exactly the
    per-chip HBM blowup the dry-run fit check guards.  Sharded decode
    stays on the shardable whole-row naive graph until a shard_map'd
    decode kernel exists (ROADMAP: paged KV follow-up)."""
    if (s_q == 1 and t >= tiling.DECODE_FLASH_MIN_KV
            and dispatch.ambient_mesh() is None):
        return "flash_decode"
    return blocked_impl() if use_flash(s_q, t) else "naive"


def _attention_entry(q, k, v, *, q_pos, kv_valid, causal, scale,
                     softmax_impl="float", ring_axis=""):
    if softmax_impl != "float":
        raise ValueError(
            "attn_impl='flash' is the float blocked path and cannot honor "
            f"softmax_impl={softmax_impl!r} (a dualmode word contract) — "
            "use 'naive' or 'flash_pallas_int'")
    return flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                           causal=causal, scale=scale)


dispatch.register_attention(
    "flash", _attention_entry,
    modes=("float",), grad=True,
    note="pure-JAX blocked online softmax (reference VJP)")
dispatch.set_attention_auto_rule(_auto_rule)
