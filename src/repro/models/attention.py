"""Attention variants: GQA/MHA (qk-norm, qkv-bias), MLA, cross-attention.

All variants share one scores->softmax->combine core so the attention
softmax goes through the configured implementation (float or the paper's
dual-mode unit).  KV caches are explicit pytrees so the serving engine and
the scan-over-layers stack can thread them.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import datapath as dp
from repro.kernels import dispatch
from repro.kernels import flash_attention as _pallas_flash      # noqa: F401
from repro.kernels import flash_attention_int as _pallas_int    # noqa: F401
from repro.kernels import flash_decode as _pallas_decode        # noqa: F401
from repro.kernels import ring_attention as _pallas_ring        # noqa: F401
from . import flash as _flash                                   # noqa: F401
from .layers import (Params, apply_rope, layernorm, linear, linear_init,
                     rmsnorm, rmsnorm_init)


class AttnSpec(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    softmax_impl: str = "float"
    causal: bool = True
    use_rope: bool = True     # Jamba attends without positional encoding
    # auto|naive|flash|flash_pallas|flash_pallas_int|flash_ring
    attn_impl: str = "auto"
    # mesh axis the sequence-parallel ring rotates over ("" = ring off):
    # opts 'auto' into resolving flash_ring when the ambient mesh shards
    # the KV sequence dim over this axis
    ring_axis: str = ""
    # eps for the qk-norm rmsnorms — MUST carry cfg.norm_eps (the spec
    # builders thread it; norms themselves take eps with no default)
    norm_eps: float = 1e-6


class MLASpec(NamedTuple):
    d_model: int
    n_heads: int
    q_lora_rank: int      # 0 = full-rank q projection
    kv_lora_rank: int
    nope_dim: int
    rope_dim: int
    v_dim: int
    rope_theta: float = 10000.0
    softmax_impl: str = "float"
    attn_impl: str = "auto"
    ring_axis: str = ""
    # eps for the q/kv latent rmsnorms — carries cfg.norm_eps
    norm_eps: float = 1e-6


# ---------------- shared core ----------------

def _naive_sdpa(q, k, v, *, q_pos, kv_valid, causal=True,
                scale: float | None = None, softmax_impl: str = "float",
                ring_axis: str = ""):
    """Materialized-scores attention (the short-T / dual-mode path)."""
    b, s_q, t = q.shape[0], q.shape[1], k.shape[1]
    scale = (1.0 / q.shape[-1] ** 0.5) if scale is None else scale
    # accumulate QK^T in f32 with the scale folded into q BEFORE the dot,
    # exactly like the blocked paths — accumulating in the input dtype and
    # casting after made bf16 naive attention diverge from flash
    qf = q.astype(jnp.float32) * scale
    scores = jnp.einsum("bskgh,btkh->bkgst", qf, k.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    t_pos = jnp.arange(t)[None, :]                          # (1,T) cache idx
    mask = kv_valid[:, None, :]                             # (B,1,T)
    if causal:
        mask = mask & (t_pos[:, None, :] <= q_pos[:, :, None])  # (B,S,T)
    else:
        mask = jnp.broadcast_to(mask, (b, s_q, t))
    scores = jnp.where(mask[:, None, None, :, :], scores, dp.MASK_VALUE)
    probs = dispatch.get_softmax(softmax_impl)(scores).astype(v.dtype)
    return jnp.einsum("bkgst,btkh->bskgh", probs, v)


dispatch.register_attention(
    "naive",
    lambda q, k, v, *, q_pos, kv_valid, causal, scale,
    softmax_impl="float", ring_axis="": _naive_sdpa(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal, scale=scale,
        softmax_impl=softmax_impl),
    # whole-row scores through get_softmax: every registered softmax
    # mode is honored verbatim; a plain einsum graph, so XLA shards it
    # cleanly against a sequence-sharded KV cache (mesh_safe)
    modes=("float", "dualmode", "dualmode_snap"), grad=True,
    mesh_safe=True, note="whole-row scores; honors any softmax_impl")


def _sdpa(q, k, v, *, q_pos, kv_valid, softmax_impl, causal=True,
          scale: float | None = None, attn_impl: str = "auto",
          ring_axis: str = ""):
    """q: (B,S,K,G,h)  k/v: (B,T,K,hk)/(B,T,K,hv)  q_pos: (B,S)
    kv_valid: (B,T) bool.

    Returns (B,S,K,G,hv).  Causality: kv position t attends iff
    kv_valid[t] and (not causal or t_pos <= q_pos).  kv positions are
    their cache indices (prefill writes at [0..S), decode appends).

    Dispatch goes through the kernel registry (kernels/dispatch.py):
    'auto' streams KV through the blocked online-softmax path when the
    (S,T) score tile is too large to materialize (models/flash.py, or the
    Pallas kernel with attn_impl='flash_pallas') — same log-domain
    arithmetic as the paper's unit, in streaming form.  Resolution is
    softmax-aware: softmax_impl='dualmode' runs the bit-accurate unit
    whole-row on the naive path (short T: encoder blocks), through the
    snapped one-sweep int kernel (attn_impl='flash_pallas_int') when
    streamed, the int split-KV path inside 'flash_decode' at decode
    shapes, and the int monoid ring under a mesh — it is never silently
    dropped to the float datapath on ANY phase.
    """
    s_q, t = q.shape[1], k.shape[1]
    impl = dispatch.resolve_attention(attn_impl, s_q, t,
                                      softmax_impl=softmax_impl,
                                      ring_axis=ring_axis)
    return dispatch.get_attention(impl)(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=scale, softmax_impl=softmax_impl, ring_axis=ring_axis)


def _sdpa_paged(q, k_pool, v_pool, *, block_tables, q_pos, kv_valid,
                softmax_impl, causal=True, scale: float | None = None,
                attn_impl: str = "auto", ring_axis: str = ""):
    """The paged twin of :func:`_sdpa`: K/V live in (N, bs, K, h) pools
    addressed through (B, max_blocks) block tables.

    Resolution is the SAME dense rule at the logical cache extent —
    paged changes the memory layout, never the numerics pick.  When the
    resolved impl has a block-table native mode in the paged registry
    (flash_decode's scalar-prefetch gather) the pools go to the kernel
    untouched; otherwise K/V are gathered dense once and the dense impl
    runs — identical words either way, the gather is pure data movement.
    """
    s_q = q.shape[1]
    t = block_tables.shape[1] * k_pool.shape[1]
    impl = dispatch.resolve_attention(attn_impl, s_q, t,
                                      softmax_impl=softmax_impl,
                                      ring_axis=ring_axis)
    fn = dispatch.get_paged_attention(impl) if s_q == 1 else None
    if fn is not None:
        return fn(q, k_pool, v_pool, block_tables=block_tables, q_pos=q_pos,
                  kv_valid=kv_valid, causal=causal, scale=scale,
                  softmax_impl=softmax_impl, ring_axis=ring_axis)
    return dispatch.get_attention(impl)(
        q, paged_gather(k_pool, block_tables),
        paged_gather(v_pool, block_tables), q_pos=q_pos, kv_valid=kv_valid,
        causal=causal, scale=scale, softmax_impl=softmax_impl,
        ring_axis=ring_axis)


def paged_write(pool, new, pos, block_tables):
    """Scatter ``new`` (B,S,...) into the (N,bs,...) pool at logical
    offset ``pos`` through each row's block table.

    Logical position p of row b lands in pool block
    ``block_tables[b, p // bs]`` at offset ``p % bs``.  Positions past
    the table's extent — and table entries that ARE the sentinel — clamp
    into sentinel block 0, which is never referenced by a valid key, so
    pad rows scatter harmlessly instead of corrupting live blocks.
    ``pos`` may be scalar or (B,), same contract as :func:`_write_seq`.
    """
    n, bs = pool.shape[:2]
    b, sl = new.shape[:2]
    nblk = block_tables.shape[1]
    off0 = pos[:, None] if jnp.ndim(pos) else pos
    logpos = jnp.broadcast_to(off0 + jnp.arange(sl)[None, :], (b, sl))
    blk, off = logpos // bs, logpos % bs
    phys = jnp.take_along_axis(block_tables, jnp.clip(blk, 0, nblk - 1),
                               axis=1)
    phys = jnp.where((blk >= 0) & (blk < nblk), phys, 0)
    flat = (phys * bs + off).reshape(-1)
    pool_flat = pool.reshape((n * bs,) + pool.shape[2:])
    pool_flat = pool_flat.at[flat].set(
        new.astype(pool.dtype).reshape((b * sl,) + new.shape[2:]))
    return pool_flat.reshape(pool.shape)


def paged_gather(pool, block_tables):
    """Materialize the dense (B, max_blocks*bs, ...) view of a paged
    cache — the fallback for impls without a native block-table mode
    (and the whole story for MLA, whose latent must expand densely
    anyway before attention)."""
    b, nblk = block_tables.shape
    dense = pool[block_tables]                 # (B, nblk, bs, ...)
    return dense.reshape((b, nblk * pool.shape[1]) + pool.shape[2:])


def _write_seq(buf, new, pos):
    """Write `new` (B,S,...) into `buf` (B,Smax,...) at offset `pos`.

    pos may be a scalar (lockstep prefill/decode) or a (B,) vector
    (continuous batching: every slot is at its own depth)."""
    new = new.astype(buf.dtype)
    if jnp.ndim(pos) == 0:
        idx = (0, pos) + (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, new, idx)
    def row(b_, n_, p_):
        return jax.lax.dynamic_update_slice(
            b_, n_, (p_,) + (0,) * (b_.ndim - 1))
    return jax.vmap(row)(buf, new, pos)


def _kv_valid_mask(t: int, pos, sl: int, b: int):
    """(B,T) validity: cache rows [0, pos+sl) hold data."""
    t_idx = jnp.arange(t)[None, :]
    end = (pos + sl if jnp.ndim(pos) == 0 else pos[:, None] + sl)
    return jnp.broadcast_to(t_idx < end, (b, t))


def _update_cache(cache, k_new, v_new, pos):
    """Write (B,S,K,h) at sequence offset pos into (B,Smax,K,h) buffers."""
    return {"k": _write_seq(cache["k"], k_new, pos),
            "v": _write_seq(cache["v"], v_new, pos)}


# ---------------- GQA ----------------

def gqa_init(key, s: AttnSpec, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {"wq": linear_init(ks[0], s.d_model, s.n_heads * s.head_dim, dtype,
                           bias=s.qkv_bias),
         "wk": linear_init(ks[1], s.d_model, s.n_kv_heads * s.head_dim, dtype,
                           bias=s.qkv_bias),
         "wv": linear_init(ks[2], s.d_model, s.n_kv_heads * s.head_dim, dtype,
                           bias=s.qkv_bias),
         "wo": linear_init(ks[3], s.n_heads * s.head_dim, s.d_model, dtype)}
    if s.qk_norm:
        p["qn"] = rmsnorm_init(s.head_dim, dtype)
        p["kn"] = rmsnorm_init(s.head_dim, dtype)
    return p


def gqa_cache_init(s: AttnSpec, batch: int, max_seq: int, dtype) -> Params:
    shape = (batch, max_seq, s.n_kv_heads, s.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_apply(p: Params, s: AttnSpec, x, *, positions, cache=None, pos=0,
              paged=None, prenorm=None):
    """x: (B,S,d).  If cache given: write new kv at `pos`, attend over cache.
    Returns (out, new_cache_or_None).

    ``paged`` (B, max_blocks) int32 block tables switches the cache from
    contiguous (B, Smax, K, h) rows to (N, bs, K, h) pools: writes
    scatter through the table, attention runs :func:`_sdpa_paged`.

    ``prenorm=(norm_params, kind, eps, provider)`` hands this sublayer
    its own input norm (the block's norm1): with a fused provider and
    bias-free projections the norm->QKV seam runs as ONE Pallas kernel
    over the concatenated [wq|wk|wv] panel (kernels/fused_norm
    .norm_linear); otherwise the dense norm applies here and the three
    projections proceed unchanged."""
    b, sl, _ = x.shape
    g = s.n_heads // s.n_kv_heads
    fused_qkv = None
    if prenorm is not None:
        np_, kind, eps, nprov = prenorm
        if nprov is not None and not s.qkv_bias:
            w_cat = jnp.concatenate(
                [p["wq"]["w"], p["wk"]["w"], p["wv"]["w"]], axis=1)
            fused_qkv = nprov["norm_linear"](x, np_["g"], np_.get("b"),
                                             w_cat, kind=kind, eps=eps)
        else:
            x = (rmsnorm if kind == "rms" else layernorm)(np_, x, eps)
    if fused_qkv is not None:
        nq = s.n_heads * s.head_dim
        nk = s.n_kv_heads * s.head_dim
        q = fused_qkv[..., :nq].reshape(b, sl, s.n_heads, s.head_dim)
        k = fused_qkv[..., nq:nq + nk].reshape(b, sl, s.n_kv_heads,
                                               s.head_dim)
        v = fused_qkv[..., nq + nk:].reshape(b, sl, s.n_kv_heads,
                                             s.head_dim)
    else:
        q = linear(p["wq"], x).reshape(b, sl, s.n_heads, s.head_dim)
        k = linear(p["wk"], x).reshape(b, sl, s.n_kv_heads, s.head_dim)
        v = linear(p["wv"], x).reshape(b, sl, s.n_kv_heads, s.head_dim)
    if s.qk_norm:
        q = rmsnorm(p["qn"], q, s.norm_eps)
        k = rmsnorm(p["kn"], k, s.norm_eps)
    if s.use_rope:
        q = apply_rope(q, positions, s.rope_theta)
        k = apply_rope(k, positions, s.rope_theta)
    if paged is not None:
        cache = {"k": paged_write(cache["k"], k, pos, paged),
                 "v": paged_write(cache["v"], v, pos, paged)}
        t = paged.shape[1] * cache["k"].shape[1]
        kv_valid = _kv_valid_mask(t, pos, sl, b)
        qg = q.reshape(b, sl, s.n_kv_heads, g, s.head_dim)
        o = _sdpa_paged(qg, cache["k"], cache["v"], block_tables=paged,
                        q_pos=positions, kv_valid=kv_valid,
                        softmax_impl=s.softmax_impl, causal=s.causal,
                        attn_impl=s.attn_impl, ring_axis=s.ring_axis)
        o = o.reshape(b, sl, s.n_heads * s.head_dim)
        return linear(p["wo"], o), cache
    if cache is not None:
        cache = _update_cache(cache, k, v, pos)
        k_all, v_all = cache["k"], cache["v"]
        kv_valid = _kv_valid_mask(k_all.shape[1], pos, sl, b)
    else:
        k_all, v_all = k, v
        kv_valid = jnp.ones((b, sl), dtype=bool)
    qg = q.reshape(b, sl, s.n_kv_heads, g, s.head_dim)
    o = _sdpa(qg, k_all, v_all, q_pos=positions, kv_valid=kv_valid,
              softmax_impl=s.softmax_impl, causal=s.causal,
              attn_impl=s.attn_impl, ring_axis=s.ring_axis)
    o = o.reshape(b, sl, s.n_heads * s.head_dim)
    return linear(p["wo"], o), cache


# ---------------- MLA (DeepSeek-V2 / MiniCPM3 style) ----------------

def mla_init(key, s: MLASpec, dtype) -> Params:
    ks = jax.random.split(key, 6)
    qk_head = s.nope_dim + s.rope_dim
    p: Params = {}
    if s.q_lora_rank:
        p["wq_a"] = linear_init(ks[0], s.d_model, s.q_lora_rank, dtype)
        p["q_norm"] = rmsnorm_init(s.q_lora_rank, dtype)
        p["wq_b"] = linear_init(ks[1], s.q_lora_rank, s.n_heads * qk_head, dtype)
    else:
        p["wq"] = linear_init(ks[0], s.d_model, s.n_heads * qk_head, dtype)
    p["wkv_a"] = linear_init(ks[2], s.d_model, s.kv_lora_rank + s.rope_dim, dtype)
    p["kv_norm"] = rmsnorm_init(s.kv_lora_rank, dtype)
    p["wkv_b"] = linear_init(ks[3], s.kv_lora_rank,
                             s.n_heads * (s.nope_dim + s.v_dim), dtype)
    p["wo"] = linear_init(ks[4], s.n_heads * s.v_dim, s.d_model, dtype)
    return p


def mla_cache_init(s: MLASpec, batch: int, max_seq: int, dtype) -> Params:
    """MLA caches the *compressed* latent + shared rope key — the memory win."""
    return {"ckv": jnp.zeros((batch, max_seq, s.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_seq, s.rope_dim), dtype)}


def mla_apply(p: Params, s: MLASpec, x, *, positions, cache=None, pos=0,
              paged=None):
    b, sl, _ = x.shape
    qk_head = s.nope_dim + s.rope_dim
    if s.q_lora_rank:
        q = linear(p["wq_b"],
                   rmsnorm(p["q_norm"], linear(p["wq_a"], x), s.norm_eps))
    else:
        q = linear(p["wq"], x)
    q = q.reshape(b, sl, s.n_heads, qk_head)
    q_nope, q_rope = q[..., : s.nope_dim], q[..., s.nope_dim:]
    q_rope = apply_rope(q_rope, positions, s.rope_theta)

    kv_a = linear(p["wkv_a"], x)                       # (B,S,kv_lora+rope)
    ckv = rmsnorm(p["kv_norm"], kv_a[..., : s.kv_lora_rank], s.norm_eps)
    k_rope_new = apply_rope(kv_a[..., s.kv_lora_rank:][:, :, None, :],
                            positions, s.rope_theta)[:, :, 0, :]

    if paged is not None:
        # MLA pages the COMPRESSED latent + rope key; the latent must
        # expand densely before attention regardless, so the paged win is
        # pure storage — gather once, then the dense path is unchanged.
        cache = {"ckv": paged_write(cache["ckv"], ckv, pos, paged),
                 "krope": paged_write(cache["krope"], k_rope_new, pos,
                                      paged)}
        ckv_all = paged_gather(cache["ckv"], paged)
        krope_all = paged_gather(cache["krope"], paged)
        t = ckv_all.shape[1]
        kv_valid = _kv_valid_mask(t, pos, sl, b)
    elif cache is not None:
        ckv_all = _write_seq(cache["ckv"], ckv, pos)
        krope_all = _write_seq(cache["krope"], k_rope_new, pos)
        cache = {"ckv": ckv_all, "krope": krope_all}
        t = ckv_all.shape[1]
        kv_valid = _kv_valid_mask(t, pos, sl, b)
    else:
        ckv_all, krope_all = ckv, k_rope_new
        t = sl
        kv_valid = jnp.ones((b, sl), dtype=bool)

    # expand latent -> per-head k_nope / v (naive MLA; absorbed form is a
    # perf option, see EXPERIMENTS.md §Perf)
    kv = linear(p["wkv_b"], ckv_all).reshape(b, t, s.n_heads,
                                             s.nope_dim + s.v_dim)
    k_nope, v = kv[..., : s.nope_dim], kv[..., s.nope_dim:]

    # route through the shared core: concat rope/nope halves so MLA uses
    # the same naive/flash dispatch as GQA (K=n_heads, G=1)
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1) \
        .reshape(b, sl, s.n_heads, 1, qk_head)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_all[:, :, None, :],
                                  (b, t, s.n_heads, s.rope_dim))], axis=-1)
    o = _sdpa(q_cat, k_cat, v, q_pos=positions, kv_valid=kv_valid,
              softmax_impl=s.softmax_impl, causal=True,
              scale=1.0 / qk_head ** 0.5, attn_impl=s.attn_impl,
              ring_axis=s.ring_axis)
    o = o.reshape(b, sl, s.n_heads * s.v_dim)
    return linear(p["wo"], o), cache


# ---------------- cross attention (VLM / enc-dec) ----------------

def cross_init(key, s: AttnSpec, dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {"wq": linear_init(ks[0], s.d_model, s.n_heads * s.head_dim, dtype),
            "wk": linear_init(ks[1], s.d_model, s.n_kv_heads * s.head_dim, dtype),
            "wv": linear_init(ks[2], s.d_model, s.n_kv_heads * s.head_dim, dtype),
            "wo": linear_init(ks[3], s.n_heads * s.head_dim, s.d_model, dtype)}


def cross_kv(p: Params, s: AttnSpec, enc):
    """Precompute cross K/V from encoder states (prefill-time, cached)."""
    b, t, _ = enc.shape
    k = linear(p["wk"], enc).reshape(b, t, s.n_kv_heads, s.head_dim)
    v = linear(p["wv"], enc).reshape(b, t, s.n_kv_heads, s.head_dim)
    return {"k": k, "v": v}


def cross_apply(p: Params, s: AttnSpec, x, kv: Params):
    b, sl, _ = x.shape
    g = s.n_heads // s.n_kv_heads
    q = linear(p["wq"], x).reshape(b, sl, s.n_kv_heads, g, s.head_dim)
    t = kv["k"].shape[1]
    valid = jnp.ones((b, t), dtype=bool)
    o = _sdpa(q, kv["k"], kv["v"], q_pos=jnp.zeros((b, sl), jnp.int32),
              kv_valid=valid, softmax_impl=s.softmax_impl, causal=False,
              attn_impl=s.attn_impl, ring_axis=s.ring_axis)
    return linear(p["wo"], o.reshape(b, sl, s.n_heads * s.head_dim))
