"""Mamba-1 selective SSM block (the Jamba mixer).

Faithful structure: in_proj -> causal depthwise conv -> SiLU -> selective
(dt, B, C) projections -> discretized diagonal SSM scan -> gate -> out_proj.

The scan is a `jax.lax.scan` over time with per-step discretization, so the
(B, S, d_inner, d_state) tensor is never materialized (at Jamba scale that
tensor would be ~17 GB/device).  A chunked variant for better TPU pipelining
is a §Perf option.  Decode carries (conv window, ssm state) — O(1) in
sequence length, which is what makes `long_500k` runnable for this family.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import Params, dense_init, linear, linear_init
from .scan_utils import chunked_time_scan


class MambaSpec(NamedTuple):
    d_model: int
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0          # 0 -> ceil(d_model/16)

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def mamba_init(key, s: MambaSpec, dtype) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "in_proj": linear_init(ks[0], s.d_model, 2 * s.d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, s.d_inner)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((s.d_inner,), dtype),
        "x_proj": linear_init(ks[2], s.d_inner, s.rank + 2 * s.d_state, dtype),
        "dt_proj": {"w": dense_init(ks[3], s.rank, s.d_inner, dtype),
                    "b": jnp.full((s.d_inner,), -4.6, dtype)},  # softplus~0.01
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32),
            (s.d_inner, s.d_state))).astype(dtype),
        "D": jnp.ones((s.d_inner,), dtype),
        "out_proj": linear_init(ks[4], s.d_inner, s.d_model, dtype),
    }


def mamba_state_init(s: MambaSpec, batch: int, dtype) -> Params:
    return {"conv": jnp.zeros((batch, s.d_conv - 1, s.d_inner), dtype),
            "ssm": jnp.zeros((batch, s.d_inner, s.d_state), jnp.float32)}


def _ssm_scan(p, s: MambaSpec, xc, dt, bmat, cmat, h0):
    """Sequential selective scan.  xc,dt: (B,S,di); bmat,cmat: (B,S,ds)."""
    a = -jnp.exp(p["A_log"].astype(jnp.float32))              # (di,ds)

    out_dtype = xc.dtype

    def step(h, inp):
        x_t, dt_t, b_t, c_t = [t.astype(jnp.float32) for t in inp]
        da = jnp.exp(dt_t[..., None] * a)                     # (B,di,ds)
        dbx = (dt_t * x_t)[..., None] * b_t[:, None, :]       # (B,di,ds)
        h = h * da + dbx
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y.astype(out_dtype)

    # keep the big (S,B,di) streams in model dtype — the f32 cast happens
    # per step on (B,di) slices (a full-S f32 copy is 4x the layer weights)
    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
    h, ys = chunked_time_scan(step, h0, xs)
    return h, jnp.moveaxis(ys, 0, 1).astype(xc.dtype)         # (B,S,di)


def mamba_apply(p: Params, s: MambaSpec, x, *, state=None, axes=None):
    """x: (B,S,d).  state: decode-mode carry (None for train/prefill-from-0).

    Returns (y, new_state).  In decode mode S is the new-token count (1).

    `axes` = (dp, tp) mesh-axis names: the SSM scan runs time-major over
    full S, so this layer trades the residual stream's seq sharding for
    d_inner sharding — xz/xc/y live (dp, None, tp) and the recurrent state
    (dp, tp, None).  Without the pins GSPMD replicates BOTH dims
    (measured: 2 GiB f32 per intermediate per chip at jamba train_4k).
    """
    if axes is not None:
        from jax.sharding import PartitionSpec as P
        dp, tp = axes
        pin = jax.lax.with_sharding_constraint
    b, sl, _ = x.shape
    xz = linear(p["in_proj"], x)
    if axes is not None:
        xz = pin(xz, P(dp, None, tp))
    x_in, z = jnp.split(xz, 2, axis=-1)                       # (B,S,di)

    conv_state = (state["conv"] if state is not None else
                  jnp.zeros((b, s.d_conv - 1, s.d_inner), x.dtype))
    xpad = jnp.concatenate([conv_state, x_in], axis=1)        # (B,S+3,di)
    new_conv = xpad[:, -(s.d_conv - 1):, :]
    xc = sum(xpad[:, i:i + sl, :] * p["conv_w"][i] for i in range(s.d_conv))
    xc = jax.nn.silu(xc + p["conv_b"])

    proj = linear(p["x_proj"], xc)
    dt = proj[..., : s.rank]
    bmat = proj[..., s.rank: s.rank + s.d_state]
    cmat = proj[..., s.rank + s.d_state:]
    dt = jax.nn.softplus(linear(p["dt_proj"], dt))            # (B,S,di)

    h0 = (state["ssm"] if state is not None else
          jnp.zeros((b, s.d_inner, s.d_state), jnp.float32))
    if axes is not None:
        xc = pin(xc, P(dp, None, tp))
        dt = pin(dt, P(dp, None, tp))
        h0 = pin(h0, P(dp, tp, None))
    h, y = _ssm_scan(p, s, xc, dt, bmat, cmat, h0)

    y = y + xc * p["D"]
    y = y * jax.nn.silu(z)
    if axes is not None:
        y = pin(y, P(dp, None, tp))
    return linear(p["out_proj"], y), {"conv": new_conv, "ssm": h}
