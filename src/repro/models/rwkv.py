"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Faithful structure (arXiv:2404.05892):
  * token-shift lerps with data-dependent deltas (ddlerp, low-rank)
  * r/k/v/g projections; per-channel decay w_t = exp(-exp(wb + lora(x)))
    (the data-dependent decay that defines Finch)
  * per-head matrix-valued state S (hd x hd):  S_t = diag(w_t) S_{t-1} +
    k_t^T v_t;  y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
  * group-norm per head, SiLU(g) gate
  * channel-mix: squared-ReLU FFN with token shift (paper technique N/A
    here — relu^2 is not sigmoid-family; see DESIGN.md §6)

Attention-free: state is O(1) in sequence length -> `long_500k` runs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import Params, dense_init, linear, linear_init
from .scan_utils import chunked_time_scan


class RWKVSpec(NamedTuple):
    d_model: int
    n_heads: int
    d_ff: int
    lora_r: int = 64      # decay/ddlerp low-rank width

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def rwkv_tm_init(key, s: RWKVSpec, dtype) -> Params:
    ks = jax.random.split(key, 12)
    d, r = s.d_model, s.lora_r
    return {
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5).astype(dtype),
        "dd_w1": dense_init(ks[1], d, 5 * r, dtype, scale=0.01),
        "dd_w2": (jax.random.normal(ks[2], (5, r, d)) * 0.01).astype(dtype),
        "wr": linear_init(ks[3], d, d, dtype),
        "wk": linear_init(ks[4], d, d, dtype),
        "wv": linear_init(ks[5], d, d, dtype),
        "wg": linear_init(ks[6], d, d, dtype),
        "wo": linear_init(ks[7], d, d, dtype),
        "w_base": jnp.full((d,), -6.0, dtype),
        "w_lora1": dense_init(ks[8], d, r, dtype, scale=0.01),
        "w_lora2": dense_init(ks[9], r, d, dtype, scale=0.01),
        "u": (jax.random.normal(ks[10], (s.n_heads, s.head_dim)) * 0.1
              ).astype(dtype),
        "ln_g": jnp.ones((d,), dtype),
        "ln_b": jnp.zeros((d,), dtype),
    }


def rwkv_cm_init(key, s: RWKVSpec, dtype) -> Params:
    ks = jax.random.split(key, 3)
    d = s.d_model
    return {"mu_k": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dtype),
            "mu_r": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dtype),
            "wk": linear_init(ks[1], d, s.d_ff, dtype),
            "wv": linear_init(ks[2], s.d_ff, d, dtype),
            "wr": linear_init(ks[0], d, d, dtype)}


def rwkv_state_init(s: RWKVSpec, batch: int, dtype) -> Params:
    return {"tm_x": jnp.zeros((batch, s.d_model), dtype),
            "cm_x": jnp.zeros((batch, s.d_model), dtype),
            "wkv": jnp.zeros((batch, s.n_heads, s.head_dim, s.head_dim),
                             jnp.float32)}


def _shift(x, x_prev):
    """Token shift: previous token's embedding (carry x_prev for t=0)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _group_norm(y, g, b, n_heads, eps=64e-5):
    bsz, sl, d = y.shape
    yh = y.reshape(bsz, sl, n_heads, d // n_heads).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return yh.reshape(bsz, sl, d).astype(y.dtype) * g + b


def rwkv_time_mix(p: Params, s: RWKVSpec, x, *, state):
    """x: (B,S,d); state dict with tm_x (B,d) and wkv (B,H,hd,hd)."""
    b, sl, d = x.shape
    hp, hd = s.n_heads, s.head_dim
    xprev = _shift(x, state["tm_x"])
    xx = xprev - x

    # ddlerp: data-dependent per-branch mix factors
    base = x + xx * p["mu"][0]
    dd = jnp.tanh(base @ p["dd_w1"]).reshape(b, sl, 5, s.lora_r)
    delta = jnp.einsum("bsfr,frd->bsfd", dd, p["dd_w2"])      # (B,S,5,d)
    mix = p["mu"][None, None] + delta                         # (B,S,5,d)
    xr, xk, xv, xw, xg = [x + xx * mix[:, :, i] for i in range(5)]

    r = linear(p["wr"], xr).reshape(b, sl, hp, hd)
    k = linear(p["wk"], xk).reshape(b, sl, hp, hd)
    v = linear(p["wv"], xv).reshape(b, sl, hp, hd)
    g = linear(p["wg"], xg)
    # data-dependent decay (per channel, in (0,1))
    w = jnp.exp(-jnp.exp(p["w_base"].astype(jnp.float32)
                         + (jnp.tanh(xw @ p["w_lora1"]) @ p["w_lora2"]
                            ).astype(jnp.float32)))
    w = w.reshape(b, sl, hp, hd)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                              # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv",
                       r_t, S + p["u"].astype(jnp.float32)[..., None] * kv)
        S = S * w_t[..., :, None] + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
               for t in (r, k, v, w))
    S, ys = chunked_time_scan(step, state["wkv"], xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sl, d).astype(x.dtype)
    y = _group_norm(y, p["ln_g"], p["ln_b"], hp)
    y = y * jax.nn.silu(g)
    new_state = {"tm_x": x[:, -1, :], "wkv": S}
    return linear(p["wo"], y), new_state


def rwkv_channel_mix(p: Params, s: RWKVSpec, x, *, state):
    xprev = _shift(x, state["cm_x"])
    xx = xprev - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    k = jnp.square(jax.nn.relu(linear(p["wk"], xk)))          # relu^2
    kv = linear(p["wv"], k)
    out = jax.nn.sigmoid(linear(p["wr"], xr)) * kv
    return out, {"cm_x": x[:, -1, :]}
