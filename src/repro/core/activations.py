"""Activation registry — every GELU/SiLU variant the paper compares, plus
the framework's standard activations.

Variants (paper Table I naming):
  'gelu_exact'        FP32 erf GELU                       (the 'FP32' model)
  'gelu_tanh'         tanh-approximated GELU (Eq. 4)
  'gelu_via_softmax'  Eq. 8 in float — algorithm-faithful, no quantization
  'gelu_dualmode'     Eq. 8 through the bit-accurate int32 dual-mode unit
                      (the 'Proposed' model)
  'igelu'             I-BERT integer GELU                 (the 'i-GELU' model)
  'silu' / 'silu_via_softmax' / 'silu_dualmode'
                      exact-identity SiLU through the same unit (beyond-paper)
  'relu2'             squared ReLU (RWKV-6 channel mix; technique N/A)

Quantized variants use a straight-through estimator so they are trainable
drop-ins (forward = unit bits, backward = float surrogate gradient).
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import datapath as _dp

from . import igelu as _igelu
from . import softmax_unit as _unit


def gelu_exact(x):
    return 0.5 * x * (1.0 + jax.lax.erf(x / math.sqrt(2.0)))


def gelu_tanh(x):
    return 0.5 * x * (1.0 + jnp.tanh(_dp.gelu_k(x)))


def gelu_via_softmax(x):
    """Eq. (8): z * softmax_1^2([k, -k]) == z * sigmoid(2k), float."""
    return _dp.gelu(x)


def silu(x):
    return x * jax.nn.sigmoid(x)


def silu_via_softmax(x):
    """Exact identity: z * softmax_1^2([z/2, -z/2])."""
    return _dp.silu(x)


def relu2(x):
    return jnp.square(jax.nn.relu(x))


def _ste(fwd_quant: Callable, surrogate: Callable) -> Callable:
    """Straight-through wrapper: forward bits, backward surrogate grad."""
    def f(x):
        return surrogate(x) + jax.lax.stop_gradient(fwd_quant(x) - surrogate(x))
    return f


gelu_dualmode = _ste(_unit.gelu_dualmode, gelu_tanh)
silu_dualmode = _ste(_unit.silu_dualmode, silu)
igelu_st = _ste(_igelu.igelu_quant, gelu_tanh)


ACTIVATIONS: dict[str, Callable] = {
    "gelu_exact": gelu_exact,
    "gelu_tanh": gelu_tanh,
    "gelu_via_softmax": gelu_via_softmax,
    "gelu_dualmode": gelu_dualmode,
    "igelu": igelu_st,
    "igelu_float": _igelu.igelu_float,
    "silu": silu,
    "silu_via_softmax": silu_via_softmax,
    "silu_dualmode": silu_dualmode,
    "relu2": relu2,
}


def get_activation(name: str) -> Callable:
    try:
        return ACTIVATIONS[name]
    except KeyError:
        raise ValueError(f"unknown activation {name!r}; have {sorted(ACTIVATIONS)}")
