"""i-GELU — the integer-only GELU of I-BERT [Kim et al., ICML 2021].

This is the state-of-the-art baseline the paper compares against (its
'i-GELU' model in Table I and the 'N/2 i-GELU units' design of Fig. 4).

i-GELU approximates erf with a clipped second-order polynomial

    erf(x) ~= sign(x) * [ a (min(|x|, -b) + b)^2 + 1 ],   a=-0.2888, b=-1.769

and evaluates GELU(x) = x * 0.5 * (1 + erf(x / sqrt(2))) in integer
arithmetic.  We implement both the float form and a bit-level int32 form in
the same S5.10 regime as the dual-mode unit, so hardware-style comparisons
(benchmarks/fig4) are apples-to-apples.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from .fixedpoint import I32, IN_FRAC, dequantize, quantize

_A = -0.2888
_B = -1.769
_INV_SQRT2_Q = int(round((1.0 / math.sqrt(2.0)) * (1 << 15)))   # Q0.15
_B_Q = int(round(-_B * (1 << IN_FRAC)))                         # 1.769 @ S5.10
_A_Q = int(round(-_A * (1 << 14)))                              # 0.2888 @ Q.14
_ONE = 1 << IN_FRAC


def igelu_float(x):
    """Reference float i-GELU (I-BERT eq. 5)."""
    s = x / math.sqrt(2.0)
    l = jnp.sign(s) * (_A * (jnp.clip(jnp.abs(s), max=-_B) + _B) ** 2 + 1.0)
    return x * 0.5 * (1.0 + l)


def igelu_int(x_fx):
    """Bit-level int32 i-GELU.  S5.10 -> S5.10."""
    x = x_fx.astype(I32)
    s = (x * I32(_INV_SQRT2_Q)) >> 15                 # x/sqrt2 @ 2**-IN_FRAC
    t = jnp.minimum(jnp.abs(s), I32(_B_Q)) - I32(_B_Q)          # <= 0
    sq = (t * t) >> IN_FRAC                           # @ 2**-IN_FRAC
    poly = I32(_ONE) - ((sq * I32(_A_Q)) >> 14)       # a*sq+1, @ 2**-IN_FRAC
    erf = jnp.sign(s) * poly
    # x * (1 + erf) / 2 : product @ 2**-2*IN_FRAC -> shift by IN_FRAC+1
    return (x * (I32(_ONE) + erf)) >> (IN_FRAC + 1)


def igelu_quant(x):
    """float in/out through the int unit (the Table-I 'i-GELU' model)."""
    return dequantize(igelu_int(quantize(x)), IN_FRAC)
