"""Core: the paper's contribution — GELU via a dual-mode softmax unit."""
from .activations import ACTIVATIONS, get_activation  # noqa: F401
from .softmax_unit import (  # noqa: F401
    gelu_dualmode, gelu_int, silu_dualmode, silu_int,
    softmax_dualmode, softmax_int,
)
