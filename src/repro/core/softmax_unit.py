"""The dual-mode softmax unit (paper §III, Fig. 2/3) — bit-accurate emulation.

Normal mode implements Eq. (10) — division in the logarithm domain:

    y_i = exp(x_i - max(x) - log(sum_j exp(x_j - max(x))))
        = 2**(t_i - lmax - log2(sum_j 2**(t_j - lmax)))     with t = x*log2(e)

Each exponential is decomposed 2**t = 2**u * 2**v (u integer -> shift,
v in [0,1) -> 8-piece PWL); the log uses a leading-one detector plus a
mantissa PWL (the forward log converter of [Kim 2006]).

GELU mode (Fig. 3) computes, per element z (Eq. 8):

    k       = sqrt(2/pi) * (z + 0.044715 z^3)
    GELU(z) = z * softmax_1^2([k, -k])

by running the *same* exp/log datapath independently on the two-element
vector [k, -k].  SiLU mode (ours, beyond-paper) is the exact identity
SiLU(z) = z * softmax_1^2([z/2, -z/2]) — only the k-datapath differs.

Everything here is int32 (inputs S5.10) and jnp-traceable, so the same code
is the Pallas kernel body's arithmetic and the oracle for its tests.

This module is the tree's single INT definition of the unit's arithmetic;
the float-lane form lives in ``repro.kernels.datapath`` (the only other
place the log2e / GELU-cubic constants appear).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from .fixedpoint import (
    EXP_FRAC, I32, IN_FRAC, T_FRAC,
    dequantize, floor_log2, mantissa_frac, quantize, sat_rshift,
)
from .pwl import exp2_frac_int, log2_mant_int

# fixed-point constants (the ROM words of the datapath)
_LOG2E_FRAC = 12
LOG2E_Q = int(round(math.log2(math.e) * (1 << _LOG2E_FRAC)))        # 5909
GELU_A_Q = int(round(0.044715 * (1 << 16)))                         # cubic coeff
GELU_C_Q = int(round(math.sqrt(2.0 / math.pi) * (1 << 14)))         # sqrt(2/pi)

# Sentinel word for positions that must carry EXACTLY zero mass (the int
# analogue of the float paths' -inf on tiling-phantom keys).  Any word w
# with w - m <= -(32 << IN_FRAC) hits the input saturation of
# ``_to_log2_domain`` and its exponential underflows the 14-bit output to
# the literal 0 word, so it contributes nothing to the sum, the probs, or
# (being far below any S5.10 word) the running max.  -2**20 keeps that
# margin for every possible S5.10 max (>= IN_MIN) with int32 to spare.
PHANTOM_Q = -(1 << 20)


def _to_log2_domain(d, in_frac: int):
    """t = d * log2(e) at scale 2**-T_FRAC (d at scale 2**-in_frac, d<=0).

    d is saturated at -32 (exp(-32) ~ 2**-46 underflows the 14-bit output
    anyway) — this keeps the int32 product in range for any input pair,
    exactly like the input saturation stage of the hardware unit.
    """
    d = jnp.maximum(d.astype(I32), I32(-32) << in_frac)
    return (d * I32(LOG2E_Q)) >> (in_frac + _LOG2E_FRAC - T_FRAC)


def _exp2_int(t):
    """2**t for t <= 0 at scale 2**-T_FRAC -> result at scale 2**-EXP_FRAC.

    Split t = u + v, u = floor(t) (arithmetic shift), v in [0,1):
    2**u is a right shift of the PWL 2**v value.
    """
    u = t >> T_FRAC                                   # floor (t<=0 -> u<=0)
    v = t - (u << T_FRAC)                             # in [0, 2**T_FRAC)
    p = exp2_frac_int(v)                              # [1,2) @ 2**-EXP_FRAC
    return sat_rshift(p, -u)


def _log2_int(s, s_frac: int):
    """log2 of s (int > 0 at scale 2**-s_frac) at scale 2**-T_FRAC."""
    e_pos = floor_log2(s)
    frac = mantissa_frac(s, e_pos, T_FRAC)
    log2m = log2_mant_int(frac)
    return ((e_pos - s_frac) << T_FRAC) + log2m


def softmax_int(x_fx, axis: int = -1, guard_shift: int | None = None):
    """Normal mode: Eq. (10) over `axis`.  x_fx int32 @ S5.10.

    Returns probabilities at scale 2**-EXP_FRAC (int32).
    `guard_shift` down-shifts each exponent before the sum so that rows up
    to 2**(16+guard_shift) elements cannot overflow the int32 accumulator.
    """
    n = x_fx.shape[axis]
    if guard_shift is None:
        guard_shift = max(0, n.bit_length() - 16)
    m = jnp.max(x_fx, axis=axis, keepdims=True)
    t = _to_log2_domain(x_fx - m, IN_FRAC)            # <= 0
    e = _exp2_int(t)                                  # @ 2**-EXP_FRAC
    s = jnp.sum(e >> guard_shift, axis=axis, keepdims=True)
    s = jnp.maximum(s, 1)                             # log(0) guard
    log2s = _log2_int(s, EXP_FRAC - guard_shift)      # @ 2**-T_FRAC
    w = t - log2s                                     # log2 of prob, <= ~0
    return _exp2_int(jnp.minimum(w, 0))


def _pair_softmax_first_int(k_fx, k_frac: int):
    """softmax_1^2([k, -k]) through the shared exp/log datapath.

    k_fx int32 at scale 2**-k_frac.  Returns sigma(2k) @ 2**-EXP_FRAC.
    This is the GELU-mode inner loop: max = |k| (the pairwise max-tree tap),
    two exponents, the pair adder-tree tap, one pair log unit, one exp.
    """
    amax = jnp.abs(k_fx)
    t1 = _to_log2_domain(k_fx - amax, k_frac)
    t2 = _to_log2_domain(-k_fx - amax, k_frac)
    e1 = _exp2_int(t1)
    e2 = _exp2_int(t2)
    s = jnp.maximum(e1 + e2, 1)                       # in (2**14, 2**15]
    log2s = _log2_int(s, EXP_FRAC)
    w = jnp.minimum(t1 - log2s, 0)
    return _exp2_int(w)


def gelu_k_int(z_fx):
    """k = sqrt(2/pi) * (z + 0.044715 z^3) in S5.10 -> int32 @ 2**-IN_FRAC.

    The cubic-path input is saturated at |z| <= 8 (k(8) = 24.6 already
    drives sigma(2k) to exactly 0/1 in 14-bit arithmetic), which bounds
    every int32 intermediate — the hardware's input saturation stage.
    """
    z = jnp.clip(z_fx.astype(I32), I32(-8) << IN_FRAC, I32(8) << IN_FRAC)
    z2 = (z * z) >> IN_FRAC
    z3 = (z2 * z) >> IN_FRAC
    az3 = (z3 * I32(GELU_A_Q)) >> 16
    return ((z + az3) * I32(GELU_C_Q)) >> 14


def gelu_int(z_fx):
    """GELU mode (Eq. 8): z * softmax_1^2([k, -k]).  S5.10 -> S5.10."""
    k = gelu_k_int(z_fx)
    sig = _pair_softmax_first_int(k, IN_FRAC)          # @ 2**-EXP_FRAC
    return (z_fx.astype(I32) * sig) >> EXP_FRAC


def silu_int(z_fx):
    """Exact-identity SiLU mode: z * softmax_1^2([z/2, -z/2]).

    k = z/2 is represented losslessly by reinterpreting z at scale
    2**-(IN_FRAC+1) — zero extra datapath.
    """
    sig = _pair_softmax_first_int(z_fx.astype(I32), IN_FRAC + 1)
    return (z_fx.astype(I32) * sig) >> EXP_FRAC


# --- blocked / online evaluation of normal mode -----------------------------
#
# The float flash recurrence corrects old partial sums by exp(m_old - m_new)
# when the running max moves; that correction is NOT exact in the PWL int
# domain (the 8-piece exp2 is not multiplicative), so a one-sweep online
# rescale would change words.  What IS exact: the max fold and the
# guard-shifted sum fold are associative int32 reductions, and the emit
# step is elementwise given the final (m, l).  Streaming therefore runs
# three KV sweeps — max, sum, emit — each an online fold whose carry
# (m, then l) never leaves the int domain, and ANY blocking schedule
# telescopes to the exact whole-row :func:`softmax_int` words.  These
# three steps are jnp-traceable and shared verbatim by the Pallas kernel
# body (``kernels/flash_attention_int.py``) and the pure-jnp blocked
# oracle below.

def online_max_int(m, x_blk, axis: int = -1):
    """Sweep 1 fold: running row max.  Init carry with ``PHANTOM_Q``."""
    return jnp.maximum(m, jnp.max(x_blk.astype(I32), axis=axis,
                                  keepdims=True))


def online_sum_int(l, m, x_blk, guard_shift: int, axis: int = -1):
    """Sweep 2 fold: guard-shifted int32 row-sum carry (init 0).

    ``m`` is the FINAL sweep-1 max; the guard shift bounds the carry for
    rows up to 2**(16+guard_shift) elements exactly as in the whole-row
    unit, so the blocked carry can never overflow int32 either.
    """
    t = _to_log2_domain(x_blk.astype(I32) - m, IN_FRAC)
    e = _exp2_int(t)
    return l + jnp.sum(e >> guard_shift, axis=axis, keepdims=True)


def online_probs_int(m, l, x_blk, guard_shift: int):
    """Sweep 3 emit: this block's probability words @ 2**-EXP_FRAC.

    Elementwise given the final (m, l) — identical to the whole-row tail
    of :func:`softmax_int` (same log2, same subtraction, same exp2).
    """
    t = _to_log2_domain(x_blk.astype(I32) - m, IN_FRAC)
    log2s = _log2_int(jnp.maximum(l, 1), EXP_FRAC - guard_shift)
    return _exp2_int(jnp.minimum(t - log2s, 0))


def softmax_int_blocked(x_fx, block: int, guard_shift: int | None = None):
    """Whole-row normal mode evaluated as the three blocked sweeps.

    Pure-jnp driver over the last axis — the oracle that PROVES the
    telescoping: tests pin its output bit-identical to
    :func:`softmax_int` for any ``block`` (divisible or not).
    """
    n = x_fx.shape[-1]
    if guard_shift is None:
        guard_shift = max(0, n.bit_length() - 16)
    x_fx = x_fx.astype(I32)
    blocks = [x_fx[..., i:i + block] for i in range(0, n, block)]
    m = jnp.full(x_fx.shape[:-1] + (1,), PHANTOM_Q, I32)
    for b in blocks:
        m = online_max_int(m, b)
    l = jnp.zeros_like(m)
    for b in blocks:
        l = online_sum_int(l, m, b, guard_shift)
    return jnp.concatenate(
        [online_probs_int(m, l, b, guard_shift) for b in blocks], axis=-1)


# --- float wrappers (quantize -> int unit -> dequantize) --------------------
def softmax_dualmode(x, axis: int = -1):
    """float in/out softmax through the bit-accurate unit (normal mode)."""
    return dequantize(softmax_int(quantize(x), axis=axis), EXP_FRAC)


def gelu_dualmode(z):
    """float in/out GELU through the bit-accurate unit (GELU mode)."""
    return dequantize(gelu_int(quantize(z)), IN_FRAC)


def silu_dualmode(z):
    """float in/out SiLU through the bit-accurate unit (SiLU mode)."""
    return dequantize(silu_int(quantize(z)), IN_FRAC)
