"""The dual-mode softmax unit (paper §III, Fig. 2/3) — bit-accurate emulation.

Normal mode implements Eq. (10) — division in the logarithm domain:

    y_i = exp(x_i - max(x) - log(sum_j exp(x_j - max(x))))
        = 2**(t_i - lmax - log2(sum_j 2**(t_j - lmax)))     with t = x*log2(e)

Each exponential is decomposed 2**t = 2**u * 2**v (u integer -> shift,
v in [0,1) -> 8-piece PWL); the log uses a leading-one detector plus a
mantissa PWL (the forward log converter of [Kim 2006]).

GELU mode (Fig. 3) computes, per element z (Eq. 8):

    k       = sqrt(2/pi) * (z + 0.044715 z^3)
    GELU(z) = z * softmax_1^2([k, -k])

by running the *same* exp/log datapath independently on the two-element
vector [k, -k].  SiLU mode (ours, beyond-paper) is the exact identity
SiLU(z) = z * softmax_1^2([z/2, -z/2]) — only the k-datapath differs.

Everything here is int32 (inputs S5.10) and jnp-traceable, so the same code
is the Pallas kernel body's arithmetic and the oracle for its tests.

This module is the tree's single INT definition of the unit's arithmetic;
the float-lane form lives in ``repro.kernels.datapath`` (the only other
place the log2e / GELU-cubic constants appear).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .fixedpoint import (
    EXP_FRAC, I32, IN_FRAC, IN_MAX, IN_MIN, T_FRAC,
    dequantize, floor_log2, mantissa_frac, quantize, sat_rshift,
)
from .pwl import exp2_frac_int, log2_mant_int

# fixed-point constants (the ROM words of the datapath)
_LOG2E_FRAC = 12
LOG2E_Q = int(round(math.log2(math.e) * (1 << _LOG2E_FRAC)))        # 5909
GELU_A_Q = int(round(0.044715 * (1 << 16)))                         # cubic coeff
GELU_C_Q = int(round(math.sqrt(2.0 / math.pi) * (1 << 14)))         # sqrt(2/pi)

# Sentinel word for positions that must carry EXACTLY zero mass (the int
# analogue of the float paths' -inf on tiling-phantom keys).  Any word w
# with w - m <= -(32 << IN_FRAC) hits the input saturation of
# ``_to_log2_domain`` and its exponential underflows the 14-bit output to
# the literal 0 word, so it contributes nothing to the sum, the probs, or
# (being far below any S5.10 word) the running max.  -2**20 keeps that
# margin for every possible S5.10 max (>= IN_MIN) with int32 to spare.
PHANTOM_Q = -(1 << 20)


def _to_log2_domain(d, in_frac: int):
    """t = d * log2(e) at scale 2**-T_FRAC (d at scale 2**-in_frac, d<=0).

    d is saturated at -32 (exp(-32) ~ 2**-46 underflows the 14-bit output
    anyway) — this keeps the int32 product in range for any input pair,
    exactly like the input saturation stage of the hardware unit.
    """
    d = jnp.maximum(d.astype(I32), I32(-32) << in_frac)
    return (d * I32(LOG2E_Q)) >> (in_frac + _LOG2E_FRAC - T_FRAC)


def _exp2_int(t):
    """2**t for t <= 0 at scale 2**-T_FRAC -> result at scale 2**-EXP_FRAC.

    Split t = u + v, u = floor(t) (arithmetic shift), v in [0,1):
    2**u is a right shift of the PWL 2**v value.
    """
    u = t >> T_FRAC                                   # floor (t<=0 -> u<=0)
    v = t - (u << T_FRAC)                             # in [0, 2**T_FRAC)
    p = exp2_frac_int(v)                              # [1,2) @ 2**-EXP_FRAC
    return sat_rshift(p, -u)


def _log2_int(s, s_frac: int):
    """log2 of s (int > 0 at scale 2**-s_frac) at scale 2**-T_FRAC."""
    e_pos = floor_log2(s)
    frac = mantissa_frac(s, e_pos, T_FRAC)
    log2m = log2_mant_int(frac)
    return ((e_pos - s_frac) << T_FRAC) + log2m


def softmax_int(x_fx, axis: int = -1, guard_shift: int | None = None):
    """Normal mode: Eq. (10) over `axis`.  x_fx int32 @ S5.10.

    Returns probabilities at scale 2**-EXP_FRAC (int32).
    `guard_shift` down-shifts each exponent before the sum so that rows up
    to 2**(16+guard_shift) elements cannot overflow the int32 accumulator.
    """
    n = x_fx.shape[axis]
    if guard_shift is None:
        guard_shift = max(0, n.bit_length() - 16)
    m = jnp.max(x_fx, axis=axis, keepdims=True)
    t = _to_log2_domain(x_fx - m, IN_FRAC)            # <= 0
    e = _exp2_int(t)                                  # @ 2**-EXP_FRAC
    s = jnp.sum(e >> guard_shift, axis=axis, keepdims=True)
    s = jnp.maximum(s, 1)                             # log(0) guard
    log2s = _log2_int(s, EXP_FRAC - guard_shift)      # @ 2**-T_FRAC
    w = t - log2s                                     # log2 of prob, <= ~0
    return _exp2_int(jnp.minimum(w, 0))


def _pair_softmax_first_int(k_fx, k_frac: int):
    """softmax_1^2([k, -k]) through the shared exp/log datapath.

    k_fx int32 at scale 2**-k_frac.  Returns sigma(2k) @ 2**-EXP_FRAC.
    This is the GELU-mode inner loop: max = |k| (the pairwise max-tree tap),
    two exponents, the pair adder-tree tap, one pair log unit, one exp.
    """
    amax = jnp.abs(k_fx)
    t1 = _to_log2_domain(k_fx - amax, k_frac)
    t2 = _to_log2_domain(-k_fx - amax, k_frac)
    e1 = _exp2_int(t1)
    e2 = _exp2_int(t2)
    s = jnp.maximum(e1 + e2, 1)                       # in (2**14, 2**15]
    log2s = _log2_int(s, EXP_FRAC)
    w = jnp.minimum(t1 - log2s, 0)
    return _exp2_int(w)


def gelu_k_int(z_fx):
    """k = sqrt(2/pi) * (z + 0.044715 z^3) in S5.10 -> int32 @ 2**-IN_FRAC.

    The cubic-path input is saturated at |z| <= 8 (k(8) = 24.6 already
    drives sigma(2k) to exactly 0/1 in 14-bit arithmetic), which bounds
    every int32 intermediate — the hardware's input saturation stage.
    """
    z = jnp.clip(z_fx.astype(I32), I32(-8) << IN_FRAC, I32(8) << IN_FRAC)
    z2 = (z * z) >> IN_FRAC
    z3 = (z2 * z) >> IN_FRAC
    az3 = (z3 * I32(GELU_A_Q)) >> 16
    return ((z + az3) * I32(GELU_C_Q)) >> 14


def gelu_int(z_fx):
    """GELU mode (Eq. 8): z * softmax_1^2([k, -k]).  S5.10 -> S5.10."""
    k = gelu_k_int(z_fx)
    sig = _pair_softmax_first_int(k, IN_FRAC)          # @ 2**-EXP_FRAC
    return (z_fx.astype(I32) * sig) >> EXP_FRAC


def silu_int(z_fx):
    """Exact-identity SiLU mode: z * softmax_1^2([z/2, -z/2]).

    k = z/2 is represented losslessly by reinterpreting z at scale
    2**-(IN_FRAC+1) — zero extra datapath.
    """
    sig = _pair_softmax_first_int(z_fx.astype(I32), IN_FRAC + 1)
    return (z_fx.astype(I32) * sig) >> EXP_FRAC


# --- blocked / online evaluation of normal mode -----------------------------
#
# The float flash recurrence corrects old partial sums by exp(m_old - m_new)
# when the running max moves; that correction is NOT exact in the PWL int
# domain (the 8-piece exp2 is not multiplicative), so a one-sweep online
# rescale would change words.  What IS exact: the max fold and the
# guard-shifted sum fold are associative int32 reductions, and the emit
# step is elementwise given the final (m, l).  Streaming therefore runs
# three KV sweeps — max, sum, emit — each an online fold whose carry
# (m, then l) never leaves the int domain, and ANY blocking schedule
# telescopes to the exact whole-row :func:`softmax_int` words.  These
# three steps are jnp-traceable and shared verbatim by the three-sweep
# Pallas kernel body (``flash_pallas_int3``) and the pure-jnp blocked
# oracle below.  The SNAPPED-max mode further down removes the
# three-sweep restriction: snapping the max to a power of two makes the
# rescale an exact bit-shift, yielding a true one-sweep word monoid.

def online_max_int(m, x_blk, axis: int = -1):
    """Sweep 1 fold: running row max.  Init carry with ``PHANTOM_Q``."""
    return jnp.maximum(m, jnp.max(x_blk.astype(I32), axis=axis,
                                  keepdims=True))


def online_sum_int(l, m, x_blk, guard_shift: int, axis: int = -1):
    """Sweep 2 fold: guard-shifted int32 row-sum carry (init 0).

    ``m`` is the FINAL sweep-1 max; the guard shift bounds the carry for
    rows up to 2**(16+guard_shift) elements exactly as in the whole-row
    unit, so the blocked carry can never overflow int32 either.
    """
    t = _to_log2_domain(x_blk.astype(I32) - m, IN_FRAC)
    e = _exp2_int(t)
    return l + jnp.sum(e >> guard_shift, axis=axis, keepdims=True)


def online_probs_int(m, l, x_blk, guard_shift: int):
    """Sweep 3 emit: this block's probability words @ 2**-EXP_FRAC.

    Elementwise given the final (m, l) — identical to the whole-row tail
    of :func:`softmax_int` (same log2, same subtraction, same exp2).
    """
    t = _to_log2_domain(x_blk.astype(I32) - m, IN_FRAC)
    log2s = _log2_int(jnp.maximum(l, 1), EXP_FRAC - guard_shift)
    return _exp2_int(jnp.minimum(t - log2s, 0))


def softmax_int_blocked(x_fx, block: int, guard_shift: int | None = None):
    """Whole-row normal mode evaluated as the three blocked sweeps.

    Pure-jnp driver over the last axis — the oracle that PROVES the
    telescoping: tests pin its output bit-identical to
    :func:`softmax_int` for any ``block`` (divisible or not).
    """
    n = x_fx.shape[-1]
    if guard_shift is None:
        guard_shift = max(0, n.bit_length() - 16)
    x_fx = x_fx.astype(I32)
    blocks = [x_fx[..., i:i + block] for i in range(0, n, block)]
    m = jnp.full(x_fx.shape[:-1] + (1,), PHANTOM_Q, I32)
    for b in blocks:
        m = online_max_int(m, b)
    l = jnp.zeros_like(m)
    for b in blocks:
        l = online_sum_int(l, m, b, guard_shift)
    return jnp.concatenate(
        [online_probs_int(m, l, b, guard_shift) for b in blocks], axis=-1)


# --- snapped-max mode: the word-exact online-softmax monoid ----------------
#
# Snap the running max UP to a multiple of 2**T_FRAC and the PWL rescale
# becomes multiplicative by construction: t_j - M keeps t_j's low T_FRAC
# bits (M is a multiple of 2**T_FRAC), so the PWL fraction word
# p_j = exp2_frac(t_j mod 2**T_FRAC) is MAX-INDEPENDENT and the max only
# selects an integer DEPTH d_j = (M - t_j) >> T_FRAC.  A max move by k
# octaves relabels every depth by +k — a pure shift, exact on int words.
#
# A scalar normalizer carry is still NOT schedule-invariant (sum-then-
# shift != shift-then-sum: 1+1 = 2, >>1 -> 1, while per-element 0+0 = 0),
# so the carry keeps one int32 partial sum PER DEPTH — a carry-save /
# Kulisch-style state (m, S[0..N_SNAP_BUCKETS)) whose merge is slide-by-k
# plus elementwise add: a TRUE monoid, bit-exact associative AND
# commutative, with identity (SNAP_MIN, zeros).  Depths beyond the last
# bucket are the unit's dynamic-range floor (N_SNAP_BUCKETS octaves below
# the max): those words are defined to carry exactly zero mass, which is
# schedule-invariant because an element's final depth depends only on the
# final max.  The finish collapses l = sum_d (S_d >> d) — each element
# shifted exactly once, after all same-depth words were summed at full
# width.
#
# Normalization is ONE f32 division at the end (SOLE-style guaranteed
# normalization): numerators float(p_j) * 2**-d_j are EXACT in f32 (p_j
# is a 15-bit word, the scale an exact power of two), so a streaming
# accumulator rescaled by 2**-k is bit-identical to the whole-row
# numerator — only f32 summation order can differ between schedules.

SNAP_MIN = -(1 << 30)     # sentinel carry: a multiple of 2**T_FRAC far
                          # below any real score's log2-domain word
N_SNAP_BUCKETS = 16       # depth range = the unit's 16-octave dynamic range


def to_snap_domain(x_fx):
    """ABSOLUTE log2-domain score t = x*log2(e) @ 2**-T_FRAC (int32).

    Unlike :func:`_to_log2_domain` this does not subtract a max first —
    snapped mode needs max-independent words.  |t| <= ~3.03e6 for any
    S5.10 input, so the int32 product has headroom.  ``PHANTOM_Q``
    sentinel words map straight to ``SNAP_MIN`` (their true t would
    overflow int32, and they must carry exactly zero mass anyway).
    """
    x = x_fx.astype(I32)
    t = (jnp.clip(x, IN_MIN, IN_MAX) * I32(LOG2E_Q)) \
        >> (IN_FRAC + _LOG2E_FRAC - T_FRAC)
    return jnp.where(x <= I32(PHANTOM_Q), I32(SNAP_MIN), t)


def snap_max_int(t_max):
    """Ceil-snap a log2-domain word UP to a multiple of 2**T_FRAC.

    exp2 of the snapped max is then exactly a power of two, so every
    rescale-by-``exp2(m_old - m_new)`` is an arithmetic shift.  SNAP_MIN
    is itself a multiple of 2**T_FRAC, so the sentinel is a fixed point.
    """
    t_max = t_max.astype(I32)
    return ((t_max + I32((1 << T_FRAC) - 1)) >> T_FRAC) << T_FRAC


def snap_prob_word(t, guard_shift: int):
    """The max-independent (guard-shifted) probability word of ``t``.

    ``t`` is an absolute :func:`to_snap_domain` word; because the snapped
    max is a multiple of 2**T_FRAC, ``t - M`` keeps t's low T_FRAC bits,
    so the PWL evaluates on ``t mod 2**T_FRAC`` alone — a 15-bit word in
    [2**EXP_FRAC, 2**(EXP_FRAC+1)) >> guard, independent of any max.
    SNAP_MIN sentinels produce the literal 0 word.
    """
    p = exp2_frac_int(t & I32((1 << T_FRAC) - 1)) >> guard_shift
    return jnp.where(t > I32(SNAP_MIN), p, 0)


def snap_scale_f32(d):
    """EXACT float32 ``2**-d`` for int depth d >= 0.

    Built by exponent-field construction (not a transcendental), so every
    consumer — whole-row oracle, one-sweep kernel, decode split fold,
    ring hop merge — multiplies by bit-identical scales.  Depths past the
    f32 normal range collapse to exact +0.0 (those words are below the
    dynamic-range floor anyway).
    """
    e = jnp.clip(I32(127) - d.astype(I32), 0, 254)
    return jax.lax.bitcast_convert_type(e << 23, jnp.float32)


def slide_buckets_int(S, k):
    """Relabel a bucket vector to a max ``k`` octaves deeper (k >= 0).

    S'[d] = S[d - k] with zero-fill; words sliding past the last bucket
    are dropped — their elements sit >= N_SNAP_BUCKETS octaves below the
    new max, the exactly-zero floor.  Slides compose additively
    (slide(k1) o slide(k2) == slide(k1+k2)), which is what makes the
    merge associative on ALL states, not just reachable ones.
    """
    idx = jnp.arange(N_SNAP_BUCKETS, dtype=I32)
    src = idx - k
    take = jnp.take_along_axis(
        S, jnp.clip(src, 0, N_SNAP_BUCKETS - 1), axis=-1)
    return jnp.where(src >= 0, take, 0)


def online_partial_int(x_blk, guard_shift: int, v=None, axis: int = -1):
    """Self-contained snapped partial (m, S, acc) of one block of words.

    The int twin of :func:`repro.kernels.datapath.online_softmax_partial`:
    ``m`` is the block's own ceil-snapped max (keepdims at ``axis``),
    ``S`` the per-depth bucket sums (bucket axis appended LAST), ``acc``
    the f32 unnormalized weighted-value accumulator (or the exact f32
    numerators themselves when ``v`` is None).  All-phantom blocks
    produce the merge identity ``(SNAP_MIN, 0, 0)``.
    """
    t = to_snap_domain(x_blk)
    m = snap_max_int(jnp.max(t, axis=axis, keepdims=True))
    p = snap_prob_word(t, guard_shift)
    d = (m >> T_FRAC) - (t >> T_FRAC)
    S = jnp.stack([jnp.sum(jnp.where(d == kk, p, 0), axis=axis)
                   for kk in range(N_SNAP_BUCKETS)], axis=-1)
    num = p.astype(jnp.float32) * snap_scale_f32(d)
    acc = num if v is None else jnp.einsum("...n,...nd->...d", num, v)
    return m, S, acc


def online_merge_int(part_a, part_b):
    """Word-exact merge of two snapped partials — the int monoid fold.

    The int twin of :func:`repro.kernels.datapath.online_softmax_merge`:
    each part is ``(m, S, acc)`` with ``m`` (..., 1) int32 snapped,
    ``S`` (..., N_SNAP_BUCKETS) int32 bucket sums, ``acc`` (..., d) f32.

        m   = max(m_a, m_b)
        S   = slide(S_a, (m-m_a)/2**T_FRAC) + slide(S_b, ...)
        acc = acc_a * 2**-k_a + acc_b * 2**-k_b   (exact f32 scales)

    ``m`` and ``S`` are bit-exact associative AND commutative (the slide
    is an exact relabeling, bucket adds are int32); ``acc`` rescales are
    exact f32 multiplies, so only its ADD order varies with the schedule.
    Identity element: ``(SNAP_MIN, 0, 0)``.
    """
    m_a, S_a, acc_a = part_a
    m_b, S_b, acc_b = part_b
    m = jnp.maximum(m_a, m_b)
    k_a = (m - m_a) >> T_FRAC
    k_b = (m - m_b) >> T_FRAC
    S = slide_buckets_int(S_a, k_a) + slide_buckets_int(S_b, k_b)
    acc = acc_a * snap_scale_f32(k_a) + acc_b * snap_scale_f32(k_b)
    return m, S, acc


def online_merge_n_int(m, S, acc, axis: int = 0):
    """Vectorized n-way fold of snapped partials stacked along ``axis``.

    The int twin of :func:`repro.kernels.datapath.online_softmax_merge_n`
    (the split-KV decode fold): one max, one slide, one sum.  ``axis``
    stays as a singleton on m/acc (shape-stable for the caller); the
    bucket axis of ``S`` is last.  Sentinel ``(SNAP_MIN, 0, 0)`` partials
    contribute exact zeros — including empty splits is a no-op.
    """
    m_all = jnp.max(m, axis=axis, keepdims=True)
    k = (m_all - m) >> T_FRAC
    S = jnp.sum(slide_buckets_int(S, k), axis=axis, keepdims=True)
    acc = jnp.sum(acc * snap_scale_f32(k), axis=axis, keepdims=True)
    return m_all, S, acc


def online_finish_int(S):
    """Exact bucketed normalizer: l = sum_d (S_d >> d), clamped >= 1.

    Scale 2**-(EXP_FRAC - guard_shift).  Each element was summed into
    exactly one bucket at full width BEFORE its depth shift, so l is
    schedule-invariant (the shift distributes over nothing).  Reduces the
    trailing bucket axis away.
    """
    l = jnp.sum(S >> jnp.arange(N_SNAP_BUCKETS, dtype=I32), axis=-1)
    return jnp.maximum(l, 1)


def snap_row_stats(x_fx, axis: int = -1, guard_shift: int | None = None):
    """Whole-row snapped statistics (p, d, l) — the streaming oracle.

    p: max-independent guard-shifted probability words (0 for sentinels),
    d: per-element depth below the ceil-snapped row max,
    l: the exact bucketed normalizer (keepdims at ``axis``).

    The guard-shift rule matches :func:`softmax_int`: rows up to
    2**(16+guard_shift) elements cannot overflow a bucket (each element
    lands in exactly ONE bucket).
    """
    n = x_fx.shape[axis]
    if guard_shift is None:
        guard_shift = max(0, n.bit_length() - 16)
    m, S, _ = online_partial_int(x_fx, guard_shift, axis=axis)
    t = to_snap_domain(x_fx)
    p = snap_prob_word(t, guard_shift)
    d = (m >> T_FRAC) - (t >> T_FRAC)
    return p, d, jnp.expand_dims(online_finish_int(S), axis)


def softmax_snap(x_fx, axis: int = -1, guard_shift: int | None = None):
    """Snapped-max normal mode over ``axis``: x_fx S5.10 -> f32 probs.

    prob_j = float(p_j) * 2**-d_j / float(l) — exact f32 numerators, one
    deterministic IEEE division.  This is the whole-row reference every
    streaming schedule telescopes to: the one-sweep kernel, the decode
    split fold, and the ring hop fold all reproduce (p, d, l) word-exact
    and therefore these exact probabilities.
    """
    p, d, l = snap_row_stats(x_fx, axis=axis, guard_shift=guard_shift)
    return p.astype(jnp.float32) * snap_scale_f32(d) / l.astype(jnp.float32)


def softmax_snap_blocked(x_fx, block: int, guard_shift: int | None = None):
    """Whole-row snapped mode evaluated as a blocked monoid fold.

    Pure-jnp driver over the last axis — the oracle that PROVES the
    telescoping: partials of arbitrary blocks fold with
    :func:`online_merge_int` and the result is bit-identical in (m, S)
    — hence in l and the probability words — to :func:`softmax_snap`
    for any ``block`` (divisible or not).
    """
    n = x_fx.shape[-1]
    if guard_shift is None:
        guard_shift = max(0, n.bit_length() - 16)
    x_fx = x_fx.astype(I32)
    lead = x_fx.shape[:-1]
    zero_acc = jnp.zeros(lead + (1,), jnp.float32)   # prob-word-only fold
    part = (jnp.full(lead + (1,), SNAP_MIN, I32),
            jnp.zeros(lead + (N_SNAP_BUCKETS,), I32),
            zero_acc)
    for i in range(0, n, block):
        m_b, S_b, _ = online_partial_int(x_fx[..., i:i + block], guard_shift)
        part = online_merge_int(part, (m_b, S_b, zero_acc))
    m, S, _ = part
    t = to_snap_domain(x_fx)
    p = snap_prob_word(t, guard_shift)
    d = (m >> T_FRAC) - (t >> T_FRAC)
    l = jnp.expand_dims(online_finish_int(S), -1)
    return p.astype(jnp.float32) * snap_scale_f32(d) / l.astype(jnp.float32)


# --- normalization mode (SOLE-style reuse of the exp/log datapath) ----------
#
# RMSNorm/LayerNorm need one rsqrt per row; on this unit that is one more
# log-domain traversal — NO divider, NO square-rooter:
#
#     xhat_i = x_i / sqrt(ms) = sign(x_i) * 2**(log2|x_i| - log2(ms)/2)
#
# i.e. the row statistic enters as HALF its log (an arithmetic shift),
# and the per-element normalize is the same log2 -> subtract -> exp2
# pipeline Eq. (10) runs for softmax.  SOLE's "guaranteed normalization"
# maps onto the word lattice as: the mean-square word is clamped >= 1
# (so the log never sees zero), the output saturates at the S5.10 rails,
# and the mean divide is a reciprocal MULTIPLY by the static ROM word
# round(2**15 / n) — integer division never appears in the datapath
# (audited: analysis/int_purity forbids div/rsqrt/sqrt on int paths).
#
# Gain/bias stay OUT of the int unit — the float wrappers apply them in
# f32 after dequantize, mirroring the dense contract's single-downcast
# op order (models/layers.py).

def _exp2_signed_to_in(w):
    """2**w (w @ 2**-T_FRAC, ANY sign) -> word @ 2**-IN_FRAC, saturating.

    Unlike :func:`_exp2_int` (t <= 0 only) the normalization exponent
    w = log2|x| - log2(ms)/2 can be positive (elements above the RMS).
    Split w = u + v as usual; the 2**u shift runs against the 5-bit
    headroom of the target scale and saturates at the S5.10 rail IN_MAX
    — the unit's output saturation stage.
    """
    u = w >> T_FRAC
    v = w - (u << T_FRAC)
    p = exp2_frac_int(v)                       # [1,2) @ 2**-EXP_FRAC
    # rescale 2**-EXP_FRAC -> 2**-IN_FRAC is >> 4; pre-shift by 5 keeps
    # the left-shift cases (u > 4) inside int32, then saturate
    shift = (EXP_FRAC - IN_FRAC) - u
    return jnp.minimum(sat_rshift(p << 5, shift + 5), I32(IN_MAX))


def _log2_ms_int(x, n: int, guard_shift: int):
    """log2 of the row mean square of ``x`` (S5.10) @ 2**-T_FRAC.

    Sum of squares with a guard shift (x*x is at 2**-2*IN_FRAC and <=
    2**30 per element, so rows up to 2**(guard_shift+1) elements cannot
    overflow int32), then the mean is a log-domain SUBTRACTION of the
    static word round(log2(n) * 2**T_FRAC) — no divide.
    """
    xx = (x * x) >> guard_shift                # @ 2**-(2*IN_FRAC - guard)
    s2 = jnp.maximum(jnp.sum(xx, axis=-1, keepdims=True), 1)
    log2n_q = int(round(math.log2(n) * (1 << T_FRAC)))
    return _log2_int(s2, 2 * IN_FRAC - guard_shift) - I32(log2n_q)


def rmsnorm_int(x_fx, guard_shift: int | None = None):
    """Normalization mode: x / sqrt(mean(x^2)) over the last axis.

    x_fx int32 @ S5.10 -> int32 @ S5.10 (saturating).  Entirely on the
    unit's datapath: per-element log2, one row log2, shifts, one exp2.
    Zero words stay exactly zero.
    """
    n = x_fx.shape[-1]
    if guard_shift is None:
        guard_shift = max(0, n.bit_length() - 1)
    x = x_fx.astype(I32)
    t_ms = _log2_ms_int(x, n, guard_shift)
    a = jnp.abs(x)
    t_x = _log2_int(jnp.maximum(a, 1), IN_FRAC)
    w = t_x - (t_ms >> 1)                      # log2 |xhat|
    y = _exp2_signed_to_in(w)
    return jnp.where(a == 0, 0, jnp.sign(x) * y)


def layernorm_int(x_fx, guard_shift: int | None = None):
    """Normalization mode with centering: (x - mu) / sqrt(var(x)).

    The mean is the ONE place a true divide-by-n appears; on the unit it
    is a multiply by the static reciprocal ROM word round(2**15 / n)
    (exact to the output lattice for the n of every assigned arch), then
    the centered row reuses the rmsnorm datapath — var(x) IS the mean
    square of the centered words.
    """
    n = x_fx.shape[-1]
    x = x_fx.astype(I32)
    recip_q = int(round((1 << 15) / n))
    s1 = jnp.sum(x, axis=-1, keepdims=True)    # |s1| <= n * 2**15
    mu = (s1 * I32(recip_q)) >> 15             # @ 2**-IN_FRAC
    xc = jnp.clip(x - mu, IN_MIN, IN_MAX)
    return rmsnorm_int(xc, guard_shift=guard_shift)


def rmsnorm_dualmode(x, g, eps: float):
    """float in/out RMSNorm through the int unit; ``g`` applied in f32.

    ``eps`` is accepted for signature parity with the float home
    (``kernels/datapath.rmsnorm``) but plays no role on the word lattice
    — the unit's guaranteed normalization (the >=1 mean-square clamp and
    the S5.10 output rails) is what bounds the zero/overflow cases.
    """
    del eps
    y = dequantize(rmsnorm_int(quantize(x)), IN_FRAC)
    return y * g.astype(jnp.float32)


def layernorm_dualmode(x, g, b, eps: float):
    """float in/out LayerNorm through the int unit; g/b applied in f32."""
    del eps
    y = dequantize(layernorm_int(quantize(x)), IN_FRAC)
    return y * g.astype(jnp.float32) + b.astype(jnp.float32)


# --- float wrappers (quantize -> int unit -> dequantize) --------------------
def softmax_dualmode(x, axis: int = -1):
    """float in/out softmax through the bit-accurate unit (normal mode)."""
    return dequantize(softmax_int(quantize(x), axis=axis), EXP_FRAC)


def softmax_dualmode_snap(x, axis: int = -1):
    """float in/out softmax through the SNAPPED-max unit.

    The whole-row oracle of every streamed dual-mode path (one-sweep int
    flash, dual-mode decode, dual-mode ring): identical word pipeline,
    one f32 division.  Registered as softmax impl 'dualmode_snap' so the
    naive attention path serves as the snapped reference for free.
    """
    return softmax_snap(quantize(x), axis=axis)


def gelu_dualmode(z):
    """float in/out GELU through the bit-accurate unit (GELU mode)."""
    return dequantize(gelu_int(quantize(z)), IN_FRAC)


def silu_dualmode(z):
    """float in/out SiLU through the bit-accurate unit (SiLU mode)."""
    return dequantize(silu_int(quantize(z)), IN_FRAC)
