"""Fixed-point arithmetic helpers (paper §IV numerics).

The paper evaluates all designs with *16-bit fixed-point inputs with five
integer bits* and *32-bit integer arithmetic for all internal operations*
(same regime as i-GELU / I-BERT).  We emulate that bit-accurately with
int32 tensors:

  input format  S5.10  — 1 sign bit, 5 integer bits, 10 fraction bits,
                          scale 2**-10, representable range [-32, 32).
  internal      int32  — products are shifted back to a documented scale
                          at every step; no hidden floating point.

All functions are jnp-traceable and usable inside Pallas kernel bodies
(interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

import jax.numpy as jnp

# --- canonical formats ----------------------------------------------------
IN_FRAC = 10          # S5.10 input fraction bits (paper: 5 integer bits)
IN_BITS = 16
IN_MIN = -(1 << (IN_BITS - 1))          # -32768
IN_MAX = (1 << (IN_BITS - 1)) - 1       # +32767
EXP_FRAC = 14         # scale of PWL-exp2 outputs: 2**v in [1,2) -> [2**14, 2**15)
T_FRAC = 16           # scale of the log2-domain quantities (t = x*log2e, w)

I32 = jnp.int32


def quantize(x, frac_bits: int = IN_FRAC):
    """float -> saturating S(15-frac).frac int32 (16-bit range)."""
    q = jnp.round(x * (1 << frac_bits)).astype(I32)
    return jnp.clip(q, IN_MIN, IN_MAX)


def dequantize(q, frac_bits: int = IN_FRAC):
    return q.astype(jnp.float32) * (1.0 / (1 << frac_bits))


def fx_mul(a, b, shift: int):
    """int32 product, arithmetic-shifted right by `shift` (scale fixup)."""
    return (a.astype(I32) * b.astype(I32)) >> shift


def floor_log2(v):
    """Position of the leading one bit of v (v >= 1), i.e. floor(log2(v)).

    Bit-exact leading-one detector, the fixed-point analogue of the
    normalization step of the PWL forward log converter [Kim et al. 2006].
    """
    v = v.astype(I32)
    r = jnp.zeros_like(v)
    for shift in (16, 8, 4, 2, 1):
        cond = v >= (1 << shift)
        v = jnp.where(cond, v >> shift, v)
        r = r + jnp.where(cond, shift, 0)
    return r


def mantissa_frac(s, e_pos, frac_bits: int = T_FRAC):
    """Fractional part of the mantissa of s (int, MSB at e_pos).

    Returns (s / 2**e_pos - 1) at scale 2**-frac_bits, in [0, 2**frac_bits).
    Uses only shifts (variable shift amounts are element-wise in XLA).
    """
    s = s.astype(I32)
    rem = s - (I32(1) << e_pos)            # strip leading one
    up = jnp.maximum(frac_bits - e_pos, 0)
    down = jnp.maximum(e_pos - frac_bits, 0)
    return (rem << up) >> down


def sat_rshift(x, n):
    """Arithmetic right shift with shift amount clamped to [0, 31]."""
    return x >> jnp.clip(n, 0, 31)
