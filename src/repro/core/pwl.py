"""Eight-piece piecewise-linear (PWL) approximations (paper §III-A).

The paper's softmax datapath evaluates
  * 2**v for v in [0,1)      (the fractional part of each exponent), and
  * log2(m) for m in [1,2)   (the mantissa of the forward log converter
                              [Kim et al., JSSC 2006])
with 8-segment PWL approximations whose coefficients were derived with
`pwlf` on the target range.  We derive coefficients by per-segment least
squares on a dense grid (deterministic at import; error <= the continuous
pwlf fit used in the paper) and quantize them to fixed point.

Segment selection is the top-3 bits of the fraction — exactly the mux a
hardware PWL unit would use.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .fixedpoint import I32, T_FRAC, EXP_FRAC

N_SEG = 8
_COEF_FRAC = 14          # coefficient quantization (Q2.14)


def _fit_pwl(fn, lo: float, hi: float, n_seg: int = N_SEG, grid: int = 4096):
    """Per-segment least-squares linear fit of fn over [lo, hi)."""
    slopes, intercepts = [], []
    edges = np.linspace(lo, hi, n_seg + 1)
    for i in range(n_seg):
        x = np.linspace(edges[i], edges[i + 1], grid, endpoint=False)
        y = fn(x)
        a, b = np.polyfit(x, y, 1)
        slopes.append(a)
        intercepts.append(b)
    return np.asarray(slopes), np.asarray(intercepts)


# --- float coefficients (reference) ----------------------------------------
EXP2_SLOPE_F, EXP2_INTERCEPT_F = _fit_pwl(lambda v: np.exp2(v), 0.0, 1.0)
LOG2_SLOPE_F, LOG2_INTERCEPT_F = _fit_pwl(lambda f: np.log2(1.0 + f), 0.0, 1.0)

# --- quantized coefficients (the bits the hardware would store) -------------
EXP2_SLOPE_Q = np.round(EXP2_SLOPE_F * (1 << _COEF_FRAC)).astype(np.int32)
EXP2_INTERCEPT_Q = np.round(EXP2_INTERCEPT_F * (1 << _COEF_FRAC)).astype(np.int32)
LOG2_SLOPE_Q = np.round(LOG2_SLOPE_F * (1 << _COEF_FRAC)).astype(np.int32)
LOG2_INTERCEPT_Q = np.round(LOG2_INTERCEPT_F * (1 << _COEF_FRAC)).astype(np.int32)


def _mux8(seg, table):
    """8-way coefficient mux as a select chain (TPU/Pallas friendly —
    no gather; this is literally the hardware segment mux)."""
    out = jnp.full_like(seg, int(table[0]))
    for s in range(1, N_SEG):
        out = jnp.where(seg == s, I32(int(table[s])), out)
    return out


def _pwl_int(frac, slope_q, intercept_q, frac_bits: int, out_frac: int):
    """Evaluate a quantized 8-segment PWL at `frac` (scale 2**-frac_bits).

    Output scale 2**-out_frac.  Pure int32: one mux, one multiply,
    one shift, one add — the same op count as the hardware lane.
    """
    frac = frac.astype(I32)
    seg = (frac >> (frac_bits - 3)).astype(I32)          # top-3 bits
    a = _mux8(seg, slope_q)
    b = _mux8(seg, intercept_q)
    # a*frac: scale 2**-(COEF_FRAC+frac_bits) -> shift to out_frac
    prod = (a * frac) >> (_COEF_FRAC + frac_bits - out_frac)
    return prod + (b >> (_COEF_FRAC - out_frac) if _COEF_FRAC >= out_frac
                   else b << (out_frac - _COEF_FRAC))


def exp2_frac_int(v):
    """2**v for v in [0,1) at scale 2**-T_FRAC -> result scale 2**-EXP_FRAC."""
    return _pwl_int(v, EXP2_SLOPE_Q, EXP2_INTERCEPT_Q, T_FRAC, EXP_FRAC)


def log2_mant_int(f):
    """log2(1+f) for f in [0,1) at scale 2**-T_FRAC -> scale 2**-T_FRAC."""
    return _pwl_int(f, LOG2_SLOPE_Q, LOG2_INTERCEPT_Q, T_FRAC, T_FRAC)


# --- float PWL (algorithm-faithful float path, used by ref oracles) ---------
def exp2_frac_float(v):
    seg = jnp.clip((v * N_SEG).astype(jnp.int32), 0, N_SEG - 1)
    a = jnp.asarray(EXP2_SLOPE_F, dtype=v.dtype)[seg]
    b = jnp.asarray(EXP2_INTERCEPT_F, dtype=v.dtype)[seg]
    return a * v + b


def log2_mant_float(f):
    seg = jnp.clip((f * N_SEG).astype(jnp.int32), 0, N_SEG - 1)
    a = jnp.asarray(LOG2_SLOPE_F, dtype=f.dtype)[seg]
    b = jnp.asarray(LOG2_INTERCEPT_F, dtype=f.dtype)[seg]
    return a * f + b


def pwl_max_error():
    """(exp2_err, log2_err): max abs error of the float fits on their ranges."""
    v = np.linspace(0, 1, 1 << 16, endpoint=False)
    e1 = np.abs(np.exp2(v) - (EXP2_SLOPE_F[np.minimum((v * 8).astype(int), 7)] * v
                              + EXP2_INTERCEPT_F[np.minimum((v * 8).astype(int), 7)]))
    e2 = np.abs(np.log2(1 + v) - (LOG2_SLOPE_F[np.minimum((v * 8).astype(int), 7)] * v
                                  + LOG2_INTERCEPT_F[np.minimum((v * 8).astype(int), 7)]))
    return float(e1.max()), float(e2.max())
