"""Sharded checkpoint store: npz shards + JSON manifest, async save,
atomic publish, elastic restore.

Layout:
    <dir>/step_<N>/manifest.json       tree structure, shapes, dtypes,
                                       shard->file map, save metadata
    <dir>/step_<N>/shard_<host>.npz    this host's leaves (flat key -> array)
    <dir>/step_<N>.tmp/...             in-flight (renamed on completion)

Properties needed at fleet scale (DESIGN.md §5):
  * atomicity   — writers fill `step_N.tmp/` and `os.replace` it to
                  `step_N/` last; a crashed save can never be mistaken for
                  a complete checkpoint (restart-safe).
  * async       — `save(..., block=False)` hands the host-local arrays to a
                  daemon thread; training continues while bytes hit disk.
                  `wait()` joins before the next save (single-writer).
  * elastic     — the manifest is device-layout-free: leaves are stored
                  unsharded (gathered on save), so a restore may apply ANY
                  new mesh/sharding — rescale 256->512 chips = restore with
                  the new `param_pspecs`.  (True per-shard storage would add
                  a gather-free path; at this repo's scale gathered saves
                  keep restore universally reshardable.)
  * versioned   — monotone step dirs; `latest_step` picks the newest
                  complete one; `keep` bounds disk use.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
            for e in path)
        flat[key] = np.asarray(leaf)
    return flat


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := _STEP_RE.match(d))
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


class CheckpointStore:
    def __init__(self, directory: str, *, keep: int = 3,
                 host_id: int = 0, n_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------

    def save(self, step: int, tree: Any, *, block: bool = True,
             extra: dict | None = None) -> None:
        """Checkpoint `tree` at `step`.  Leaves are gathered to host memory
        synchronously (cheap vs the disk write); the write is async when
        block=False."""
        self.wait()
        flat = _flatten(jax.tree.map(
            lambda x: jax.device_get(x) if hasattr(x, "device") else x, tree))
        treedef = jax.tree.structure(tree)
        manifest = {
            "step": step,
            "n_hosts": self.n_hosts,
            "treedef": str(treedef),
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "extra": extra or {},
        }

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if block:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.dir)
            if (m := _STEP_RE.match(d)))
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------- restore ----------------

    def restore(self, like: Any, *, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int, dict]:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs).  `shardings` (optional pytree of NamedSharding)
        places each leaf — pass specs built on a NEW mesh to elastically
        reshard.  Returns (tree, step, extra)."""
        step = latest_step(self.dir) if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data: dict[str, np.ndarray] = {}
        for h in range(manifest["n_hosts"]):
            p = os.path.join(d, f"shard_{h}.npz")
            if os.path.exists(p):
                with np.load(p) as z:
                    data.update({k: z[k] for k in z.files})
        flat_like = _flatten(like)
        missing = set(flat_like) - set(data)
        if missing:
            raise KeyError(f"checkpoint at step {step} missing {sorted(missing)[:5]}...")
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        keys = ["/".join(
            str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
            for e in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
        arrays = [data[k] for k in keys]
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s, l: jax.device_put(
                    np.asarray(a).astype(l.dtype), s),
                tree, shardings, like)
        else:
            tree = jax.tree.map(
                lambda a, l: jax.numpy.asarray(a).astype(l.dtype), tree, like)
        return tree, step, manifest.get("extra", {})
