"""Cell builders: (arch x shape x mesh) -> the exact jit'd program +
ShapeDtypeStruct args + shardings that the dry-run lowers and the real
launchers execute.  One code path for both — the dry-run proves what
train.py/serve.py would run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import (ModelConfig, SHAPES, ShapeCell, TrainConfig)
from repro.distributed import batch_pspec, cache_pspecs, param_pspecs
from repro.models.accounting import pick_profile
from repro.models.transformer import encoder_apply, init_caches, init_lm
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.step import make_train_step, state_pspecs


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple                 # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate: tuple[int, ...]
    cfg: ModelConfig
    cell: ShapeCell

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate)

    def lower(self):
        return self.jit().lower(*self.args)

    def resident_bytes_per_chip(self) -> float:
        """Exact per-chip bytes of the program's RESIDENT state (params,
        optimizer, caches, batch) from the declared shardings — the
        hardware-true memory floor, independent of XLA-CPU buffer-
        assignment artifacts."""
        total = 0.0

        def add(leaf, sh):
            nonlocal total
            if not hasattr(leaf, "shape"):
                return
            shape = (sh.shard_shape(leaf.shape)
                     if hasattr(sh, "shard_shape") else leaf.shape)
            n = 1
            for d in shape:
                n *= d
            total += n * leaf.dtype.itemsize

        for arg, sh in zip(self.args, self.in_shardings):
            if isinstance(sh, NamedSharding):
                jax.tree.map(lambda l: add(l, sh), arg)
            else:
                jax.tree.map(add, arg, sh)
        return total


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def default_train_cfg() -> TrainConfig:
    """Dry-run / launcher training defaults: remat + SP on; FSDP off —
    under SP the weights are gathered per use anyway (ZeRO-3 pattern), so
    FSDP only added a second gather path (§Perf qwen3 iteration D: t_n
    8.58 -> 6.57 s).  TP + ZeRO-1-style opt sharding keeps residency
    under 16 GiB for every assigned arch."""
    return TrainConfig(remat=True, fsdp=False)


def build_cell(arch_id: str, shape_name: str, mesh: Mesh, *,
               dtype=jnp.bfloat16, tcfg: TrainConfig | None = None) -> Cell:
    cfg = registry.get_config(arch_id)
    cell = SHAPES[shape_name]
    ok, why = registry.cell_applicable(cfg, cell)
    if not ok:
        raise ValueError(f"{arch_id} x {shape_name}: {why}")
    b, s = cell.global_batch, cell.seq_len
    profile = pick_profile(cfg)
    dp = batch_pspec(mesh, b, include_model=(profile == "dp"))
    sds = jax.ShapeDtypeStruct

    if cell.kind == "train":
        tcfg = tcfg or default_train_cfg()
        state_sds, state_spec = state_pspecs(cfg, tcfg, mesh, dtype)
        batch_sds = registry.input_specs(cfg, cell, dtype)
        batch_spec = {k: P(*([dp[0]] + [None] * (len(v.shape) - 1)))
                      for k, v in batch_sds.items()}
        fn = make_train_step(cfg, tcfg, mesh)
        return Cell(arch_id, shape_name, "train", fn,
                    (state_sds, batch_sds),
                    (_named(mesh, state_spec), _named(mesh, batch_spec)),
                    (_named(mesh, state_spec), None), (0,), cfg, cell)

    # serving cells share params/caches construction; small models serve
    # with replicated weights ('dp' profile) — no per-layer TP collectives
    p_sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg, dtype))
    p_spec = param_pspecs(p_sds, mesh, fsdp=False, profile=profile)
    c_sds = jax.eval_shape(lambda: init_caches(cfg, b, s, dtype))
    c_spec = cache_pspecs(c_sds, mesh, b, ring_axis=cfg.ring_axis or None)
    # residual-stream pin for serving: batch over dp.  Under the 'dp'
    # profile the 'model' axis would otherwise sit idle and every rank
    # duplicates the compute (measured 16x flops bloat on whisper
    # prefill) — prefill puts it to work as sequence parallelism.
    sp_ax = None
    dp_has_model = isinstance(dp[0], tuple) and "model" in dp[0]
    if (cell.kind == "prefill" and profile == "dp" and not dp_has_model
            and "model" in mesh.axis_names
            and s % mesh.shape["model"] == 0):
        sp_ax = "model"
    act_pspec = (P(dp[0], sp_ax, None) if (dp[0] is not None or sp_ax)
                 else None)

    if cell.kind == "prefill":
        pf = make_prefill_step(cfg, act_pspec)
        toks = sds((b, s), jnp.int32)
        last = sds((b,), jnp.int32)
        args = [p_sds, c_sds, toks, last]
        specs = [p_spec, c_spec, P(dp[0], None), P(dp[0])]
        if cfg.family == "encdec":
            def fn(params, caches, tokens, last_idx, frames):
                enc = encoder_apply(params, cfg, frames)
                return pf(params, caches, tokens, last_idx, enc)
            args.append(sds((b, cfg.n_frames, cfg.d_model), dtype))
            specs.append(P(dp[0], None, None))
        elif cfg.family == "vlm":
            def fn(params, caches, tokens, last_idx, img):
                return pf(params, caches, tokens, last_idx, img)
            args.append(sds((b, cfg.n_img_tokens, cfg.d_model), dtype))
            specs.append(P(dp[0], None, None))
        else:
            def fn(params, caches, tokens, last_idx):
                return pf(params, caches, tokens, last_idx, None)
        return Cell(arch_id, shape_name, "prefill", fn, tuple(args),
                    tuple(_named(mesh, sp) for sp in specs),
                    (None, _named(mesh, c_spec)), (1,), cfg, cell)

    # decode: one new token against a seq_len-deep cache
    dc = make_decode_step(cfg, act_pspec)
    toks = sds((b, 1), jnp.int32)
    pos = sds((b,), jnp.int32)
    return Cell(arch_id, shape_name, "decode", dc,
                (p_sds, c_sds, toks, pos),
                (_named(mesh, p_spec), _named(mesh, c_spec),
                 _named(mesh, P(dp[0], None)), _named(mesh, P(dp[0]))),
                (None, _named(mesh, c_spec)), (1,), cfg, cell)


def applicable_cells(arch_id: str) -> list[str]:
    cfg = registry.get_config(arch_id)
    return [name for name, cell in SHAPES.items()
            if registry.cell_applicable(cfg, cell)[0]]


# count_params / analytic_model_flops moved to repro.models.accounting
# (re-exported above for benchmark/back-compat callers).
