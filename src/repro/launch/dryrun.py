import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count at first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

Usage:
    python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out benchmarks/artifacts/dryrun

Success criteria (assignment): .lower().compile() succeeds on the 16x16
single-pod mesh AND the 2x16x16 multi-pod mesh for every applicable cell;
memory_analysis proves residency, cost_analysis + HLO collective parse
feed EXPERIMENTS.md §Roofline.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import registry
from repro.launch import hlo_analysis as ha
from repro.launch.cells import applicable_cells, build_cell
from repro.launch.mesh import make_production_mesh
from repro.models.accounting import analytic_model_flops


def run_cell(arch: str, shape: str, multi_pod: bool, *, verbose: bool = True
             ) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "ok": False}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(arch, shape, mesh)
        rec["kind"] = cell.kind
        t0 = time.perf_counter()
        with mesh:
            lowered = cell.lower()
            rec["lower_s"] = round(time.perf_counter() - t0, 2)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.perf_counter() - t1, 2)
        rec["xla_cost_once"] = ha.cost_summary(compiled)   # cross-check only
        rec["memory"] = ha.memory_summary(compiled)
        hlo_text = compiled.as_text()
        # CPU-only bf16->f32 weight-upcast temps (absent on TPU)
        params_tree = (cell.args[0].params if cell.kind == "train"
                       else cell.args[0])
        pshapes = [l.shape for l in jax.tree.leaves(params_tree)]
        corr = ha.cpu_upcast_correction(hlo_text, pshapes)
        rec["memory"]["cpu_upcast_bytes"] = corr
        rec["memory"]["tpu_hbm_bytes"] = max(
            rec["memory"].get("total_hbm_bytes", 0.0) - corr, 0.0)
        # hardware-true resident state from the declared shardings; the
        # fit check adds a 2 GiB working-set allowance for activations
        resident = cell.resident_bytes_per_chip()
        rec["memory"]["resident_bytes_per_chip"] = resident
        rec["memory"]["fits_v5e_16g"] = resident + 2 * 2**30 < 16e9
        a = ha.analyze_hlo(hlo_text)                       # trip-count-aware
        rec.update(a)
        rec["roofline"] = ha.roofline_terms(
            a["flops"], a["bytes_accessed"], a["collective_wire_bytes"])
        n_dev = mesh.devices.size
        mf = analytic_model_flops(cell.cfg, cell.cell)
        rec["model_flops_global"] = mf
        rec["useful_ratio"] = (mf / (a["flops"] * n_dev)
                               if a["flops"] else 0.0)
        rec["ok"] = True
        if verbose:
            r = rec["roofline"]
            mem = rec["memory"].get("resident_bytes_per_chip", 0) / 2**30
            print(f"[dryrun] {arch:22s} {shape:12s} {mesh_name:10s} OK "
                  f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"resident={mem:.2f}GiB "
                  f"tc={r['t_compute']:.3e} tm={r['t_memory']:.3e} "
                  f"tn={r['t_collective']:.3e} -> {r['bottleneck']} "
                  f"useful={rec['useful_ratio']:.2f}",
                  flush=True)
    except Exception as e:  # noqa: BLE001 — sweep must survive cell bugs
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch:22s} {shape:12s} {mesh_name:10s} "
                  f"FAIL {rec['error']}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "XLA host-device override failed"
    archs = registry.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        shapes = ([args.shape] if args.shape else applicable_cells(arch))
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp)
                results.append(rec)
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
    n_ok = sum(r["ok"] for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} cells OK")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
