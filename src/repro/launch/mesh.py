"""Production meshes.  A FUNCTION, not a module constant — importing this
module must never touch jax device state (smoke tests see 1 device)."""
from __future__ import annotations

import jax


def auto_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis_types where this jax has the
    concept (jax >= 0.5); on older jax Auto is the only behavior, so the
    kwarg is simply omitted.  Every mesh in the repo goes through here."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: 'pod' = inter-pod data parallelism (DCN-ish links), 'data' =
    in-pod data parallelism / FSDP / sequence-parallel fallback, 'model' =
    tensor/expert parallelism (ICI-local).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return auto_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Dev mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return auto_mesh((n // model, model), ("data", "model"))
