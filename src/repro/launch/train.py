"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 200 --batch 8 --seq 128 --reduced --ckpt /tmp/ck

--reduced runs the smoke-scale config (CPU-feasible); full-scale runs use
the production mesh on real hardware (same code path the dry-run proves).
On a TPU fleet each host runs this same entrypoint; jax.distributed
initialization is attempted automatically when the standard TPU env vars
are present.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS
                    + ["bert-base"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-feasible)")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (registry.reduced_config(args.arch) if args.reduced
           else registry.get_config(args.arch))
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 1),
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt,
                       microbatch=args.microbatch, fsdp=args.fsdp,
                       grad_compress=args.grad_compress, remat=True,
                       seed=args.seed)
    trainer = Trainer(cfg, tcfg, global_batch=args.batch, seq_len=args.seq)
    print(f"[train] {cfg.name} reduced={args.reduced} "
          f"devices={len(jax.devices())} start={trainer.start_step}")
    metrics = trainer.run(args.steps)
    print(f"[train] done: {metrics}")
    trainer.save(trainer.start_step)


if __name__ == "__main__":
    main()
