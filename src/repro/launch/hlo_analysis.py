"""Trip-count-aware static analysis of compiled (post-SPMD) HLO.

Why not compiled.cost_analysis()?  Measured on this toolchain: XLA's cost
analysis counts every while-loop BODY ONCE — a scan over 24 periods
reports 1/24th of its real flops (verified: scan x10 of a matmul reports
exactly 1 matmul).  Our programs are scans-over-scans (periods x flash
blocks x CE chunks), so raw cost_analysis under-counts ~20-100x and, worse,
*differently per cell*, which would make every roofline comparison wrong.
The same bug hits a naive HLO-text grep for collectives: FSDP all-gathers
live inside the period loop body.

So we parse the HLO module into its computations and walk ENTRY
recursively:

  flops   : every `dot` contributes 2 * |out| * contracted_size
            (shapes resolved via a per-computation SSA symbol table)
  bytes   : HBM-traffic model at FUSION boundaries — a fusion (or bare
            non-free op) reads its operands and writes its result once;
            internal fusion ops are VMEM-resident and free
  colls   : result bytes of all-gather / all-reduce / reduce-scatter /
            all-to-all / collective-permute, ring-weighted:
            all-reduce 2x, others 1x  (n->inf limit of (n-1)/n factors)
  whiles  : body+cond costs multiplied by the trip count extracted from
            the condition's ROOT compare-vs-constant (all our loops are
            static scans); conditionals take the max branch

All sums are per-chip: the module analyzed is the per-device SPMD program.
"""
from __future__ import annotations

import re
from typing import Any

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
          "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}

_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

# ops that move no HBM bytes of their own.  NOTE: custom-call is NOT
# free — on TPU every pallas_call lowers to one, and its kernel streams
# all operands in and the result out of HBM exactly once (that is the
# whole point of a flash kernel).  It used to sit in this set, which
# silently zeroed the HBM bytes of precisely the kernels this module
# exists to price; cost() now charges it operands + result.
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id",
         "bitcast-convert", "opt-barrier"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*?)\s*"
                     r"([\w\-]+)\(")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTR_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _shape_elems_bytes(txt: str) -> tuple[int, int]:
    """Total (elements, bytes) over every dtype[dims] token in txt."""
    el, by = 0, 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        el += n
        by += n * _BYTES[dt]
    return el, by


def _first_shape_dims(txt: str):
    m = _SHAPE_RE.search(txt)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


class HloProgram:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        cur, buf = None, []
        for line in hlo_text.splitlines():
            if line.endswith("{") and ("->" in line):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = m.group(1)
                    if not cur.startswith("%"):
                        cur = "%" + cur
                    buf = []
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur
                    continue
            if line.strip() == "}":
                if cur is not None:
                    self.comps[cur] = buf
                cur = None
                continue
            if cur is not None:
                buf.append(line)
        self._memo: dict[str, dict[str, float]] = {}

    # ---------------- per-computation symbol table ----------------

    def _symbols(self, comp: str) -> dict[str, str]:
        table = {}
        for line in self.comps.get(comp, ()):
            m = _DEF_RE.match(line)
            if m:
                table[m.group(1)] = m.group(2)      # name -> result shape txt
        return table

    # ---------------- trip counts ----------------

    def trip_count(self, cond_comp: str) -> int:
        """Loop bound from the condition computation: the largest integer
        constant in it (and in computations it calls — the compare is often
        wrapped in a fusion).  All our loops are 0..N step-1 scans."""
        best = 1
        stack, seen = [cond_comp], set()
        while stack:
            comp = stack.pop()
            if comp in seen:
                continue
            seen.add(comp)
            for line in self.comps.get(comp, ()):
                for n in re.findall(r"constant\((\d+)\)", line):
                    best = max(best, int(n))
                c = _CALLS_RE.search(line)
                if c:
                    stack.append(c.group(1))
        return best

    # ---------------- recursive cost walk ----------------

    def cost(self, comp: str | None = None) -> dict[str, float]:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        tot = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0,
               "coll_wire": 0.0, "coll_count": 0.0}
        by_op: dict[str, float] = {}
        table = self._symbols(comp)
        self._memo[comp] = tot                     # cycle guard
        for line in self.comps.get(comp, ()):
            m = _DEF_RE.match(line)
            if not m:
                continue
            _, result_txt, op = m.groups()
            base = op.replace("-start", "").replace("-done", "")
            # ---- nested computations ----
            if op == "while":
                cond = _COND_RE.search(line)
                body = _CALLS_RE.search(line)
                trips = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    sub = self.cost(body.group(1))
                    for k in tot:
                        tot[k] += trips * sub[k]
                continue
            if op == "conditional":
                br = _BRANCH_RE.search(line)
                if br:
                    subs = [self.cost(b.strip()) for b in
                            br.group(1).split(",")]
                    for k in tot:
                        tot[k] += max(s[k] for s in subs)
                continue
            if op in ("fusion", "call", "map", "reduce", "reduce-window",
                      "sort", "scatter", "select-and-scatter"):
                c = _CALLS_RE.search(line)
                if c and c.group(1) in self.comps:
                    sub = self.cost(c.group(1))
                    tot["flops"] += sub["flops"]   # fused dots still compute
                    tot["coll_bytes"] += sub["coll_bytes"]
                    tot["coll_wire"] += sub["coll_wire"]
                    tot["coll_count"] += sub["coll_count"]
                tot["bytes"] += self._traffic(line, result_txt, table)
                continue
            # ---- leaf ops ----
            if base in _COLL_FACTOR:
                _, rb = _shape_elems_bytes(result_txt)
                if op.endswith("-done"):
                    continue                        # counted at -start
                tot["coll_bytes"] += rb
                tot["coll_wire"] += rb * _COLL_FACTOR[base]
                tot["coll_count"] += 1
                tot["bytes"] += 2 * rb              # HBM read+write around wire
                continue
            if op == "dot":
                out_dims = _first_shape_dims(result_txt) or []
                out_n = 1
                for d in out_dims:
                    out_n *= d
                lhs = self._operand_shapes(line, table)
                contr = _CONTR_RE.search(line)
                csize = 1
                if lhs and contr:
                    ldims = lhs[0]
                    for i in (int(x) for x in contr.group(1).split(",") if x):
                        if i < len(ldims):
                            csize *= ldims[i]
                tot["flops"] += 2.0 * out_n * csize
                tot["bytes"] += self._traffic(line, result_txt, table)
                continue
            if op == "convolution":
                # rough: 2 * out * (kernel elems) — none perf-critical here
                out_dims = _first_shape_dims(result_txt) or []
                out_n = 1
                for d in out_dims:
                    out_n *= d
                tot["flops"] += 2.0 * out_n
                continue
            if base == "custom-call":
                # a pallas_call kernel: reads every operand, writes the
                # result, once each (the -done half of an async pair is
                # the same transfer, already charged at -start)
                if op.endswith("-done"):
                    continue
                _, rb = _shape_elems_bytes(result_txt)
                tot["bytes"] += rb + float(
                    sum(self._operand_sizes(line, table)))
                continue
            if op in _FREE:
                continue
            tot["bytes"] += self._traffic(line, result_txt, table)
        self._memo[comp] = tot
        return tot

    def _traffic(self, line: str, result_txt: str,
                 table: dict[str, str]) -> float:
        """HBM traffic model for one (possibly fused) op, calibrated to TPU
        fusion behaviour (the CPU-lowered HLO we analyze fuses *less* than
        TPU would, so charging operand reads on every op overcounts ~10x):

        * dynamic-update-slice: the big buffer aliases in place — traffic
          is 2x the update slice (read + write), not the buffer.
        * dot / reduce: stream all operands + result exactly once.
        * copy: read + write.
        * gather / scatter / dynamic-slice: touch ~result-sized windows of
          their operands, not whole operands.
        * anything else (elementwise chains, converts, broadcasts, selects
          — whether CPU fused them or not): ONE result write.  Their reads
          are the producing ops' writes, already charged there; on TPU
          these chains fuse into neighbours and never re-read HBM.
        """
        _, rb = _shape_elems_bytes(result_txt)
        ops = self._operand_sizes(line, table)
        if "dynamic-update-slice" in line:
            return 2.0 * (min(ops) if ops else rb)
        if re.search(r"\s(dot|reduce|reduce-window)\(", line):
            return rb + float(sum(ops))
        if re.search(r"\scopy\(", line):
            return 2.0 * rb
        if re.search(r"\s(gather|scatter|dynamic-slice)\(", line):
            return rb + min(float(sum(ops)), 2.0 * rb)
        return float(rb)

    def _operand_shapes(self, line: str, table: dict[str, str]):
        call = line[line.index("("):]
        shapes = []
        for name in re.findall(r"(%[\w.\-]+)", call):
            if name in table:
                dims = _first_shape_dims(table[name])
                if dims is not None:
                    shapes.append(dims)
        return shapes

    def _operand_sizes(self, line: str, table: dict[str, str]) -> list[int]:
        call = line[line.index("("):]
        seen, sizes = set(), []
        for name in re.findall(r"(%[\w.\-]+)", call):
            if name in table and name not in seen:
                seen.add(name)
                _, b = _shape_elems_bytes(table[name])
                sizes.append(b)
        return sizes


def collective_result_bytes(hlo_text: str, op: str = "all-gather"
                            ) -> list[int]:
    """Result bytes of every ``op`` instruction in the module, across ALL
    computations (loop bodies included, unweighted — callers that need
    trip counts use :meth:`HloProgram.cost`).  Async pairs count once, at
    the -start half.  This is the shared walker behind the mesh-safety
    pass in ``repro.analysis``: a post-SPMD all-gather whose result is
    the full KV cache is the per-chip HBM blowup that pass hunts."""
    prog = HloProgram(hlo_text)
    sizes = []
    for lines in prog.comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            _, result_txt, found = m.groups()
            if found.endswith("-done"):
                continue
            if found.replace("-start", "") != op:
                continue
            _, rb = _shape_elems_bytes(result_txt)
            sizes.append(rb)
    return sizes


def analyze_hlo(hlo_text: str) -> dict[str, float]:
    prog = HloProgram(hlo_text)
    c = prog.cost()
    return {"flops": c["flops"], "bytes_accessed": c["bytes"],
            "collective_bytes": c["coll_bytes"],
            "collective_wire_bytes": c["coll_wire"],
            "collective_count": c["coll_count"]}


# ---------------- roofline ----------------

# hardware constants (TPU v5e per chip; assignment-given)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


def roofline_terms(flops: float, bytes_accessed: float, wire_bytes: float
                   ) -> dict[str, Any]:
    """Three per-chip time lower bounds, seconds."""
    t_c = flops / PEAK_FLOPS
    t_m = bytes_accessed / HBM_BW
    t_n = wire_bytes / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_n,
            "bottleneck": dom[0], "t_bound": dom[1]}


def cpu_upcast_correction(hlo_text: str, param_shapes) -> float:
    """Bytes of f32 temp copies of bf16 parameters — a CPU-backend
    artifact (XLA CPU upcasts bf16 dot operands to f32; the TPU MXU eats
    bf16 natively, so these buffers do not exist on the target).  Counted
    as: one f32 buffer per distinct parameter shape that appears as an
    f32 tensor in the HLO.  Shapes are matched on normalized dims
    (singletons dropped, sorted) so transposed / singleton-expanded
    weight copies are caught too."""
    def norm(dims):
        return tuple(sorted(d for d in dims if d != 1))

    want = {}
    for shp in param_shapes:
        if len(shp) == 0 or np_prod(shp) < (1 << 16):
            continue                        # small params: noise
        want[norm(shp)] = 4.0 * float(int(np_prod(shp)))
    seen = set()
    total = 0.0
    for m in re.finditer(r"f32\[([\d,]+)\]", hlo_text):
        key = norm(int(d) for d in m.group(1).split(","))
        if key in want and key not in seen:
            seen.add(key)
            total += want[key]
    return total


def np_prod(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def cost_summary(compiled) -> dict[str, float]:
    """Raw XLA cost_analysis (per-device, while-bodies-once) — kept for
    cross-checking the HLO walk, not for the roofline."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        ca = None
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    ca = ca or {}
    return {"xla_flops_once": float(ca.get("flops", 0.0)),
            "xla_bytes_once": float(ca.get("bytes accessed", 0.0))}


def memory_summary(compiled) -> dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0.0)
                                  + out.get("output_size_in_bytes", 0.0)
                                  + out.get("temp_size_in_bytes", 0.0)
                                  - out.get("alias_size_in_bytes", 0.0))
    return out
