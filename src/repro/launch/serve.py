"""Serving launcher: continuous-batching engine over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 16 --slots 4 --max-new 32 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import registry
from repro.models.transformer import init_lm
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ring-devices", type=int, default=0,
                    help="shard long-context prefill KV over a ring of "
                         "this many local devices (0 = off; off-TPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count accordingly)")
    ap.add_argument("--prefill-impl", default=None,
                    help="attention impl for the prefill program "
                         "(default: resolve cfg.attn_impl per phase)")
    ap.add_argument("--decode-impl", default=None,
                    help="attention impl for the decode program — e.g. "
                         "'flash_decode' to force the split-KV decode "
                         "kernel at any cache length, 'naive' to pin the "
                         "whole-row path (default: 'auto' resolution, "
                         "which picks flash_decode at long --max-seq)")
    ap.add_argument("--cache-mode", default="auto",
                    choices=("auto", "paged", "contiguous"),
                    help="KV cache layout: 'paged' = block-table pool "
                         "with prefix sharing + chunked prefill, "
                         "'contiguous' = per-slot rows with bucketed "
                         "prefill, 'auto' = paged wherever the arch "
                         "supports it")
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged KV block size in tokens (0 = the tiling "
                         "policy's pick for --max-seq)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged pool size in blocks incl. sentinel "
                         "(0 = match the contiguous HBM budget: "
                         "slots*max_blocks + 1)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged prefill chunk length in tokens "
                         "(0 = default 64)")
    ap.add_argument("--admission", default="reactive",
                    choices=("reactive", "worst_case"),
                    help="paged admission: 'reactive' reserves only the "
                         "prompt's block reach and grows per decode tick "
                         "(preempting under pool pressure), 'worst_case' "
                         "reserves prompt+max_new up front so admitted "
                         "requests never preempt")
    ap.add_argument("--preempt-policy", default="youngest",
                    choices=("youngest", "oldest"),
                    help="victim choice under pool pressure (always "
                         "lowest-priority first; this orders ties)")
    ap.add_argument("--preempt-mode", default="recompute",
                    choices=("recompute", "swap"),
                    help="'recompute' drops a victim's blocks and "
                         "re-prefills on resume; 'swap' copies them to "
                         "host memory and restores the exact bytes")
    ap.add_argument("--hol-window", type=int, default=4,
                    help="queue entries a pool-blocked head request can "
                         "be skipped past at admission (1 = strict FCFS)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline in seconds (0 = none); "
                         "expired requests retire with reason 'deadline'")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (registry.reduced_config(args.arch) if args.reduced
           else registry.get_config(args.arch))
    mesh = None
    if args.ring_devices:
        from repro.launch.mesh import auto_mesh
        cfg = cfg.replace(ring_axis="model")
        mesh = auto_mesh((args.ring_devices,), ("model",))
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    eng = ServeEngine(cfg, params, n_slots=args.slots,
                      max_seq=args.max_seq, mesh=mesh, seed=args.seed,
                      prefill_attn_impl=args.prefill_impl,
                      decode_attn_impl=args.decode_impl,
                      cache_mode=args.cache_mode,
                      block_size=args.block_size or None,
                      num_blocks=args.num_blocks or None,
                      prefill_chunk=args.prefill_chunk or None,
                      admission=args.admission,
                      preempt_policy=args.preempt_policy,
                      preempt_mode=args.preempt_mode,
                      hol_window=args.hol_window)
    mode = eng.cache_mode
    if mode == "paged":
        mode += (f" (block={eng.block_size} pool={eng.num_blocks} "
                 f"chunk={eng.prefill_chunk})")
    print(f"[serve] cache={mode} attention impls: "
          f"prefill={eng.prefill_attn_impl} decode={eng.decode_attn_impl}")
    rng = jax.random.PRNGKey(args.seed + 1)
    reqs = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 2, 16))
        prompt = [int(t) for t in
                  jax.random.randint(k, (plen,), 0, cfg.vocab - 1)]
        reqs.append(Request(rid=i, prompt=prompt, max_new=args.max_new,
                            temperature=args.temperature,
                            deadline_s=args.deadline_s or None))
    t0 = time.perf_counter()
    outs = eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in outs.values())
    print(f"[serve] {cfg.name}: {len(outs)} requests, {toks} tokens in "
          f"{dt:.2f}s ({toks/dt:.1f} tok/s) stats={eng.stats}")
    for rid in sorted(outs)[:4]:
        print(f"  rid={rid}: {outs[rid][:12]}...")


if __name__ == "__main__":
    main()
