"""Architecture configs — one module per assigned arch + the paper's BERT."""
from .base import (LayerSpec, MLACfg, MambaCfg, ModelConfig, MoECfg,  # noqa
                   SHAPES, ShapeCell, TrainConfig)
from .registry import ARCH_IDS, get_config, input_specs, reduced_config  # noqa
