"""rwkv6-1.6b [ssm] — Finch, data-dependent decay, attention-free.

24L d=2048 (32 heads of 64) d_ff=7168 vocab=65536 [arXiv:2404.05892].
The paper's GELU-via-softmax technique is N/A for the channel-mix
(squared-ReLU is not sigmoid-family — DESIGN.md §6); arch fully supported.
Attention-free -> O(1) state -> runs long_500k.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    pattern=(LayerSpec(mixer="rwkv", ffn="rwkv_cm"),),
    activation="relu2",
    use_rope=False,
    pos_emb="none",
    rwkv_lora_r=64,
    sub_quadratic=True,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, d_ff=128,
                         vocab=512, rwkv_lora_r=8)
