"""granite-moe-3b-a800m [moe] — per assignment spec line: MoE 40e top-8.

32L d=1536 24H (kv=8) d_ff(expert)=512 vocab=49155
[hf:ibm-granite family].  The assignment's note says 32 experts; the spec
line says 40e — we follow the spec line (DESIGN.md §Config fidelity).
"""
from .base import LayerSpec, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    # ep_pad=48: 40 experts don't divide the 16-wide EP axis; 8 zero-init
    # unroutable pad experts make the stacks (48,...) so expert
    # parallelism shards 3/chip instead of replicating (DESIGN.md §8)
    moe=MoECfg(n_experts=40, top_k=8, d_ff=512, ep_pad=48),
    activation="silu",
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=64, vocab=512,
                         moe=MoECfg(n_experts=4, top_k=2, d_ff=64))
