"""minicpm3-4b [dense] — MLA attention.  62L d=2560 40H (kv=40 spec; MLA
expands per-head) d_ff=6400 vocab=73448 [hf:openbmb/MiniCPM3-4B].

MLA dims from the HF config: q_lora=768, kv_lora=256, qk_nope=64,
qk_rope=32, v_head=64.
"""
from .base import LayerSpec, MLACfg, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab=73448,
    pattern=(LayerSpec(mixer="mla", ffn="mlp"),),
    mla=MLACfg(q_lora_rank=768, kv_lora_rank=256, nope_dim=64, rope_dim=32,
               v_dim=64),
    activation="silu",
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=512,
    mla=MLACfg(q_lora_rank=32, kv_lora_rank=16, nope_dim=16, rope_dim=8,
               v_dim=16))
