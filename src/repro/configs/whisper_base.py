"""whisper-base [audio] — enc-dec, conv frontend (stub).  6L d=512 8H
(kv=8) d_ff=2048 vocab=51865 [arXiv:2212.04356].

Backbone only: the conv frontend is a stub; `input_specs()` provides
precomputed frame embeddings (B, 1500, d).  Decoder = 6 layers of
self-attn + cross-attn + GELU MLP; GELU runs through the paper's
dual-mode unit when activation='gelu_dualmode'.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    pattern=(LayerSpec(mixer="attn", ffn="mlp", cross=True),),
    activation="gelu_tanh",
    gated_mlp=False,
    norm="layer",
    pos_emb="learned",
    enc_layers=6,
    n_frames=1500,
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab=512, enc_layers=2, n_frames=16)
