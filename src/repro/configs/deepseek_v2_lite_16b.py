"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared+routed MoE top-6.

27L d=2048 16H d_ff(expert)=1408 vocab=102400 [arXiv:2405.04434].
Assignment note says both "MoE 64e top-6" and "160 routed"; V2-Lite is
64 routed + 2 shared top-6 (160 routed is full V2) — we follow 64
(see DESIGN.md §Config fidelity).  First layer uses a dense MLP
(d_ff=10944), remaining 26 are MoE — expressed as prefix + period.
MLA: kv_lora=512, rope=64, nope=128, v=128, no q-lora.
"""
from .base import LayerSpec, MLACfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,                      # dense first-layer MLP
    vocab=102400,
    prefix=(LayerSpec(mixer="mla", ffn="mlp"),),
    pattern=(LayerSpec(mixer="mla", ffn="moe"),),
    mla=MLACfg(q_lora_rank=0, kv_lora_rank=512, nope_dim=128, rope_dim=64,
               v_dim=128),
    moe=MoECfg(n_experts=64, top_k=6, d_ff=1408, n_shared=2),
    activation="silu",
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=256,
    vocab=512,
    mla=MLACfg(q_lora_rank=0, kv_lora_rank=32, nope_dim=16, rope_dim=8,
               v_dim=16),
    moe=MoECfg(n_experts=8, top_k=2, d_ff=32, n_shared=1))
