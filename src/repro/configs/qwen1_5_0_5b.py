"""qwen1.5-0.5b [dense] — QKV bias, MHA-as-GQA (kv=16).  24L d=1024 16H
d_ff=2816 vocab=151936 [hf:Qwen/Qwen1.5-0.5B]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    activation="silu",
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab=512)
