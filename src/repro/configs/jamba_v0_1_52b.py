"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536 [arXiv:2403.19887].
Period-8 unit: attention at index 4, Mamba elsewhere; MoE on odd indices
(every other layer), dense MLP on even.  No positional encoding (the Mamba
layers carry position).  Sub-quadratic -> runs long_500k.
"""
from .base import LayerSpec, MambaCfg, ModelConfig, MoECfg


def _pattern():
    out = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "mlp"
        out.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(out)


CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    pattern=_pattern(),
    activation="silu",
    use_rope=False,
    moe=MoECfg(n_experts=16, top_k=2, d_ff=14336),
    mamba=MambaCfg(d_inner=8192, d_state=16, d_conv=4, dt_rank=256),
    sub_quadratic=True,
)

REDUCED = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    moe=MoECfg(n_experts=4, top_k=2, d_ff=128),
    mamba=MambaCfg(d_inner=128, d_state=8, d_conv=4, dt_rank=8),
)
