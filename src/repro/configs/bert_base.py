"""bert-base — the paper's own evaluation model (encoder-only, GELU FFN).

12L d=768 12H d_ff=3072 vocab=30522 [Devlin et al. 2019].  This is the
architecture of the paper's Table I experiments: GELU in the FFN runs
through the dual-mode softmax unit ('gelu_dualmode'), i-GELU, or FP32.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="bert-base",
    family="encoder",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=30522,
    pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
    activation="gelu_tanh",
    gated_mlp=False,
    norm="layer",
    pos_emb="learned",
    causal=False,
    max_seq=512,
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab=512)
