"""Arch registry: --arch <id> -> ModelConfig, reduced smoke config, and
ShapeDtypeStruct input specs for every shape cell (dry-run stand-ins,
no device allocation)."""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from .base import ModelConfig, SHAPES, ShapeCell

_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen3-14b": "qwen3_14b",
    "yi-6b": "yi_6b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "minicpm3-4b": "minicpm3_4b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "whisper-base": "whisper_base",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "bert-base": "bert_base",
}

ARCH_IDS = [k for k in _MODULES if k != "bert-base"]   # the 10 assigned


def _mod(arch_id: str):
    try:
        return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")


def get_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).CONFIG


def reduced_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).REDUCED


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic families;
    decode only for archs with a decoder."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch; long_500k needs sub-quadratic"
    if cell.kind == "decode" and cfg.family == "encoder":
        return False, "encoder-only arch has no decode step"
    return True, ""


def input_specs(cfg: ModelConfig, cell: ShapeCell | str,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train   : tokens+labels (B,S) [+ modality stubs]
    prefill : tokens (B,S) [+ modality stubs]
    decode  : tokens (B,1) — caches are built by the step fn factory
    """
    if isinstance(cell, str):
        cell = SHAPES[cell]
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if cell.kind == "train":
        specs = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
    elif cell.kind == "prefill":
        specs = {"tokens": sds((b, s), i32)}
    else:  # decode: one new token, cache length = seq_len
        specs = {"tokens": sds((b, 1), i32)}
    if cfg.family == "encdec" and cell.kind != "decode":
        specs["frames"] = sds((b, cfg.n_frames, cfg.d_model), dtype)
    if cfg.family == "vlm" and cell.kind != "decode":
        specs["image_embeds"] = sds((b, cfg.n_img_tokens, cfg.d_model), dtype)
    return specs
