"""llama-3.2-vision-11b [vlm] — cross-attn image layers.  40L d=4096 32H
(kv=8) d_ff=14336 vocab=128256 [hf:meta-llama/Llama-3.2-11B-Vision].

Backbone only per assignment: the vision tower is a stub; `input_specs()`
provides precomputed patch embeddings (B, 1601, d).  40 layers = 32
self-attention + 8 gated cross-attention layers (every 5th position,
offset 3 — matching the HF cross_attention_layers list modulo counting).
"""
from .base import LayerSpec, ModelConfig

_PERIOD = (
    LayerSpec(mixer="attn", ffn="mlp"),
    LayerSpec(mixer="attn", ffn="mlp"),
    LayerSpec(mixer="attn", ffn="mlp"),
    LayerSpec(mixer="none", ffn="mlp", cross=True),   # gated cross-attn layer
    LayerSpec(mixer="attn", ffn="mlp"),
)

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    pattern=_PERIOD,
    rope_theta=5e5,
    activation="silu",
    n_img_tokens=1601,
)

REDUCED = CONFIG.replace(n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab=512, n_img_tokens=8)
