"""Config schema: model architecture + shape cells + run settings.

Every assigned architecture is expressed as a `ModelConfig`; the repeating
layer structure is a `pattern` (one period) plus optional non-repeated
`prefix` layers, which is what lets heterogeneous stacks (Jamba's 1:7
mamba:attn interleave, the VLM's every-5th cross-attn layer, DeepSeek's
dense first layer) run under one scan-over-periods loop.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"       # attn | mla | mamba | rwkv | none
    ffn: str = "mlp"          # mlp | moe | rwkv_cm | none
    cross: bool = False       # cross-attention sublayer after the mixer


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int                 # per-expert hidden size
    n_shared: int = 0
    capacity_factor: float = 1.25
    # pad the expert STACKS (not the router) to a multiple of the EP axis
    # so expert parallelism divides the mesh; padded experts are zero-init
    # and unroutable (router has exactly n_experts outputs).  0 = no pad.
    ep_pad: int = 0


@dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int          # 0 = full-rank q
    kv_lora_rank: int
    nope_dim: int
    rope_dim: int
    v_dim: int


@dataclass(frozen=True)
class MambaCfg:
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | vlm | encdec | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    prefix: tuple[LayerSpec, ...] = ()
    activation: str = "silu"
    gated_mlp: bool = True
    qk_norm: bool = False
    qkv_bias: bool = False
    norm: str = "rms"         # rms | layer
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    use_rope: bool = True
    pos_emb: str = "rope"     # rope | learned | sinusoid
    max_seq: int = 1 << 20    # learned-pos table size cap / cache bound
    causal: bool = True
    tie_embeddings: bool = False
    mla: MLACfg | None = None
    moe: MoECfg | None = None
    mamba: MambaCfg | None = None
    rwkv_lora_r: int = 64
    softmax_impl: str = "float"     # float | dualmode  (paper's unit)
    # attention execution strategy (kernels/dispatch.py registry):
    #   auto             naive for short T, blocked online-softmax
    #                    otherwise (dualmode -> the int blocked kernel)
    #   naive            always materialize (S,T) scores; honors any
    #                    softmax_impl
    #   flash            pure-JAX blocked online softmax (models/flash.py)
    #   flash_pallas     Pallas blocked kernel (kernels/flash_attention.py)
    #   flash_pallas_int Pallas blocked BIT-ACCURATE unit
    #                    (kernels/flash_attention_int.py); requires
    #                    softmax_impl='dualmode'
    #   flash_ring       sequence-parallel ring flash attention
    #                    (kernels/ring_attention.py): KV shards rotate
    #                    over the `ring_axis` mesh axis via ppermute
    # resolution refuses float blocked impls + softmax_impl='dualmode'
    attn_impl: str = "auto"
    # mesh axis for sequence-parallel ring attention ("" = off).  When
    # set (usually "model"), attn_impl='auto' upgrades its blocked picks
    # to 'flash_ring' whenever the ambient mesh carries the axis and the
    # sequence dims divide it — long-context prefill shards the KV
    # sequence instead of replicating 32k-deep caches per chip.
    ring_axis: str = ""
    # gated-MLP execution: dense | fused_pallas (kernels/fused_ffn.py)
    # | auto (resolves to fused_pallas on TPU, dense elsewhere — explicit
    # strings are never rewritten; see kernels/dispatch.resolve_ffn)
    ffn_impl: str = "dense"
    # norm-seam execution: dense | fused_pallas (kernels/fused_norm.py:
    # residual-add+norm epilogues and norm->matmul prologues) | auto
    # (fused_pallas on TPU, dense elsewhere — dispatch.resolve_norm).
    # Fused seams match the dense contract to <=1e-5, not bitwise.
    norm_impl: str = "dense"
    moe_dispatch: str = "sort"      # sort | dense
    # modality stubs (assignment: frontend is a stub, backbone is real)
    enc_layers: int = 0       # whisper encoder depth
    n_frames: int = 1500      # whisper stub frame count
    n_img_tokens: int = 0     # VLM stub image-token count
    sub_quadratic: bool = False     # may run long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        body = self.n_layers - len(self.prefix)
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {body} layers not divisible by "
            f"period {len(self.pattern)}")
        return body // len(self.pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


# the assigned LM shape set (identical for all 10 archs)
SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    microbatch: int = 0           # 0 = no gradient accumulation
    remat: bool = True
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    grad_compress: bool = False   # int8 + error feedback
    fsdp: bool = False            # shard params/opt-state over 'data'
    seq_shard: bool = True        # SP: shard seq over 'model' at boundaries
    inner_pins: bool = False      # Megatron AG/RS pins inside blocks (§Perf)
    profile: str = "auto"         # auto | tp | dp   (sharding profile)
    remat_mode: str = "period"    # period | two_level (sqrt-L groups)
