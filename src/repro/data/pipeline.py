"""Deterministic, sharded, resumable synthetic-token data pipeline.

Design constraints (DESIGN.md §3):
  * deterministic   — batch(step) is a pure function of (seed, step), so
                      checkpoint-resume replays the exact token stream with
                      zero pipeline state to save (the step index IS the
                      state); elastic re-shards are trivially consistent.
  * sharded         — each host materializes only its slice of the global
                      batch (`host_slice`), indexed by process id.
  * learnable       — tokens follow a fixed random *bigram* LM (Zipf-ish
                      marginals), so cross-entropy training has a proper
                      floor (the bigram conditional entropy) and examples /
                      tests can assert real learning, not noise-fitting.

Batches are (tokens, labels) with labels = next token (shift-by-one inside
the same sampled sequence of length seq_len+1).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def host_slice(global_batch: int, n_hosts: int, host_id: int) -> slice:
    """Contiguous rows of the global batch owned by this host."""
    per = global_batch // n_hosts
    rem = global_batch % n_hosts
    lo = host_id * per + min(host_id, rem)
    return slice(lo, lo + per + (1 if host_id < rem else 0))


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2           # marginal skew
    n_hosts: int = 1
    host_id: int = 0

    def _table(self):
        """Fixed bigram transition logits (vocab, vocab), seed-deterministic."""
        rng = np.random.default_rng(self.seed)
        # sparse-ish transitions: each token prefers ~8 successors
        logits = rng.gumbel(size=(self.vocab, self.vocab)).astype(np.float32)
        top = np.partition(logits, -8, axis=-1)[:, -8:-7]
        logits = np.where(logits >= top, logits * 3.0, logits - 4.0)
        # Zipf marginal bias on successors
        bias = -self.zipf_a * np.log1p(np.arange(self.vocab, dtype=np.float32))
        return jnp.asarray(logits + bias[None, :])

    def __post_init__(self):
        object.__setattr__(self, "_tbl", self._table())

    @property
    def local_batch(self) -> int:
        sl = host_slice(self.global_batch, self.n_hosts, self.host_id)
        return sl.stop - sl.start

    def batch(self, step: int):
        """(tokens, labels), both (local_batch, seq_len) int32.  Pure in
        (seed, step, host_id) — the resume/replay guarantee."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
            self.host_id)
        b = self.local_batch
        k0, kseq = jax.random.split(key)
        first = jax.random.categorical(
            k0, jnp.zeros((b, self.vocab)), axis=-1)

        def gen(tok, k):
            nxt = jax.random.categorical(k, self._tbl[tok], axis=-1)
            return nxt, nxt

        keys = jax.random.split(kseq, self.seq_len)
        _, seq = jax.lax.scan(gen, first, keys)
        seq = jnp.moveaxis(seq, 0, 1)                 # (B, S)
        full = jnp.concatenate([first[:, None], seq], axis=1)  # (B, S+1)
        return full[:, :-1].astype(jnp.int32), full[:, 1:].astype(jnp.int32)

    def bigram_entropy(self) -> float:
        """Conditional entropy of the generating bigram LM (loss floor)."""
        p = jax.nn.softmax(self._tbl, axis=-1)
        marg = jnp.full((self.vocab,), 1.0 / self.vocab)  # approx stationary
        h = -jnp.sum(p * jnp.log(jnp.clip(p, 1e-30)), axis=-1)
        return float(jnp.sum(marg * h))
