from .pipeline import SyntheticLM, host_slice
