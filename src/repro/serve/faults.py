"""Deterministic fault injection for the serve engine:
``python -m repro.serve.faults --soak | --fixture NAME``.

Mirrors the ``analysis.audit --fixture`` pattern: every failure mode the
engine claims to contain has a seeded injector here, and CI proves the
containment machinery still fires by running each fixture and demanding
the documented exit code.

  exit 0   --soak: chaos soak invariants held
  exit 1   --fixture: the seeded fault was detected/contained as intended
  exit 2   --fixture: the fault ran but the engine did NOT contain it
           (the sentry/validator has gone blind)

The injector is pure host-side state consulted by engine hooks — no
monkeypatching, no randomness outside the seeded PRNG:

  alloc_shortfall(where, step)   force a pool shortfall at admission
                                 ('admit') or decode growth ('grow');
                                 scheduled hits are ONE-SHOT so the
                                 engine's preempt-retry loop can succeed
                                 and never livelocks on the injector
  decode_logits(step, rids, x)   poison one decoding row with NaN
  prefill_logits(step, rid, x)   poison a prefill-completion row
  corrupt_tables(step, t, slots) scribble an out-of-range block id into
                                 an occupied slot's table row

``affected`` collects the rids whose output the faults changed — the
soak's bitwise-unaffected invariant is checked against its complement.

The chaos soak runs the SAME seeded workload twice — fault-free, then
with every injector armed and the pool sized at ``pool_frac`` of the
worst-case block demand — and checks: no deadlock (nothing starved),
``pool.in_use() == 0`` after drain, every request terminated with a
reason code, and every unaffected request's tokens identical to the
fault-free run (preempted/resumed requests INCLUDED — preemption must
be invisible).
"""
from __future__ import annotations

import argparse
import random
import sys

FIXTURES = ("nan_logits", "pool_exhaustion", "preempt_storm",
            "table_corrupt", "oversize_prompt")


class FaultInjector:
    """Seeded, scheduled fault source consulted by engine hooks."""

    def __init__(self, seed: int = 0, *,
                 shortfall_admit_steps=(), shortfall_grow_steps=(),
                 storm_rate: float = 0.0, storm_until: int = 0,
                 nan_decode_step: int | None = None,
                 nan_prefill_step: int | None = None,
                 corrupt_step: int | None = None):
        self._rng = random.Random(seed)
        self._admit_steps = set(shortfall_admit_steps)
        self._grow_steps = set(shortfall_grow_steps)
        self.storm_rate = storm_rate
        self.storm_until = storm_until
        self._storm_fired: set[int] = set()
        self.nan_decode_step = nan_decode_step
        self.nan_prefill_step = nan_prefill_step
        self.corrupt_step = corrupt_step
        self.affected: set[int] = set()   # rids whose OUTPUT faults changed
        self.log: list[tuple] = []

    # ---- engine hooks ----

    def alloc_shortfall(self, where: str, step: int) -> bool:
        """Force ``pool.alloc``/``ensure_reach`` to report a shortfall.
        Scheduled steps fire once and are consumed — the engine retries
        after preempting a victim, and the retry must see the real pool.
        The storm mode fires at most once per engine step (seeded coin)
        until ``storm_until``: every hit forces one preemption, but a
        preemption does not change any request's final tokens, so storm
        targets are NOT marked affected."""
        sched = self._admit_steps if where == "admit" else self._grow_steps
        if step in sched:
            sched.discard(step)
            self.log.append(("shortfall", where, step))
            return True
        if (where == "grow" and step <= self.storm_until
                and step not in self._storm_fired
                and self._rng.random() < self.storm_rate):
            self._storm_fired.add(step)
            self.log.append(("storm", where, step))
            return True
        return False

    def decode_logits(self, step: int, rids: list[int], logits):
        """NaN-poison the first decoding row at (or after, if no row is
        decoding exactly then) ``nan_decode_step``.  One-shot."""
        if self.nan_decode_step is None or step < self.nan_decode_step:
            return logits
        rows = [i for i, r in enumerate(rids) if r >= 0]
        if not rows:
            return logits
        import jax.numpy as jnp
        self.nan_decode_step = None
        i = rows[0]
        self.affected.add(rids[i])
        self.log.append(("nan_decode", step, rids[i]))
        return logits.at[i].set(jnp.nan)

    def prefill_logits(self, step: int, rid: int, logits):
        if self.nan_prefill_step is None or step < self.nan_prefill_step:
            return logits
        import jax.numpy as jnp
        self.nan_prefill_step = None
        self.affected.add(rid)
        self.log.append(("nan_prefill", step, rid))
        return jnp.full_like(logits, jnp.nan)

    def corrupt_tables(self, step: int, tables, slots) -> None:
        """Scribble an impossible block id into the first occupied
        slot's table row (host array, pre-validation).  One-shot."""
        if self.corrupt_step is None or step < self.corrupt_step:
            return
        for i, s in enumerate(slots):
            if not s.free:
                self.corrupt_step = None
                tables[i, 0] = 2 ** 20
                self.affected.add(s.rid)
                self.log.append(("corrupt", step, s.rid))
                return


# ---------------------------------------------------------------------------
# workload + soak
# ---------------------------------------------------------------------------


def _workload(seed: int, n_requests: int, max_seq: int, vocab: int):
    """Seeded mixed workload: ragged lengths, a shared prefix family
    (exercises prefix-cache refcounts under preemption), varied
    max_new."""
    rng = random.Random(seed)
    from .engine import Request
    base = [rng.randrange(1, vocab) for _ in range(max_seq)]
    reqs = []
    for i in range(n_requests):
        if rng.random() < 0.35:         # prefix family
            plen = rng.randrange(10, min(34, max_seq - 12))
            prompt = base[:plen]
        else:
            plen = rng.randrange(4, min(40, max_seq - 12))
            prompt = [rng.randrange(1, vocab) for _ in range(plen)]
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new=rng.randrange(4, 11)))
    return reqs


def _mk_engine(cfg, params, *, seed, num_blocks, faults=None,
               n_slots=3, max_seq=64, preempt_mode="recompute"):
    from .engine import ServeEngine
    return ServeEngine(cfg, params, n_slots=n_slots, max_seq=max_seq,
                       cache_mode="paged", prefill_chunk=16, seed=seed,
                       num_blocks=num_blocks, admission="reactive",
                       preempt_mode=preempt_mode, faults=faults)


def _setup(seed: int, n_requests: int = 10, max_seq: int = 64,
           pool_frac: float = 0.5, n_slots: int = 3):
    import jax

    from repro.configs import registry
    from repro.kernels import tiling
    from repro.models.transformer import init_lm

    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    reqs = _workload(seed, n_requests, max_seq, cfg.vocab)
    bs = tiling.paged_block_size(max_seq)
    worst = max(tiling.cdiv(min(len(r.prompt) + r.max_new, max_seq), bs)
                for r in reqs)
    # pool_frac of the worst-case demand of a full slot complement,
    # floored so a single request always fits (the submit guard)
    num_blocks = max(worst, int(pool_frac * n_slots * worst)) + 1
    return cfg, params, reqs, num_blocks


def chaos_soak(seed: int = 0, *, pool_frac: float = 0.5,
               n_requests: int = 10, n_slots: int = 3, max_seq: int = 64,
               preempt_mode: str = "recompute",
               max_steps: int = 4000) -> dict:
    """Fault-free run, then the same workload with every injector armed.
    Returns a report dict with ``ok`` and the violated invariants."""
    cfg, params, reqs, num_blocks = _setup(
        seed, n_requests=n_requests, max_seq=max_seq,
        pool_frac=pool_frac, n_slots=n_slots)

    base = _mk_engine(cfg, params, seed=seed, num_blocks=num_blocks,
                      n_slots=n_slots, max_seq=max_seq,
                      preempt_mode=preempt_mode)
    base_out = base.run(list(reqs), max_steps=max_steps)

    inj = FaultInjector(seed, storm_rate=0.5, storm_until=25,
                        shortfall_admit_steps=(3, 7),
                        nan_decode_step=12, corrupt_step=20)
    eng = _mk_engine(cfg, params, seed=seed, num_blocks=num_blocks,
                     n_slots=n_slots, max_seq=max_seq,
                     preempt_mode=preempt_mode, faults=inj)
    from .engine import Request
    oversize_rejected = False
    try:
        eng.submit(Request(rid=10 ** 6,
                           prompt=list(range(1, max_seq + 2)), max_new=1))
    except ValueError:
        oversize_rejected = True
    out = eng.run(list(reqs), max_steps=max_steps)

    violations = []
    if not oversize_rejected:
        violations.append("oversized prompt was admitted")
    if eng.stats["starved"] or base.stats["starved"]:
        violations.append(f"deadlock/starvation: {eng.stats['starved']} "
                          f"(baseline {base.stats['starved']})")
    for e, tag in ((base, "baseline"), (eng, "armed")):
        if e.pool.in_use() != 0:
            violations.append(f"{tag}: {e.pool.in_use()} blocks leaked")
    for r in reqs:
        if r.rid not in out or r.rid not in eng.reasons:
            violations.append(f"rid {r.rid} never terminated with a reason")
    for r in reqs:
        if r.rid in inj.affected:
            continue
        if out.get(r.rid) != base_out.get(r.rid):
            violations.append(
                f"rid {r.rid} unaffected by faults but tokens diverged: "
                f"{out.get(r.rid)} != {base_out.get(r.rid)}")
    return {"ok": not violations, "violations": violations,
            "stats": {k: v for k, v in eng.stats.items()
                      if k != "admit_time_s"},
            "affected": sorted(inj.affected),
            "reasons": dict(eng.reasons),
            "injections": len(inj.log)}


# ---------------------------------------------------------------------------
# fixtures: each proves one containment path still fires
# ---------------------------------------------------------------------------


def _fixture_nan_logits(seed: int):
    """NaN decode logits at step k must quarantine exactly one slot
    (reason 'numeric') while its neighbours' tokens stay bitwise equal
    to the fault-free run."""
    cfg, params, reqs, _ = _setup(seed, n_requests=4)
    base_out = _mk_engine(cfg, params, seed=seed,
                          num_blocks=None).run(list(reqs))
    inj = FaultInjector(seed, nan_decode_step=6)
    eng = _mk_engine(cfg, params, seed=seed, num_blocks=None, faults=inj)
    out = eng.run(list(reqs))
    quarantined = [r for r, why in eng.reasons.items() if why == "numeric"]
    ok = (len(quarantined) == 1 and quarantined[0] in inj.affected
          and eng.pool.in_use() == 0
          and all(out[r.rid] == base_out[r.rid] for r in reqs
                  if r.rid not in inj.affected))
    return ok, {"quarantined": quarantined, "affected": sorted(inj.affected),
                "numeric": eng.stats["numeric"]}


def _fixture_pool_exhaustion(seed: int):
    """A pool that only fits one worst-case request at a time must block
    admission (backpressure, counted) yet drain every request with a
    reason and zero leaked blocks."""
    cfg, params, reqs, _ = _setup(seed, n_requests=6)
    from repro.kernels import tiling
    bs = tiling.paged_block_size(64)
    worst = max(tiling.cdiv(min(len(r.prompt) + r.max_new, 64), bs)
                for r in reqs)
    eng = _mk_engine(cfg, params, seed=seed, num_blocks=worst + 1)
    out = eng.run(list(reqs))
    ok = (eng.stats["admit_blocked"] > 0 and eng.pool.in_use() == 0
          and all(r.rid in out and r.rid in eng.reasons for r in reqs)
          and not eng.stats["starved"])
    return ok, {"admit_blocked": eng.stats["admit_blocked"],
                "reasons": dict(eng.reasons)}


def _fixture_preempt_storm(seed: int):
    """Every decode growth forced short for the first 15 steps: the
    engine must preempt and resume repeatedly, and the storm must be
    INVISIBLE in the tokens (greedy recompute is exact)."""
    cfg, params, reqs, _ = _setup(seed, n_requests=5)
    base_out = _mk_engine(cfg, params, seed=seed, num_blocks=None
                          ).run(list(reqs))
    inj = FaultInjector(seed, storm_rate=1.0, storm_until=15)
    eng = _mk_engine(cfg, params, seed=seed, num_blocks=None, faults=inj)
    out = eng.run(list(reqs))
    ok = (eng.stats["preemptions"] > 0 and eng.stats["resumes"] > 0
          and eng.pool.in_use() == 0 and out == base_out)
    return ok, {"preemptions": eng.stats["preemptions"],
                "resumes": eng.stats["resumes"],
                "match": out == base_out}


def _fixture_table_corrupt(seed: int):
    """An out-of-range block id scribbled into a live table row must be
    caught by the per-step validator before any kernel consumes it."""
    cfg, params, reqs, _ = _setup(seed, n_requests=4)
    inj = FaultInjector(seed, corrupt_step=8)
    eng = _mk_engine(cfg, params, seed=seed, num_blocks=None, faults=inj)
    out = eng.run(list(reqs))
    corrupted = [r for r, why in eng.reasons.items() if why == "corrupt"]
    ok = (len(corrupted) == 1 and corrupted[0] in inj.affected
          and eng.stats["corrupt"] == 1 and eng.pool.in_use() == 0
          and all(r.rid in out for r in reqs))
    return ok, {"corrupted": corrupted, "affected": sorted(inj.affected)}


def _fixture_oversize_prompt(seed: int):
    """A prompt past max_seq (and one past the pool's worst-case reach)
    must be rejected at submit, leaving the engine state untouched."""
    cfg, params, reqs, num_blocks = _setup(seed, n_requests=2)
    from .engine import Request
    eng = _mk_engine(cfg, params, seed=seed, num_blocks=num_blocks)
    rejected = 0
    try:                               # past max_seq
        eng.submit(Request(rid=100, prompt=list(range(1, 66)), max_new=1))
    except ValueError:
        rejected += 1
    # within max_seq but past a small pool's worst-case reach
    small = _mk_engine(cfg, params, seed=seed, num_blocks=3)
    try:
        small.submit(Request(rid=101, prompt=list(range(1, 11)),
                             max_new=30))
    except ValueError:
        rejected += 1
    out = eng.run(list(reqs))
    ok = (rejected == 2 and 100 not in out and 101 not in out
          and all(r.rid in out for r in reqs)
          and eng.pool.in_use() == 0)
    return ok, {"rejected": rejected}


_FIXTURE_RUNNERS = {
    "nan_logits": _fixture_nan_logits,
    "pool_exhaustion": _fixture_pool_exhaustion,
    "preempt_storm": _fixture_preempt_storm,
    "table_corrupt": _fixture_table_corrupt,
    "oversize_prompt": _fixture_oversize_prompt,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.faults",
        description="deterministic fault injection for the serve engine "
                    "(chaos soak + seeded containment fixtures)")
    ap.add_argument("--soak", action="store_true",
                    help="run the chaos soak; exit 0 iff invariants held")
    ap.add_argument("--fixture", choices=FIXTURES,
                    help="run one seeded fault; exit 1 iff contained as "
                         "documented, 2 if the engine has gone blind")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pool-frac", type=float, default=0.5)
    ap.add_argument("--preempt-mode", default="recompute",
                    choices=("recompute", "swap"))
    args = ap.parse_args(argv)
    if not args.soak and not args.fixture:
        ap.error("pick --soak or --fixture NAME")

    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    if args.soak:
        report = chaos_soak(args.seed, pool_frac=args.pool_frac,
                            preempt_mode=args.preempt_mode)
        print(f"chaos soak: {'OK' if report['ok'] else 'FAIL'} — "
              f"{report['injections']} injections, "
              f"affected rids {report['affected']}, "
              f"stats {report['stats']}")
        for v in report["violations"]:
            print(f"  VIOLATION: {v}", file=sys.stderr)
        return 0 if report["ok"] else 1

    ok, detail = _FIXTURE_RUNNERS[args.fixture](args.seed)
    if ok:
        print(f"fixture {args.fixture!r} contained as intended: {detail}")
        return 1
    print(f"fixture {args.fixture!r} NOT contained — the engine has "
          f"gone blind: {detail}", file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
