from .engine import (Request, ServeEngine, make_chunk_prefill_step,
                     make_decode_step, make_paged_decode_step,
                     make_prefill_step)
from .paged_cache import BlockPool, chain_hashes
