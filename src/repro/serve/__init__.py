from .engine import (Request, ServeEngine, make_decode_step,
                     make_prefill_step)
