from .engine import (Request, ServeEngine, make_chunk_prefill_step,
                     make_decode_step, make_paged_decode_step,
                     make_prefill_step)
from .paged_cache import BlockPool, chain_hashes

# NOTE: the fault-injection harness lives in `repro.serve.faults`
# (FaultInjector, chaos_soak) and is imported explicitly — keeping it
# out of the package namespace lets `python -m repro.serve.faults` run
# without the runpy double-import warning.
