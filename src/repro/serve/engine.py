"""Batched serving engine: continuous batching over a slotted KV cache.

Two compiled programs serve every request mix (vLLM-style separation):

  prefill(params, row_caches, tokens(1,L))        one request's prompt ->
      its caches at batch=1 (bucketed prompt lengths bound compile count)
  decode(params, caches, tokens(B,1), pos(B,))    ONE token for EVERY slot
      in lockstep; per-slot depths via vector `pos` (per-row cache writes
      + per-row causal masks in models/attention.py)

The engine then does classic continuous batching on the host: admit a
queued request whenever a slot frees, splice its prefilled caches into the
batched cache tree at the slot index, sample, retire on EOS/max_tokens.
`make_prefill_step`/`make_decode_step` are also what the multi-pod dry-run
lowers for the decode/prefill shape cells.

Attention impls are selected PER PHASE through the kernel dispatch
registry: prefill runs wide q tiles (the blocked/flash paths pay off);
decode runs s_q=1 rows against the full cache bucket — at long `max_seq`
the 'auto' rule resolves the split-KV flash-decode kernel
(``kernels/flash_decode.py``), which parallelizes over the KEYS and,
because the batched decode step feeds it the per-slot cache depths (the
vector ``pos`` becomes the ragged ``kv_valid`` mask and each row's
``q_pos``), skips cache tiles beyond each slot's own depth — lockstep
continuous batching stops paying for the longest slot's full bucket on
every row.  Short caches stay on whole-row 'naive' (which also keeps the
dual-mode unit exact).  Each phase's impl is resolved once at engine
construction at the phase's representative shape, so the two compiled
programs pin their own kernels instead of both trailing the model
default.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import dispatch
from repro.models.transformer import encoder_apply, init_caches, lm_apply

Params = Any


# ---------------- compiled steps ----------------

def make_prefill_step(cfg: ModelConfig, act_pspec=None):
    """(params, caches, tokens(B,S), last_idx(B,)[, cross_src]) ->
    (logits(B,V) at each row's last REAL prompt position, caches).

    Logits are computed only at `last_idx` — prompts shorter than the
    padded bucket sample from the right position, and the (B,S,vocab)
    prefill logits tensor never exists.  `act_pspec` pins the residual
    stream on a production mesh (batch over dp; MoE dispatch pins)."""
    def prefill(params, caches, tokens, last_idx, cross_src=None):
        logits, caches, _ = lm_apply(params, cfg, tokens, pos=0,
                                     caches=caches, cross_src=cross_src,
                                     last_pos=last_idx, act_pspec=act_pspec)
        return logits[:, -1, :], caches
    return prefill


def make_decode_step(cfg: ModelConfig, act_pspec=None):
    """(params, caches, tokens(B,1), pos(B,)) -> (logits(B,V), caches).

    `pos` is the current depth of every slot (vector => slots advance
    independently).  Inside the model the vector becomes each row's
    ragged `kv_valid` mask and `q_pos` — which is exactly what the
    split-KV flash-decode kernel keys its per-row tile skip on, so a
    shallow slot does not pay for the deepest slot's cache sweep.
    Cross-attention KV (VLM/enc-dec) is read from the cache written at
    prefill time.
    """
    def decode(params, caches, tokens, pos):
        logits, caches, _ = lm_apply(params, cfg, tokens, pos=pos,
                                     caches=caches, act_pspec=act_pspec)
        return logits[:, -1, :], caches
    return decode


def _splice_slot(full_tree, row_tree, slot: int):
    """Write batch=1 cache `row_tree` into slot index `slot` of the batched
    cache.  The batch axis is 1 for stacked-period leaves ('periods' in the
    path carries a leading n_periods dim), else 0."""
    def write(path, full, one):
        names = [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]
        axis = 1 if "periods" in names else 0
        start = [0] * full.ndim
        start[axis] = slot
        return jax.lax.dynamic_update_slice(full, one.astype(full.dtype),
                                            tuple(start))
    return jax.tree_util.tree_map_with_path(write, full_tree, row_tree)


def sample_token(key, logits, temperature: float):
    greedy = jnp.argmax(logits, axis=-1)
    if temperature <= 0.0:
        return greedy
    return jax.random.categorical(key, logits / temperature, axis=-1)


# ---------------- engine ----------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    cross_src: Any = None            # stub frontend embeddings (VLM/encdec)


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    pos: int = 0
    remaining: int = 0
    out: list = dataclasses.field(default_factory=list)
    temperature: float = 0.0

    @property
    def free(self) -> bool:
        return self.rid < 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Params, *,
                 n_slots: int = 4, max_seq: int = 512,
                 eos_id: int | None = None, dtype=jnp.float32,
                 prefill_buckets: tuple[int, ...] = (32, 128, 512),
                 prefill_attn_impl: str | None = None,
                 decode_attn_impl: str | None = None,
                 mesh=None, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_seq = n_slots, max_seq
        self.eos_id = eos_id
        self.dtype = dtype
        # optional device mesh: per-phase resolution AND the compiled
        # programs trace under `with mesh:`, so a cfg with ring_axis set
        # resolves long-context prefill to the sequence-parallel ring
        # path (decode stays s_q=1 -> naive) and the flash_ring provider
        # finds the same mesh ambient at trace time
        self.mesh = mesh
        self.buckets = tuple(b for b in sorted(prefill_buckets)
                             if b <= max_seq) or (max_seq,)
        # state-carrying mixers (mamba/rwkv) integrate every input token —
        # right-padding a bucket would corrupt their state, so those archs
        # prefill at exact prompt length (one compile per distinct length)
        self._exact_prefill = any(
            s.mixer in ("mamba", "rwkv")
            for s in tuple(cfg.pattern) + tuple(cfg.prefix))
        self.caches = init_caches(cfg, n_slots, max_seq, dtype)
        # per-phase attention impls, resolved once through the dispatch
        # registry at each phase's representative shape (prefill: widest
        # q tile vs the full cache; decode: one q row vs the full cache —
        # long max_seq resolves 'auto' to the split-KV flash_decode
        # kernel, short caches to whole-row naive).  None defers to
        # cfg.attn_impl, so a config that pins a concrete impl keeps it
        # for both phases; resolution is softmax-aware, so a dualmode
        # config routes to the bit-accurate paths instead of silently
        # running the float ones (dualmode decode stays naive: the unit
        # is whole-row exact at s_q=1).
        prefill_sq = max_seq if self._exact_prefill else self.buckets[-1]
        with self._mesh_ctx():
            # the compiled prefill runs at EVERY bucket, so the ring is
            # only offered to 'auto' when each bucket (and the cache
            # depth) divides the ring width — resolving on the widest
            # bucket alone would bake flash_ring into a program that a
            # smaller bucket then crashes.  Exact-length prefill
            # (mamba/rwkv hybrids) sees arbitrary prompt lengths and
            # never rings; decode is s_q=1 and can't either.
            n = dispatch.ring_axis_size(cfg.ring_axis)
            ring_ok = (not self._exact_prefill and n > 1
                       and max_seq % n == 0
                       and all(b % n == 0 for b in self.buckets))
            self.prefill_attn_impl = dispatch.resolve_attention(
                prefill_attn_impl or cfg.attn_impl, prefill_sq, max_seq,
                softmax_impl=cfg.softmax_impl,
                ring_axis=cfg.ring_axis if ring_ok else "")
            self.decode_attn_impl = dispatch.resolve_attention(
                decode_attn_impl or cfg.attn_impl, 1, max_seq,
                softmax_impl=cfg.softmax_impl)
        self._prefill = jax.jit(make_prefill_step(
            cfg.replace(attn_impl=self.prefill_attn_impl)))
        self._decode = jax.jit(make_decode_step(
            cfg.replace(attn_impl=self.decode_attn_impl)))
        self._slots = [_Slot() for _ in range(n_slots)]
        self._queue: list[Request] = []
        self._key = jax.random.PRNGKey(seed)
        self.finished: dict[int, list[int]] = {}
        self._last_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.stats = {"prefills": 0, "decode_steps": 0, "admitted": 0}

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else (
            contextlib.nullcontext())

    # ---- host-side bookkeeping ----

    def submit(self, req: Request) -> None:
        # validate at submission so an over-long prompt fails fast instead
        # of being popped mid-run (both prefill flavors: the bucketed path
        # AND the exact-length mamba/rwkv path, which used to skip every
        # length check and silently overrun the cache)
        self._bucket(len(req.prompt))
        self._queue.append(req)

    def _bucket(self, n: int) -> int:
        if n > self.max_seq:
            raise ValueError(f"prompt length {n} exceeds max_seq "
                             f"{self.max_seq}")
        if self._exact_prefill:
            return n
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds largest bucket "
                         f"{self.buckets[-1]}")

    def _admit(self) -> None:
        for i, slot in enumerate(self._slots):
            # max_new=0 requests finish with an EMPTY completion — never
            # consume a slot, a prefill, or emit the prefill-sampled token
            # (which used to be appended unconditionally)
            while self._queue and self._queue[0].max_new <= 0:
                done = self._queue.pop(0)
                self.finished[done.rid] = []
                self.stats["admitted"] += 1
            if not self._queue:
                return
            if not slot.free:
                continue
            req = self._queue.pop(0)
            L = self._bucket(len(req.prompt))
            toks = jnp.asarray(req.prompt + [0] * (L - len(req.prompt)),
                               jnp.int32)[None, :]
            row = init_caches(self.cfg, 1, self.max_seq, self.dtype)
            cross = None
            if req.cross_src is not None:
                cross = (encoder_apply(self.params, self.cfg, req.cross_src)
                         if self.cfg.family == "encdec" else req.cross_src)
            last_idx = jnp.asarray([len(req.prompt) - 1], jnp.int32)
            with self._mesh_ctx():
                logits, row = self._prefill(self.params, row, toks,
                                            last_idx, cross)
            # splice the prefilled row caches into the batch at slot i —
            # stacked-period leaves are (n_periods, B, ...): batch axis 1
            self.caches = _splice_slot(self.caches, row, i)
            self._slots[i] = _Slot(rid=req.rid, pos=len(req.prompt),
                                   remaining=req.max_new, out=[],
                                   temperature=req.temperature)
            self._key, k = jax.random.split(self._key)
            first = sample_token(k, logits[0], req.temperature)
            self._slots[i].out.append(int(first))
            self._slots[i].remaining -= 1
            self._last_tok = self._last_tok.at[i, 0].set(first)
            self.stats["prefills"] += 1
            self.stats["admitted"] += 1
            self._retire(i)

    def _retire(self, i: int) -> None:
        s = self._slots[i]
        if s.free:
            return
        done = (s.remaining <= 0 or s.pos >= self.max_seq - 1 or
                (self.eos_id is not None and s.out and
                 s.out[-1] == self.eos_id))
        if done:
            self.finished[s.rid] = s.out
            self._slots[i] = _Slot()

    @property
    def active(self) -> int:
        return sum(not s.free for s in self._slots)

    def pending(self) -> int:
        return len(self._queue) + self.active

    # ---- one engine step = admit + one lockstep decode ----

    def step(self) -> None:
        self._admit()
        if self.active == 0:
            return
        pos = jnp.asarray([s.pos for s in self._slots], jnp.int32)
        with self._mesh_ctx():
            logits, self.caches = self._decode(self.params, self.caches,
                                               self._last_tok, pos)
        self.stats["decode_steps"] += 1
        self._key, k = jax.random.split(self._key)
        keys = jax.random.split(k, self.n_slots)
        for i, s in enumerate(self._slots):
            if s.free:
                continue
            tok = int(sample_token(keys[i], logits[i], s.temperature))
            s.out.append(tok)
            s.pos += 1
            s.remaining -= 1
            self._last_tok = self._last_tok.at[i, 0].set(tok)
            self._retire(i)

    def run(self, requests: list[Request], max_steps: int = 10_000
            ) -> dict[int, list[int]]:
        for r in requests:
            self.submit(r)
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        return dict(self.finished)
