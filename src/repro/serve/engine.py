"""Batched serving engine: continuous batching over a paged (or slotted
contiguous) KV cache.

Two cache modes, same host scheduler skeleton:

``paged`` (the default wherever the architecture supports it) — the
vLLM-style layout: per-layer (N, block_size, ...) block pools shared by
every request, one (B, max_blocks) int32 block table, and a host-side
:class:`repro.serve.paged_cache.BlockPool` doing admission/retire as
pure block alloc/free.  Three properties fall out:

  * zero-copy admission: a request is admitted by writing integers into
    its table row — no cache-tree splice, no row copy (``_splice_slot``
    only survives on the contiguous path, and ``stats['cache_copies']``
    counts it);
  * prefix-cache sharing: full prompt blocks are chain-hashed and
    ref-counted, so a request whose prompt extends an already-prefilled
    prefix starts decoding from the shared blocks without recomputing
    (or re-storing) them;
  * chunked prefill: prompts are consumed ``prefill_chunk`` tokens per
    engine step, interleaved with the decode tick, so a long prompt
    never stalls decode traffic.  Compiled-program count stays bounded:
    ONE chunk shape (1, C) + ONE decode shape (B, 1).

``contiguous`` — the seed layout: per-slot (n_slots, max_seq, ...) rows,
bucketed whole-prompt prefill at batch 1, caches spliced per admission.
State-carrying mixers (mamba/rwkv), cross-attention caches and encoders
have nothing to page and stay here; ``cache_mode='auto'`` picks per
architecture.

Serving under pressure (paged mode): ``admission='reactive'`` (the
default) reserves only a request's PROMPT reach at admission and grows
its block table one block at a time from inside the decode loop
(``BlockPool.ensure_reach``) — the table must always cover the next
write position, because out-of-table scatters clamp to the sentinel
block and silently lose data.  On growth shortfall the engine preempts
a victim (``preempt_policy``: lowest priority first, youngest admission
by default) by either dropping its blocks for recompute-on-resume (the
prompt + generated prefix re-enters the queue HEAD as one prefill) or
swapping the block contents to a host-side store (``preempt_mode``).
Backpressure is bounded by ``hol_window`` skip-ahead admission, wall
clocks by per-request ``deadline_s``, and a per-step isfinite sentry
quarantines a slot whose logits go non-finite without touching its
neighbours.  Every request leaves the engine with a reason code in
``engine.reasons``.  All of it is fault-injectable — see
``repro.serve.faults``.

Attention impls are selected PER PHASE through the kernel dispatch
registry exactly as before; on the paged path the resolved decode impl
additionally picks up its block-table native variant from
``dispatch.get_paged_attention`` (flash_decode's scalar-prefetch gather)
inside the model, while impls without one read through a dense gather.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import dispatch, tiling
from repro.models.transformer import (encoder_apply, init_caches,
                                      init_paged_caches, lm_apply,
                                      paged_supported)
from .paged_cache import BlockPool, chain_hashes

Params = Any


# ---------------- compiled steps ----------------

def make_prefill_step(cfg: ModelConfig, act_pspec=None):
    """(params, caches, tokens(B,S), last_idx(B,)[, cross_src]) ->
    (logits(B,V) at each row's last REAL prompt position, caches).

    Logits are computed only at `last_idx` — prompts shorter than the
    padded bucket sample from the right position, and the (B,S,vocab)
    prefill logits tensor never exists.  `act_pspec` pins the residual
    stream on a production mesh (batch over dp; MoE dispatch pins)."""
    def prefill(params, caches, tokens, last_idx, cross_src=None):
        logits, caches, _ = lm_apply(params, cfg, tokens, pos=0,
                                     caches=caches, cross_src=cross_src,
                                     last_pos=last_idx, act_pspec=act_pspec)
        return logits[:, -1, :], caches
    return prefill


def make_decode_step(cfg: ModelConfig, act_pspec=None):
    """(params, caches, tokens(B,1), pos(B,)) -> (logits(B,V), caches).

    `pos` is the current depth of every slot (vector => slots advance
    independently).  Inside the model the vector becomes each row's
    ragged `kv_valid` mask and `q_pos` — which is exactly what the
    split-KV flash-decode kernel keys its per-row tile skip on, so a
    shallow slot does not pay for the deepest slot's cache sweep.
    Cross-attention KV (VLM/enc-dec) is read from the cache written at
    prefill time.
    """
    def decode(params, caches, tokens, pos):
        logits, caches, _ = lm_apply(params, cfg, tokens, pos=pos,
                                     caches=caches, act_pspec=act_pspec)
        return logits[:, -1, :], caches
    return decode


def make_chunk_prefill_step(cfg: ModelConfig, act_pspec=None):
    """(params, caches, tokens(1,C), pos, tables(1,max_blocks),
    last_idx(1,)) -> (logits(1,V), caches) — ONE prompt chunk written
    through the slot's block table at traced offset ``pos``.

    One compiled shape serves every chunk of every prompt: position is a
    traced scalar, the table a traced operand.  ``last_idx`` picks the
    logits row (the chunk's last REAL token) — only the final chunk's
    logits are consumed, the others are (1, V) throwaways."""
    def prefill_chunk(params, caches, tokens, pos, tables, last_idx):
        logits, caches, _ = lm_apply(params, cfg, tokens, pos=pos,
                                     caches=caches, last_pos=last_idx,
                                     act_pspec=act_pspec, paged=tables)
        return logits[:, -1, :], caches
    return prefill_chunk


def make_paged_decode_step(cfg: ModelConfig, act_pspec=None):
    """(params, caches, tokens(B,1), pos(B,), tables(B,max_blocks)) ->
    (logits(B,V), caches) — the lockstep decode tick reading/writing
    K/V through per-slot block tables.  Rows that must not write (free
    slots, slots mid-prefill) are handed all-sentinel table rows, so
    their scatter lands in block 0 and touches nothing live."""
    def decode(params, caches, tokens, pos, tables):
        logits, caches, _ = lm_apply(params, cfg, tokens, pos=pos,
                                     caches=caches, act_pspec=act_pspec,
                                     paged=tables)
        return logits[:, -1, :], caches
    return decode


def _splice_slot(full_tree, row_tree, slot: int):
    """Write batch=1 cache `row_tree` into slot index `slot` of the batched
    cache (CONTIGUOUS mode only — the paged path admits by table writes
    and never copies cache trees).  The batch axis is 1 for
    stacked-period leaves ('periods' in the path carries a leading
    n_periods dim), else 0."""
    def write(path, full, one):
        names = [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]
        axis = 1 if "periods" in names else 0
        start = [0] * full.ndim
        start[axis] = slot
        return jax.lax.dynamic_update_slice(full, one.astype(full.dtype),
                                            tuple(start))
    return jax.tree_util.tree_map_with_path(write, full_tree, row_tree)


def sample_token(key, logits, temperature: float):
    greedy = jnp.argmax(logits, axis=-1)
    if temperature <= 0.0:
        return greedy
    return jax.random.categorical(key, logits / temperature, axis=-1)


# ---------------- engine ----------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    cross_src: Any = None            # stub frontend embeddings (VLM/encdec)
    deadline_s: float | None = None  # wall-clock budget from submission
    priority: int = 0                # higher = preempted later


@dataclasses.dataclass
class _QEntry:
    """Internal queue record: a fresh submission or a preempted request
    waiting to resume.  Recompute resumes carry ``resume_prompt`` (the
    original prompt + every token generated so far — one prefill redoes
    the dropped KV); swap resumes carry the saved block contents and
    re-enter decode directly at ``pos``."""
    req: Request
    deadline_at: float | None = None
    prior_out: list = dataclasses.field(default_factory=list)
    resume_prompt: list | None = None
    swap: Any = None                 # {'saved': host tree, 'n': #blocks}
    pos: int = 0                     # swap resume: decode depth
    out: list = dataclasses.field(default_factory=list)  # swap resume

    @property
    def is_resume(self) -> bool:
        return self.resume_prompt is not None or self.swap is not None


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    pos: int = 0
    remaining: int = 0
    out: list = dataclasses.field(default_factory=list)
    temperature: float = 0.0
    # paged-mode fields: while `prompt` is set the slot is mid-prefill
    # (`filled` tokens written so far); `blocks` are the table entries
    # this slot holds references on (shared prefix + private).
    prompt: list | None = None
    filled: int = 0
    blocks: list = dataclasses.field(default_factory=list)
    seq: int = 0                     # admission order (FCFS prefill)
    # pressure fields: the ORIGINAL prompt and the tokens generated in
    # earlier incarnations (before a preemption) — `finished[rid]` is
    # always prior_out + out, so resumes are invisible to the caller
    full_prompt: list = dataclasses.field(default_factory=list)
    prior_out: list = dataclasses.field(default_factory=list)
    priority: int = 0
    deadline_at: float | None = None

    @property
    def free(self) -> bool:
        return self.rid < 0

    @property
    def decoding(self) -> bool:
        return self.rid >= 0 and self.prompt is None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Params, *,
                 n_slots: int = 4, max_seq: int = 512,
                 eos_id: int | None = None, dtype=jnp.float32,
                 prefill_buckets: tuple[int, ...] = (32, 128, 512),
                 prefill_attn_impl: str | None = None,
                 decode_attn_impl: str | None = None,
                 prefill_softmax_impl: str | None = None,
                 decode_softmax_impl: str | None = None,
                 mesh=None, seed: int = 0,
                 cache_mode: str = "auto",
                 block_size: int | None = None,
                 num_blocks: int | None = None,
                 prefill_chunk: int | None = None,
                 admission: str = "reactive",
                 preempt_policy: str = "youngest",
                 preempt_mode: str = "recompute",
                 hol_window: int = 4,
                 faults=None, clock=None):
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_seq = n_slots, max_seq
        self.eos_id = eos_id
        self.dtype = dtype
        # optional device mesh: per-phase resolution AND the compiled
        # programs trace under `with mesh:`, so a cfg with ring_axis set
        # resolves long-context prefill to the sequence-parallel ring
        # path (decode stays s_q=1 -> naive/flash_decode) and the
        # flash_ring provider finds the same mesh ambient at trace time
        self.mesh = mesh
        if cache_mode not in ("auto", "paged", "contiguous"):
            raise ValueError(f"unknown cache_mode {cache_mode!r}")
        if admission not in ("reactive", "worst_case"):
            raise ValueError(f"unknown admission {admission!r}")
        if preempt_policy not in ("youngest", "oldest"):
            raise ValueError(f"unknown preempt_policy {preempt_policy!r}")
        if preempt_mode not in ("recompute", "swap"):
            raise ValueError(f"unknown preempt_mode {preempt_mode!r}")
        if cache_mode == "paged":
            if not paged_supported(cfg):
                raise ValueError(
                    "cache_mode='paged' requires attention-only cached "
                    "layers (no mamba/rwkv state, cross-attention, or "
                    "encoder) — use 'auto' or 'contiguous'")
            if mesh is not None:
                raise ValueError(
                    "cache_mode='paged' does not compose with a device "
                    "mesh yet (pools are unsharded) — ROADMAP item 4")
        self.cache_mode = ("paged" if cache_mode == "paged" or
                           (cache_mode == "auto" and paged_supported(cfg)
                            and mesh is None)
                           else "contiguous")
        self.admission = admission
        self.preempt_policy = preempt_policy
        self.preempt_mode = preempt_mode
        self.hol_window = max(1, hol_window)
        self.faults = faults
        self._now = clock or time.monotonic
        self.buckets = tuple(b for b in sorted(prefill_buckets)
                             if b <= max_seq) or (max_seq,)
        # state-carrying mixers (mamba/rwkv) integrate every input token —
        # right-padding a bucket would corrupt their state, so those archs
        # prefill at exact prompt length (one compile per distinct length)
        self._exact_prefill = any(
            s.mixer in ("mamba", "rwkv")
            for s in tuple(cfg.pattern) + tuple(cfg.prefix))

        if self.cache_mode == "paged":
            self.block_size = block_size or tiling.paged_block_size(max_seq)
            self.max_blocks = tiling.cdiv(max_seq, self.block_size)
            # default pool = the contiguous HBM budget (+1 sentinel): at
            # EQUAL memory, admission only reserves what a request can
            # actually reach (prompt+max_new), so more requests fit
            self.num_blocks = num_blocks or (n_slots * self.max_blocks + 1)
            self.prefill_chunk = min(prefill_chunk or 64, max_seq)
            self.pool = BlockPool(self.num_blocks, self.block_size)
            self.caches = init_paged_caches(cfg, self.num_blocks,
                                            self.block_size, dtype)
            self._tables = np.zeros((n_slots, self.max_blocks), np.int32)
        else:
            self.pool = None
            self.caches = init_caches(cfg, n_slots, max_seq, dtype)

        # per-phase attention impls, resolved once through the dispatch
        # registry at each phase's representative shape (prefill: widest
        # q tile vs the full cache; decode: one q row vs the full cache —
        # long max_seq resolves 'auto' to the split-KV flash_decode
        # kernel, short caches to whole-row naive).  None defers to
        # cfg.attn_impl, so a config that pins a concrete impl keeps it
        # for both phases; resolution is softmax-aware, so a dualmode
        # config routes to the bit-accurate paths instead of silently
        # running the float ones (snapped one-sweep kernel on blocked
        # prefill, the int split-KV path inside flash_decode at decode —
        # the unit no longer forces a whole-row naive fallback anywhere).
        # The softmax impl is ALSO per-phase overridable: float prefill +
        # dualmode decode is a real serving mix (prompt ingestion at
        # float speed, generated words bit-accurate), and each phase's
        # resolution must see the softmax it will actually compile with.
        self.prefill_softmax_impl = (prefill_softmax_impl
                                     or cfg.softmax_impl)
        self.decode_softmax_impl = decode_softmax_impl or cfg.softmax_impl
        if self.cache_mode == "paged":
            prefill_sq = self.prefill_chunk
            t_kv = self.max_blocks * self.block_size
        else:
            prefill_sq = max_seq if self._exact_prefill else self.buckets[-1]
            t_kv = max_seq
        with self._mesh_ctx():
            # the compiled prefill runs at EVERY bucket, so the ring is
            # only offered to 'auto' when each bucket (and the cache
            # depth) divides the ring width — resolving on the widest
            # bucket alone would bake flash_ring into a program that a
            # smaller bucket then crashes.  Exact-length prefill
            # (mamba/rwkv hybrids) sees arbitrary prompt lengths and
            # never rings; decode is s_q=1 and can't either.
            n = dispatch.ring_axis_size(cfg.ring_axis)
            ring_ok = (self.cache_mode == "contiguous"
                       and not self._exact_prefill and n > 1
                       and max_seq % n == 0
                       and all(b % n == 0 for b in self.buckets))
            self.prefill_attn_impl = dispatch.resolve_attention(
                prefill_attn_impl or cfg.attn_impl, prefill_sq, t_kv,
                softmax_impl=self.prefill_softmax_impl,
                ring_axis=cfg.ring_axis if ring_ok else "")
            self.decode_attn_impl = dispatch.resolve_attention(
                decode_attn_impl or cfg.attn_impl, 1, t_kv,
                softmax_impl=self.decode_softmax_impl)
        prefill_cfg = cfg.replace(attn_impl=self.prefill_attn_impl,
                                  softmax_impl=self.prefill_softmax_impl)
        decode_cfg = cfg.replace(attn_impl=self.decode_attn_impl,
                                 softmax_impl=self.decode_softmax_impl)
        if self.cache_mode == "paged":
            self._prefill = jax.jit(make_chunk_prefill_step(prefill_cfg))
            self._decode = jax.jit(make_paged_decode_step(decode_cfg))
        else:
            self._prefill = jax.jit(make_prefill_step(prefill_cfg))
            self._decode = jax.jit(make_decode_step(decode_cfg))
        self._slots = [_Slot() for _ in range(n_slots)]
        self._admit_seq = 0
        self._queue: list[_QEntry] = []
        self._key = jax.random.PRNGKey(seed)
        self.finished: dict[int, list[int]] = {}
        self.reasons: dict[int, str] = {}
        self._last_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.stats = {"prefills": 0, "decode_steps": 0, "admitted": 0,
                      "prefill_chunks": 0, "cache_copies": 0,
                      "shared_blocks": 0, "blocks_hwm": 0,
                      "admit_time_s": 0.0, "engine_steps": 0,
                      "preemptions": 0, "swap_outs": 0, "swap_ins": 0,
                      "resumes": 0, "hol_skips": 0, "admit_blocked": 0,
                      "numeric": 0, "corrupt": 0, "deadlines": 0,
                      "starved": []}

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else (
            contextlib.nullcontext())

    # ---- host-side bookkeeping ----

    def submit(self, req: Request) -> None:
        # validate at submission so an over-long prompt fails fast instead
        # of being popped mid-run (both prefill flavors: the bucketed path
        # AND the exact-length mamba/rwkv path, which used to skip every
        # length check and silently overrun the cache)
        if self.cache_mode == "paged":
            n = len(req.prompt)
            if n > self.max_seq:
                raise ValueError(f"prompt length {n} exceeds max_seq "
                                 f"{self.max_seq}")
            if self._blocks_needed(req) > self.num_blocks - 1:
                raise ValueError(
                    f"request needs {self._blocks_needed(req)} blocks, "
                    f"exceeds pool of {self.num_blocks - 1}")
        else:
            self._bucket(len(req.prompt))
        ddl = (None if req.deadline_s is None
               else self._now() + req.deadline_s)
        self._queue.append(_QEntry(req=req, deadline_at=ddl))

    def _bucket(self, n: int) -> int:
        if n > self.max_seq:
            raise ValueError(f"prompt length {n} exceeds max_seq "
                             f"{self.max_seq}")
        if self._exact_prefill:
            return n
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds largest bucket "
                         f"{self.buckets[-1]}")

    def _blocks_needed(self, req: Request) -> int:
        """Worst-case table entries: the request can reach at most
        prompt+max_new tokens, clipped by the max_seq retire guard."""
        cap = min(len(req.prompt) + max(req.max_new, 0), self.max_seq)
        return tiling.cdiv(max(cap, 1), self.block_size)

    def _finish_queued(self, e: _QEntry, reason: str) -> None:
        self.finished[e.req.rid] = e.prior_out + e.out
        self.reasons[e.req.rid] = reason

    def _drain_zero_tokens(self) -> None:
        """Finish queued max_new<=0 requests with EMPTY completions —
        they never consume a slot, a prefill, or emit the prefill-sampled
        token.  ONE pass at the queue head, hoisted out of the per-slot
        admission loop (the drain used to re-run — and re-read the queue
        head — once per slot, a burst of zero-token requests cost
        O(queue·slots) head scans instead of O(queue)).  Resume entries
        always have tokens left (a done slot retires instead of
        preempting) and are never drained."""
        while (self._queue and not self._queue[0].is_resume
               and self._queue[0].req.max_new <= 0):
            done = self._queue.pop(0)
            self._finish_queued(done, "max_new")
            self.stats["admitted"] += 1

    def _expire_queue_deadlines(self) -> None:
        """Retire queued entries whose wall-clock budget ran out before
        they reached a slot — reason 'deadline', partial output for
        preempted resumes (the tokens they DID produce are not lost)."""
        if not any(e.deadline_at is not None for e in self._queue):
            return
        now = self._now()
        kept = []
        for e in self._queue:
            if e.deadline_at is not None and now >= e.deadline_at:
                self._finish_queued(e, "deadline")
                self.stats["deadlines"] += 1
            else:
                kept.append(e)
        self._queue = kept

    def _expire_running_deadlines(self) -> None:
        now = None
        for i, s in enumerate(self._slots):
            if s.free or s.deadline_at is None:
                continue
            now = self._now() if now is None else now
            if now >= s.deadline_at:
                self.stats["deadlines"] += 1
                self._finish_slot(i, "deadline")

    def _admit(self) -> None:
        t0 = time.perf_counter()
        self._expire_queue_deadlines()
        self._drain_zero_tokens()
        for i, slot in enumerate(self._slots):
            if not self._queue:
                break
            if not slot.free:
                continue
            if self.cache_mode == "paged":
                if not self._admit_paged_window(i):
                    # nothing in the skip-ahead window fits the pool
                    self.stats["admit_blocked"] += 1
                    break
            else:
                self._admit_contiguous(i)
            self._drain_zero_tokens()
        self.stats["admit_time_s"] += time.perf_counter() - t0

    def _admit_paged_window(self, i: int) -> bool:
        """Admit the first queue entry within ``hol_window`` that the
        pool can satisfy — a small request may skip past a blocked giant
        (stats['hol_skips']).  FCFS prefix registration is preserved:
        admission seq is assigned at admission and the prefill tick is
        seq-ordered, so whoever admits first registers first."""
        window = min(len(self._queue), self.hol_window)
        for j in range(window):
            entry = self._queue[j]
            if (j > 0 and not entry.is_resume
                    and entry.req.max_new <= 0):
                continue            # drains at the head, never via a slot
            if self._admit_entry(i, entry):
                self._queue.pop(j)
                if j > 0:
                    self.stats["hol_skips"] += 1
                return True
        return False

    def _admit_entry(self, i: int, entry: _QEntry) -> bool:
        if entry.swap is not None:
            return self._admit_swapped(i, entry)
        return self._admit_paged(i, entry)

    def _admit_contiguous(self, i: int) -> None:
        entry = self._queue.pop(0)
        req = entry.req
        L = self._bucket(len(req.prompt))
        toks = jnp.asarray(req.prompt + [0] * (L - len(req.prompt)),
                           jnp.int32)[None, :]
        row = init_caches(self.cfg, 1, self.max_seq, self.dtype)
        cross = None
        if req.cross_src is not None:
            cross = (encoder_apply(self.params, self.cfg, req.cross_src)
                     if self.cfg.family == "encdec" else req.cross_src)
        last_idx = jnp.asarray([len(req.prompt) - 1], jnp.int32)
        with self._mesh_ctx():
            logits, row = self._prefill(self.params, row, toks,
                                        last_idx, cross)
        # splice the prefilled row caches into the batch at slot i —
        # stacked-period leaves are (n_periods, B, ...): batch axis 1
        self.caches = _splice_slot(self.caches, row, i)
        self.stats["cache_copies"] += 1
        self._slots[i] = _Slot(rid=req.rid, pos=len(req.prompt),
                               remaining=req.max_new, out=[],
                               temperature=req.temperature,
                               full_prompt=list(req.prompt),
                               priority=req.priority,
                               deadline_at=entry.deadline_at)
        self._key, k = jax.random.split(self._key)
        first = sample_token(k, logits[0], req.temperature)
        self._slots[i].out.append(int(first))
        self._slots[i].remaining -= 1
        self._last_tok = self._last_tok.at[i, 0].set(first)
        self.stats["prefills"] += 1
        self.stats["admitted"] += 1
        self._retire(i)

    def _admit_paged(self, i: int, entry: _QEntry) -> bool:
        """Zero-copy admission: reserve this request's block reach
        (shared prefix by reference, the rest from the pool) and write
        its table row.  NO model compute, NO cache copies — prefill
        happens chunk-at-a-time in subsequent engine steps.  Reactive
        admission (default) reserves only the PROMPT reach and lets the
        decode loop grow the table; 'worst_case' reserves
        prompt+max_new up front so nothing ever preempts.  Returns
        False (leaving the request queued) when the pool is short."""
        req = entry.req
        prompt = (entry.resume_prompt if entry.resume_prompt is not None
                  else req.prompt)
        plen = len(prompt)
        budget = max(req.max_new, 0) - len(entry.prior_out)
        if self.admission == "worst_case":
            cap = min(plen + max(budget, 0), self.max_seq)
            total = tiling.cdiv(max(cap, 1), self.block_size)
        else:
            total = tiling.cdiv(max(plen, 1), self.block_size)
        # shareable prefix: FULL prompt blocks only, and never the block
        # holding the last prompt token — at least one token must run
        # through prefill to produce the first-sample logits (this also
        # guarantees writes never target a shared block)
        if (self.faults is not None and self.faults.alloc_shortfall(
                "admit", self.stats["engine_steps"])):
            return False
        hashes = chain_hashes(prompt, self.block_size)
        got = self.pool.reserve(hashes[:(plen - 1) // self.block_size],
                                total)
        if got is None:             # pool byte-identical: nothing to undo
            return False
        shared, fresh = got
        blocks = shared + fresh
        self._tables[i, :] = 0
        self._tables[i, :len(blocks)] = blocks
        self._slots[i] = _Slot(rid=req.rid, pos=plen,
                               remaining=budget, out=[],
                               temperature=req.temperature,
                               prompt=list(prompt),
                               filled=len(shared) * self.block_size,
                               blocks=blocks, seq=self._admit_seq,
                               full_prompt=list(req.prompt),
                               prior_out=list(entry.prior_out),
                               priority=req.priority,
                               deadline_at=entry.deadline_at)
        self._admit_seq += 1
        if entry.is_resume:
            self.stats["resumes"] += 1
        else:
            self.stats["admitted"] += 1
        self.stats["shared_blocks"] += len(shared)
        self.stats["blocks_hwm"] = max(self.stats["blocks_hwm"],
                                       self.pool.in_use())
        return True

    def _admit_swapped(self, i: int, entry: _QEntry) -> bool:
        """Resume a swapped-out request: re-allocate its block count,
        restore the saved contents, and re-enter decode at the exact
        position it left — no recompute, at the price of holding the
        block bytes on the host while preempted."""
        n = entry.swap["n"]
        forced = (self.faults is not None and self.faults.alloc_shortfall(
            "admit", self.stats["engine_steps"]))
        fresh = None if forced else self.pool.alloc(n)
        if fresh is None:
            return False
        self._swap_in(fresh, entry.swap["saved"])
        req = entry.req
        self._tables[i, :] = 0
        self._tables[i, :n] = fresh
        remaining = req.max_new - len(entry.prior_out) - len(entry.out)
        self._slots[i] = _Slot(rid=req.rid, pos=entry.pos,
                               remaining=remaining, out=list(entry.out),
                               temperature=req.temperature,
                               blocks=fresh, seq=self._admit_seq,
                               full_prompt=list(req.prompt),
                               prior_out=list(entry.prior_out),
                               priority=req.priority,
                               deadline_at=entry.deadline_at)
        self._admit_seq += 1
        self._last_tok = self._last_tok.at[i, 0].set(entry.out[-1])
        self.stats["swap_ins"] += 1
        self.stats["resumes"] += 1
        self.stats["blocks_hwm"] = max(self.stats["blocks_hwm"],
                                       self.pool.in_use())
        return True

    # ---- preemption ----

    def _swap_out(self, blocks: list[int]):
        """Gather the slot's block rows from every cache pool to host
        numpy — the swap store.  Stacked-period leaves carry a leading
        n_periods dim, so their block axis is 1."""
        idx = jnp.asarray(blocks, jnp.int32)

        def take(path, leaf):
            names = [str(getattr(e, "key", getattr(e, "idx", "")))
                     for e in path]
            axis = 1 if "periods" in names else 0
            return np.asarray(jnp.take(leaf, idx, axis=axis))
        return jax.tree_util.tree_map_with_path(take, self.caches)

    def _swap_in(self, blocks: list[int], saved) -> None:
        idx = jnp.asarray(blocks, jnp.int32)

        def put(path, leaf, rows):
            names = [str(getattr(e, "key", getattr(e, "idx", "")))
                     for e in path]
            if "periods" in names:
                return leaf.at[:, idx].set(rows.astype(leaf.dtype))
            return leaf.at[idx].set(rows.astype(leaf.dtype))
        self.caches = jax.tree_util.tree_map_with_path(
            put, self.caches, saved)

    def _pick_victim(self, i: int) -> int | None:
        """Choose a slot to preempt so slot ``i`` can grow: lowest
        priority first, then youngest (or oldest) admission seq.  None
        when no candidate exists or every candidate outranks the grower
        (the grower should yield instead of evicting its better)."""
        s = self._slots[i]
        sign = -1 if self.preempt_policy == "youngest" else 1
        cands = [(c.priority, sign * c.seq, j)
                 for j, c in enumerate(self._slots)
                 if j != i and not c.free]
        if not cands:
            return None
        prio, _, j = min(cands)
        if prio > s.priority:
            return None
        return j

    def _preempt(self, i: int) -> None:
        """Evict slot ``i`` back to the queue HEAD.  Decoding slots under
        preempt_mode='swap' keep their KV on the host and resume in
        place; everything else (and every mid-prefill slot) drops its
        blocks and resumes by re-prefilling prompt + generated prefix —
        greedy decode makes the recompute token-for-token identical."""
        s = self._slots[i]
        gen = s.prior_out + s.out
        req = Request(rid=s.rid, prompt=list(s.full_prompt),
                      max_new=len(gen) + max(s.remaining, 0),
                      temperature=s.temperature, priority=s.priority)
        if self.preempt_mode == "swap" and s.decoding:
            entry = _QEntry(req=req, deadline_at=s.deadline_at,
                            prior_out=list(s.prior_out), out=list(s.out),
                            pos=s.pos,
                            swap={"saved": self._swap_out(s.blocks),
                                  "n": len(s.blocks)})
            self.stats["swap_outs"] += 1
        else:
            base = s.prompt if s.prompt is not None else (
                s.full_prompt + s.prior_out)
            entry = _QEntry(req=req, deadline_at=s.deadline_at,
                            prior_out=s.prior_out + s.out,
                            resume_prompt=list(base) + list(s.out))
        for b in s.blocks:
            self.pool.decref(b)
        self._tables[i, :] = 0
        self._slots[i] = _Slot()
        self._queue.insert(0, entry)
        self.stats["preemptions"] += 1

    def _grow_decode_tables(self) -> None:
        """Reactive growth, oldest admission first: every decoding slot's
        table must cover position ``pos`` BEFORE the decode tick writes
        there (out-of-table scatters clamp to the sentinel block and the
        token's K/V would be silently lost).  Worst-case admission makes
        this a no-op — the reach is already reserved."""
        order = sorted((s.seq, i) for i, s in enumerate(self._slots)
                       if s.decoding)
        for seq, i in order:
            s = self._slots[i]
            if not s.decoding or s.seq != seq:
                continue            # preempted by an earlier grower
            self._grow_or_preempt(i)

    def _grow_or_preempt(self, i: int) -> bool:
        s = self._slots[i]
        while True:
            forced = (self.faults is not None and
                      self.faults.alloc_shortfall(
                          "grow", self.stats["engine_steps"]))
            fresh = (None if forced
                     else self.pool.ensure_reach(s.blocks, s.pos + 1))
            if fresh is not None:
                if fresh:
                    self._tables[i, :len(s.blocks)] = s.blocks
                    self.stats["blocks_hwm"] = max(
                        self.stats["blocks_hwm"], self.pool.in_use())
                return True
            v = self._pick_victim(i)
            if v is None:
                self._preempt(i)    # nobody cheaper to evict: yield
                return False
            self._preempt(v)

    def _validate_tables(self) -> None:
        """Per-step integrity check: every occupied slot's device-bound
        table row must mirror its host block list exactly.  A mismatch
        (bit flip, buggy writer, injected corruption) retires the slot
        with reason 'corrupt' — blocks are refunded from the HOST list,
        which is the allocation truth."""
        for i, s in enumerate(self._slots):
            if s.free:
                continue
            row = self._tables[i]
            want = np.zeros_like(row)
            want[:len(s.blocks)] = s.blocks
            if not np.array_equal(row, want):
                self.stats["corrupt"] += 1
                self._finish_slot(i, "corrupt")

    def _prefill_tick(self) -> None:
        """Advance ONE mid-prefill slot by ONE chunk.  Bounded work per
        engine step — a 32k prompt costs 32k/C steps, each sharing the
        step with a full decode tick, so decode traffic never stalls
        behind a long prompt.  FCFS by admission order: always the
        OLDEST prefilling request, so a fresh admission into a lower
        slot index cannot starve a half-prefilled one (and the first
        completion registers its prefix blocks before later duplicates
        finish privately)."""
        filling = [(s.seq, i, s) for i, s in enumerate(self._slots)
                   if not s.free and s.prompt is not None]
        for _, i, s in sorted(filling)[:1]:
            c0 = s.filled
            real = s.prompt[c0:c0 + self.prefill_chunk]
            toks = jnp.asarray(
                real + [0] * (self.prefill_chunk - len(real)),
                jnp.int32)[None, :]
            last_idx = jnp.asarray([len(real) - 1], jnp.int32)
            tables = jnp.asarray(self._tables[i:i + 1])
            with self._mesh_ctx():
                logits, self.caches = self._prefill(
                    self.params, self.caches, toks, jnp.int32(c0), tables,
                    last_idx)
            s.filled = c0 + len(real)
            self.stats["prefill_chunks"] += 1
            if s.filled >= len(s.prompt):
                if self.faults is not None:
                    logits = self.faults.prefill_logits(
                        self.stats["engine_steps"], s.rid, logits)
                if not bool(np.asarray(jnp.isfinite(logits).all())):
                    self.stats["numeric"] += 1
                    self._finish_slot(i, "numeric")
                    return
                # prefill complete: the prompt's full blocks are now
                # written and immutable — index them for prefix sharing
                n_full = len(s.prompt) // self.block_size
                hashes = chain_hashes(s.prompt, self.block_size)
                self.pool.register(hashes[:n_full],
                                   [int(b) for b in
                                    self._tables[i, :n_full]])
                s.prompt = None
                self._key, k = jax.random.split(self._key)
                first = sample_token(k, logits[0], s.temperature)
                s.out.append(int(first))
                s.remaining -= 1
                self._last_tok = self._last_tok.at[i, 0].set(first)
                self.stats["prefills"] += 1
                self._retire(i)
            return                          # one chunk per step

    def _finish_slot(self, i: int, reason: str) -> None:
        """Unconditional retirement with a reason code: output so far is
        delivered (prior incarnations included), blocks refunded."""
        s = self._slots[i]
        self.finished[s.rid] = s.prior_out + s.out
        self.reasons[s.rid] = reason
        if self.cache_mode == "paged":
            for b in s.blocks:
                self.pool.decref(b)
            self._tables[i, :] = 0
        self._slots[i] = _Slot()

    def _retire(self, i: int) -> None:
        s = self._slots[i]
        if s.free:
            return
        eos = (self.eos_id is not None and s.out and
               s.out[-1] == self.eos_id)
        if eos:
            reason = "eos"
        elif s.remaining <= 0:
            reason = "max_new"
        elif s.pos >= self.max_seq - 1:
            reason = "max_seq"
        else:
            return
        self._finish_slot(i, reason)

    @property
    def active(self) -> int:
        return sum(not s.free for s in self._slots)

    def pending(self) -> int:
        return len(self._queue) + self.active

    # ---- one engine step = admit + prefill chunk + one lockstep decode ----

    def step(self) -> None:
        self.stats["engine_steps"] += 1
        if self.cache_mode == "paged":
            if self.faults is not None:
                self.faults.corrupt_tables(self.stats["engine_steps"],
                                           self._tables, self._slots)
            self._validate_tables()
        self._expire_running_deadlines()
        self._admit()
        if self.cache_mode == "paged":
            self._prefill_tick()
            self._grow_decode_tables()
        decoding = [s.decoding for s in self._slots]
        if not any(decoding):
            return
        pos = jnp.asarray([s.pos if s.decoding else 0
                           for s in self._slots], jnp.int32)
        with self._mesh_ctx():
            if self.cache_mode == "paged":
                # non-decoding rows get all-sentinel tables: their writes
                # land in block 0, never in a mid-prefill slot's blocks
                masked = np.where(np.asarray(decoding)[:, None],
                                  self._tables, 0)
                logits, self.caches = self._decode(
                    self.params, self.caches, self._last_tok, pos,
                    jnp.asarray(masked))
            else:
                logits, self.caches = self._decode(
                    self.params, self.caches, self._last_tok, pos)
        if self.faults is not None:
            logits = self.faults.decode_logits(
                self.stats["engine_steps"],
                [s.rid if s.decoding else -1 for s in self._slots], logits)
        # numeric sentry: one (B,) host pull per tick.  A non-finite row
        # quarantines ONLY that slot (reason 'numeric', blocks refunded);
        # the per-slot sampling keys below are split from the step key by
        # slot INDEX, so the neighbours' token streams are bitwise
        # unaffected by the quarantine.
        finite = np.asarray(jnp.isfinite(logits).all(axis=-1))
        self.stats["decode_steps"] += 1
        self._key, k = jax.random.split(self._key)
        keys = jax.random.split(k, self.n_slots)
        for i, s in enumerate(self._slots):
            if not s.decoding:
                continue
            if not bool(finite[i]):
                self.stats["numeric"] += 1
                self._finish_slot(i, "numeric")
                continue
            tok = int(sample_token(keys[i], logits[i], s.temperature))
            s.out.append(tok)
            s.pos += 1
            s.remaining -= 1
            self._last_tok = self._last_tok.at[i, 0].set(tok)
            self._retire(i)

    def run(self, requests: list[Request], max_steps: int = 10_000
            ) -> dict[int, list[int]]:
        for r in requests:
            self.submit(r)
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        if self.pending():
            # max_steps exhausted: flush everything still live with
            # reason 'starved' (partial output delivered, blocks
            # refunded) and SAY SO — the old contract silently returned
            # a short dict and leaked the pool
            starved = []
            for i, s in enumerate(self._slots):
                if not s.free:
                    starved.append(s.rid)
                    self._finish_slot(i, "starved")
            while self._queue:
                e = self._queue.pop(0)
                starved.append(e.req.rid)
                self._finish_queued(e, "starved")
            self.stats["starved"].extend(starved)
        return dict(self.finished)
