"""Host-side block pool for the paged KV cache (vLLM-style).

The device side is dumb on purpose: per-layer (N, block_size, ...) pools
plus one (B, max_blocks) int32 block table threaded through
``lm_apply(..., paged=tables)``.  Everything stateful lives here, in
plain python, outside every compiled program:

  * a free list over blocks 1..N-1 — block 0 is the WRITE SENTINEL: the
    kernels clamp out-of-table scatter targets to it, so it is never
    handed out and its contents are never read as valid keys;
  * per-block refcounts — admission takes references, retirement drops
    them, and a block is shared whenever two requests' tables point at
    the same id (prefix caching);
  * a prefix index keyed by CHAIN hashes of full prompt blocks
    (hash of (parent hash, block tokens) — a block is only reusable when
    its entire left context matches, because K/V at a position depends on
    every position before it);
  * an LRU of "cached" blocks: refcount hit 0 but the block still holds
    registered prefix content, so it stays matchable until capacity
    pressure actually evicts it — free-list blocks are preferred for
    allocation, cached blocks are cannibalized oldest-first.

Admission cost is O(blocks touched) of pure bookkeeping — no cache-tree
copies (the contiguous engine's ``_splice_slot`` copied whole rows).
"""
from __future__ import annotations

from collections import OrderedDict


def chain_hashes(tokens, block_size: int) -> list:
    """Chain hash per FULL block of ``tokens``: h_j = hash((h_{j-1},
    block_j tokens)).  Partial trailing blocks get no hash — only full,
    immutable blocks are shareable."""
    out: list = []
    h = 0
    n_full = len(tokens) // block_size
    for j in range(n_full):
        h = hash((h, tuple(tokens[j * block_size:(j + 1) * block_size])))
        out.append(h)
    return out


class BlockPool:
    """Ref-counted fixed-size block allocator with a prefix-hash index."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least one sentinel + one data block")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list over 1..N-1 (0 is the sentinel)
        self._free = list(range(num_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}
        self._hash_to_block: dict = {}
        self._block_hash: dict[int, object] = {}
        # refcount-0 blocks whose prefix content is still matchable;
        # insertion order = LRU order (oldest evicted first)
        self._cached: OrderedDict[int, None] = OrderedDict()
        self.hwm = 0                      # high-water mark of in_use

    # ---- capacity ----

    def available(self) -> int:
        """Blocks allocatable right now (free + evictable cached)."""
        return len(self._free) + len(self._cached)

    def in_use(self) -> int:
        """Blocks holding live (refcounted) data."""
        return len(self._ref)

    # ---- allocation ----

    def alloc(self, n: int) -> list[int] | None:
        """Take ``n`` blocks at refcount 1, or None if capacity is short
        (all-or-nothing: a partial admission would deadlock the step
        loop).  Free-list blocks first; then the LRU cached blocks are
        evicted, dropping their prefix index entries."""
        if self.available() < n:
            return None
        out = []
        for _ in range(n):
            if self._free:
                b = self._free.pop()
            else:
                b, _ = self._cached.popitem(last=False)   # oldest
                self._drop_hash(b)
            self._ref[b] = 1
            out.append(b)
        self.hwm = max(self.hwm, self.in_use())
        return out

    def ensure_reach(self, held: list[int], tokens: int) -> list[int] | None:
        """Grow ``held`` (a request's block list, mutated in place) until
        it reaches ``tokens`` positions.  Returns the newly allocated
        blocks ([] when the reach is already covered) or None on
        shortfall — all-or-nothing, like :meth:`alloc`, and ``held`` is
        untouched on failure.  This is the reactive-admission growth
        primitive: decode ticks call it right before writing position
        ``tokens - 1`` so the table always covers the scatter target
        (out-of-table writes clamp to the sentinel and silently lose
        data)."""
        need = -(-tokens // self.block_size) - len(held)
        if need <= 0:
            return []
        fresh = self.alloc(need)
        if fresh is None:
            return None
        held.extend(fresh)
        return fresh

    def incref(self, block: int) -> None:
        self._ref[block] += 1

    def decref(self, block: int) -> None:
        """Drop one reference.  At zero the block goes to the cached LRU
        when it still backs a registered prefix (matchable until
        evicted), else straight to the free list."""
        r = self._ref[block] - 1
        if r > 0:
            self._ref[block] = r
            return
        del self._ref[block]
        if block in self._block_hash:
            self._cached[block] = None
            self._cached.move_to_end(block)
        else:
            self._free.append(block)

    # ---- prefix sharing ----

    def match_prefix(self, hashes) -> list[int]:
        """Longest run of ``hashes`` present in the index, as blocks with
        a reference TAKEN on each (cached blocks are revived to refcount
        1).  The caller owns the references — roll back with decref if
        the rest of the admission fails."""
        out = []
        for h in hashes:
            b = self._hash_to_block.get(h)
            if b is None:
                break
            if b in self._cached:
                del self._cached[b]
                self._ref[b] = 1
            else:
                self._ref[b] += 1
            out.append(b)
        self.hwm = max(self.hwm, self.in_use())
        return out

    def peek_prefix(self, hashes) -> list[int]:
        """Longest indexed run of ``hashes`` as blocks — NO references
        taken, nothing mutated.  The feasibility half of :meth:`reserve`."""
        out = []
        for h in hashes:
            b = self._hash_to_block.get(h)
            if b is None:
                break
            out.append(b)
        return out

    def reserve(self, hashes, total: int):
        """Atomic admission: take references on the longest indexed
        prefix of ``hashes`` AND allocate the remaining
        ``total - len(prefix)`` fresh blocks, or return None with the
        pool BYTE-IDENTICAL to before the call.

        Feasibility is checked on a reference-free peek first: matched
        blocks sitting in the cached LRU would be revived (leaving the
        evictable set), so they are subtracted from capacity before the
        fresh demand is compared.  The old shape — match_prefix, alloc,
        decref-rollback on shortfall — restored every refcount but
        rotated the revived blocks to the LRU tail, so a failed
        admission silently reordered evictions."""
        shared = self.peek_prefix(hashes)
        need = total - len(shared)
        revive = sum(1 for b in shared if b in self._cached)
        if len(self._free) + len(self._cached) - revive < need:
            return None
        shared = self.match_prefix(hashes)
        fresh = self.alloc(need)
        if fresh is None:           # unreachable: feasibility was checked
            for b in shared:
                self.decref(b)
            return None
        return shared, fresh

    def register(self, hashes, blocks) -> None:
        """Index ``blocks`` (just-prefilled FULL prompt blocks) under
        their chain hashes.  First writer wins: a hash already indexed
        keeps its existing block (concurrent identical prompts prefill
        privately; the duplicate simply stays unshared)."""
        for h, b in zip(hashes, blocks):
            if h not in self._hash_to_block:
                self._hash_to_block[h] = b
                self._block_hash[b] = h

    def _drop_hash(self, block: int) -> None:
        h = self._block_hash.pop(block, None)
        if h is not None and self._hash_to_block.get(h) == block:
            del self._hash_to_block[h]
