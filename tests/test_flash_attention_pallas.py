"""Pallas blocked flash attention vs the naive oracle and the pure-JAX
blocked path: the three implementations must agree (ISSUE 1 acceptance:
within 1e-5 in interpret mode) across causal/non-causal, ragged validity,
GQA groups, MLA-style head dims, and non-divisible sequence lengths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import _naive_sdpa, _sdpa
from repro.models.flash import flash_attention

RNG = np.random.default_rng(7)


def _mk(b, s, t, k, g, h, hv=None):
    hv = hv or h
    q = jnp.asarray(RNG.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(RNG.normal(size=(b, t, k, h)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, t, k, hv)), jnp.float32)
    return q, kk, v


def _check_all_paths(q, k, v, q_pos, kv_valid, causal, atol=1e-5, block=16):
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal)
    got_pl = flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                    causal=causal, interpret=True)
    got_jx = flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                             causal=causal, block=block)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               atol=atol)
    np.testing.assert_allclose(np.asarray(got_jx), np.asarray(want),
                               atol=atol)


@pytest.mark.parametrize("causal", [True, False])
def test_pallas_flash_matches_naive_and_jax_flash(causal):
    q, k, v = _mk(2, 64, 128, 2, 3, 16)        # GQA: G=3 groups per KV head
    q_pos = jnp.broadcast_to(jnp.arange(64, 128)[None], (2, 64))
    kv_valid = jnp.ones((2, 128), bool)
    _check_all_paths(q, k, v, q_pos, kv_valid, causal)


@pytest.mark.parametrize("causal", [True, False])
def test_pallas_flash_ragged_kv_valid(causal):
    q, k, v = _mk(2, 32, 96, 1, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(64, 96)[None], (2, 32))
    # every batch row has its own validity frontier + interior holes
    kv_valid = jnp.asarray(RNG.random((2, 96)) > 0.3)
    kv_valid = kv_valid.at[:, 0].set(True)
    _check_all_paths(q, k, v, q_pos, kv_valid, causal)


@pytest.mark.parametrize("s,t", [(17, 33), (5, 100), (130, 259)])
def test_pallas_flash_non_divisible_lengths(s, t):
    """S/T off the block grid exercise the pad-and-slice tiling policy
    (for BOTH blocked paths: the Pallas kernel and pure-JAX flash, whose
    odd-T handling pads KV instead of degrading to a 1-wide scan)."""
    q, k, v = _mk(1, s, t, 2, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (1, s))
    kv_valid = jnp.ones((1, t), bool)
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=True)
    got = flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                 causal=True, interpret=True)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    got_jx = flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                             causal=True, block=16)
    np.testing.assert_allclose(np.asarray(got_jx), np.asarray(want),
                               atol=1e-5)


def test_pallas_flash_grad_matches_naive():
    """The kernel's custom VJP (backward via the pure-JAX blocked path)
    must match the naive path's gradient — the train path uses this."""
    q, k, v = _mk(1, 32, 32, 1, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(32)[None], (1, 32))
    kv_valid = jnp.ones((1, 32), bool)
    g = jax.grad(lambda q_: flash_attention_pallas(
        q_, k, v, q_pos=q_pos, kv_valid=kv_valid, interpret=True).sum())(q)
    g_ref = jax.grad(lambda q_: _naive_sdpa(
        q_, k, v, q_pos=q_pos, kv_valid=kv_valid).sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)


def test_padded_phantom_keys_carry_no_mass():
    """All real scores below MASK_VALUE (-30): pad-introduced phantom keys
    would each absorb exp(-30 - m) mass if masked with the finite pad
    value — they must score -inf so ragged-T parity holds even here."""
    b, s, t, kh, g, h = 1, 8, 1500, 1, 1, 16
    q = jnp.full((b, s, kh, g, h), 3.0, jnp.float32)
    k = jnp.full((b, t, kh, h), -3.0, jnp.float32)     # scores = -36 < -30
    v = jnp.asarray(RNG.normal(size=(b, t, kh, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (b, s))
    kv_valid = jnp.ones((b, t), bool)
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=False)
    got_pl = flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                    causal=False, interpret=True)
    got_jx = flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                             causal=False, block=1024)   # pads 1500 -> 2048
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_jx), np.asarray(want),
                               atol=1e-5)


def test_pallas_flash_mla_style_hv_differs():
    q, k, v = _mk(2, 32, 32, 4, 1, 24, hv=12)   # qk head 24, v head 12
    q_pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    kv_valid = jnp.ones((2, 32), bool)
    _check_all_paths(q, k, v, q_pos, kv_valid, True, block=8)


def test_pallas_flash_hv_off_lane_grid():
    """hv=72 is not a multiple of the 128 lane width: the f32 acc scratch
    must round its lane dim up (tiling.scratch_lanes) and slice at emit —
    an hv-sized scratch mis-tiles in compiled (non-interpret) mode."""
    q, k, v = _mk(1, 16, 40, 2, 1, 16, hv=72)
    q_pos = jnp.broadcast_to(jnp.arange(24, 40)[None], (1, 16))
    kv_valid = jnp.ones((1, 40), bool)
    _check_all_paths(q, k, v, q_pos, kv_valid, True)


def test_naive_bf16_qk_accumulates_f32_matches_flash():
    """bf16 naive attention used to accumulate QK^T in bf16 and only then
    cast (jnp.einsum(...).astype(f32) * scale), diverging from the
    blocked paths which pre-scale q in f32 — all three paths must now
    agree at f32-accumulation tolerance, not bf16-accumulation error."""
    q, k, v = _mk(2, 48, 64, 2, 2, 32)
    q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))
    q_pos = jnp.broadcast_to(jnp.arange(16, 64)[None], (2, 48))
    kv_valid = jnp.ones((2, 64), bool)
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=True)
    got_pl = flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                    causal=True, interpret=True)
    got_jx = flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                             causal=True, block=16)
    # remaining difference is only the bf16 rounding of probs/output, not
    # a bf16 score accumulation (which scales with T and head_dim)
    np.testing.assert_allclose(np.asarray(got_pl, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)
    np.testing.assert_allclose(np.asarray(got_jx, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)
    # and the scores themselves are f32-accurate: compare against the f32
    # oracle computed from upcast inputs
    want_f32 = _naive_sdpa(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), q_pos=q_pos,
                           kv_valid=kv_valid, causal=True)
    np.testing.assert_allclose(np.asarray(want, np.float32),
                               np.asarray(want_f32), atol=2e-2)


def test_pallas_flash_explicit_blocks_and_dtype():
    q, k, v = _mk(1, 64, 64, 2, 2, 16)
    q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))
    q_pos = jnp.broadcast_to(jnp.arange(64)[None], (1, 64))
    kv_valid = jnp.ones((1, 64), bool)
    got = flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                 block_q=16, block_kv=32, interpret=True)
    assert got.dtype == jnp.bfloat16
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


def test_scale_is_traced_no_recompile_per_scale():
    """`scale` rides as a traced operand folded into the q pre-scale —
    distinct head-dim/user scales must share ONE compilation (it used to
    be a jit static argname, recompiling the kernel per value)."""
    q, k, v = _mk(1, 16, 16, 1, 1, 8)
    q_pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16))
    kv_valid = jnp.ones((1, 16), bool)
    from repro.kernels.flash_attention import _flash_pallas_jit
    base = _flash_pallas_jit._cache_size()
    outs = [flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                   scale=sc, interpret=True)
            for sc in (0.125, 0.25, 0.3535, 1.0)]
    assert _flash_pallas_jit._cache_size() - base <= 1
    # and the scale value still matters numerically
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid, scale=0.25)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(want),
                               atol=1e-5)
    assert not np.allclose(np.asarray(outs[0]), np.asarray(outs[3]))


# ---------------- dispatch registry ----------------

def test_registry_has_all_attention_impls():
    for name in ("naive", "flash", "flash_pallas"):
        assert callable(dispatch.get_attention(name)), name


def test_resolve_auto_matches_use_flash_rule():
    assert dispatch.resolve_attention("auto", 64, 64) == "naive"
    assert dispatch.resolve_attention("auto", 4096, 4096) == "flash"
    # ragged long T streams too (pad-and-slice removed the %512 guard)
    assert dispatch.resolve_attention("auto", 32768, 33000) == "flash"
    assert dispatch.resolve_attention("naive", 4096, 4096) == "naive"
    with pytest.raises(ValueError):
        dispatch.resolve_attention("no_such_impl", 8, 8)


def test_registry_self_loads_providers(subproc):
    """Resolving through a cold registry (a consumer that never imported
    repro.models) must lazily import the providers rather than silently
    fall back to 'naive' — needs a fresh interpreter, since in-process
    the providers are already imported."""
    out = subproc('''
from repro.kernels import dispatch
print("auto->", dispatch.resolve_attention("auto", 4096, 4096))
print("pallas_callable->", callable(dispatch.get_attention("flash_pallas")))
''', n_devices=1)
    assert "auto-> flash" in out
    assert "pallas_callable-> True" in out


def test_sdpa_explicit_pallas_impl_matches_naive():
    q, k, v = _mk(1, 48, 48, 2, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(48)[None], (1, 48))
    kv_valid = jnp.ones((1, 48), bool)
    got = _sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                softmax_impl="float", attn_impl="flash_pallas")
    want = _sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                 softmax_impl="float", attn_impl="naive")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_registry_naive_honors_softmax_impl():
    """The registry entry carries softmax_impl — resolving 'naive' through
    dispatch must not silently lose the bit-accurate dualmode unit."""
    q, k, v = _mk(1, 8, 8, 1, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    kv_valid = jnp.ones((1, 8), bool)
    kw = dict(q_pos=q_pos, kv_valid=kv_valid, causal=True, scale=None)
    via_registry = dispatch.get_attention("naive")(
        q, k, v, softmax_impl="dualmode", **kw)
    direct = _sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                   softmax_impl="dualmode", attn_impl="naive")
    np.testing.assert_array_equal(np.asarray(via_registry),
                                  np.asarray(direct))
    float_path = dispatch.get_attention("naive")(
        q, k, v, softmax_impl="float", **kw)
    assert not np.array_equal(np.asarray(via_registry),
                              np.asarray(float_path))


def test_ffn_registry():
    assert dispatch.get_ffn("dense") is None
    assert callable(dispatch.get_ffn("fused_pallas"))
    with pytest.raises(ValueError):
        dispatch.get_ffn("no_such_ffn")
