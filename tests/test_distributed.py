"""Pipeline parallelism + HLO analyzer + dry-run cell lowering."""
import pytest

from repro.launch.hlo_analysis import HloProgram, analyze_hlo


def test_pipeline_matches_sequential(subproc):
    code = '''
import jax, jax.numpy as jnp
from repro.distributed.pipeline import pipelined, bubble_fraction
from repro.launch.mesh import auto_mesh
mesh = auto_mesh((4,), ("stage",))
def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])
params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.5}
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
y = pipelined(stage_fn, mesh, n_micro=4)(params, x)
ref = x
for i in range(4):
    ref = stage_fn({"w": params["w"][i]}, ref)
assert float(jnp.abs(y - ref).max()) < 1e-6
assert abs(bubble_fraction(4, 4) - 3 / 7) < 1e-9
print("PP_OK")
'''
    assert "PP_OK" in subproc(code, n_devices=4)


def test_hlo_analyzer_counts_scan_trips(subproc):
    code = '''
import jax, jax.numpy as jnp
from repro.launch.hlo_analysis import analyze_hlo
def f(x, w):
    def body(c, _):
        return jnp.tanh(c @ w), None
    y, _ = jax.lax.scan(body, x, None, length=12)
    return y
sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
c = jax.jit(f).lower(sds, sds).compile()
a = analyze_hlo(c.as_text())
expected = 12 * 2 * 256 ** 3
assert abs(a["flops"] - expected) / expected < 0.01, a["flops"]
print("TRIPS_OK")
'''
    assert "TRIPS_OK" in subproc(code, n_devices=1)


def test_hlo_analyzer_sees_collectives(subproc):
    code = '''
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import auto_mesh
mesh = auto_mesh((4,), ("data",))
sh = NamedSharding(mesh, P("data"))
def f(x):
    return jnp.sum(x)          # cross-device all-reduce
c = jax.jit(f, in_shardings=sh).lower(
    jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
a = analyze_hlo(c.as_text())
assert a["collective_count"] >= 1, a
print("COLL_OK")
'''
    assert "COLL_OK" in subproc(code, n_devices=4)


def test_dryrun_single_cell_end_to_end(subproc):
    """One full production-mesh cell: lower+compile+roofline on 512 fake
    devices — the real deliverable, excercised in CI."""
    code = '''
from repro.launch.dryrun import run_cell
rec = run_cell("qwen1.5-0.5b", "decode_32k", multi_pod=False, verbose=False)
assert rec["ok"], rec.get("error")
assert rec["flops"] > 0 and rec["bytes_accessed"] > 0
assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")
assert rec["memory"]["total_hbm_bytes"] < 16e9     # fits v5e HBM
print("CELL_OK", rec["roofline"]["bottleneck"])
'''
    assert "CELL_OK" in subproc(code, n_devices=512)


def test_hlo_program_parses_tuple_types():
    txt = """
HloModule m

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4]{0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %ar = f32[4]{0} all-reduce(%x), replica_groups={}, to_apply=%cond
  ROOT %t = (s32[], f32[4]) tuple(%i2, %ar)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4]) tuple(%z, %a)
  %w = (s32[], f32[4]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4]{0} get-tuple-element(%w), index=1
}
"""
    prog = HloProgram(txt)
    assert prog.entry == "%main"
    a = analyze_hlo(txt)
    # 7 trips x one 16-byte all-reduce x ring factor 2
    assert a["collective_count"] == 7
    assert a["collective_wire_bytes"] == 7 * 16 * 2


def test_custom_call_charges_hbm_bytes():
    """Regression: custom-call (a TPU pallas_call) used to sit in the
    byte-free set, zeroing the HBM traffic of exactly the kernels the
    analyzer exists to price.  A flash-style custom-call must charge its
    operands + result, and the -done half of an async pair must not
    double-charge."""
    txt = """
HloModule m

ENTRY %main (q: f32[128,64], k: f32[1024,64], v: f32[1024,64]) -> f32[128,64] {
  %q = f32[128,64]{1,0} parameter(0)
  %k = f32[1024,64]{1,0} parameter(1)
  %v = f32[1024,64]{1,0} parameter(2)
  ROOT %o = f32[128,64]{1,0} custom-call(%q, %k, %v), custom_call_target="tpu_custom_call"
}
"""
    a = analyze_hlo(txt)
    expected = 4 * (128 * 64 + 1024 * 64 + 1024 * 64 + 128 * 64)
    assert a["bytes_accessed"] == expected, a

    async_txt = """
HloModule m

ENTRY %main (x: f32[256,256]) -> f32[256,256] {
  %x = f32[256,256]{1,0} parameter(0)
  %s = f32[256,256]{1,0} custom-call-start(%x), custom_call_target="tpu_custom_call"
  ROOT %d = f32[256,256]{1,0} custom-call-done(%s)
}
"""
    a2 = analyze_hlo(async_txt)
    assert a2["bytes_accessed"] == 4 * 256 * 256 * 2, a2  # start only


def test_collective_result_bytes_walks_all_computations():
    """The mesh-safety walker: every all-gather result in the module
    (loop bodies included), async pairs counted once at -start."""
    from repro.launch.hlo_analysis import collective_result_bytes
    txt = """
HloModule m

%body (p: (s32[], f32[8,64])) -> (s32[], f32[8,64]) {
  %p = (s32[], f32[8,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,64]{1,0} get-tuple-element(%p), index=1
  %g = f32[64,64]{1,0} all-gather(%x), replica_groups={}, dimensions={0}
  %r = f32[8,64]{1,0} slice(%g), slice={[0:8], [0:64]}
  ROOT %t = (s32[], f32[8,64]) tuple(%i, %r)
}

ENTRY %main (a: f32[8,64]) -> f32[64,64] {
  %a = f32[8,64]{1,0} parameter(0)
  %s = f32[64,64]{1,0} all-gather-start(%a), replica_groups={}, dimensions={0}
  ROOT %d = f32[64,64]{1,0} all-gather-done(%s)
}
"""
    sizes = collective_result_bytes(txt, "all-gather")
    assert sorted(sizes) == [64 * 64 * 4, 64 * 64 * 4]
    assert collective_result_bytes(txt, "all-reduce") == []
