"""Training integration: convergence, grad-accum equivalence, fault
tolerance (checkpoint/restart), straggler monitor, elastic remesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data import SyntheticLM
from repro.models.transformer import init_lm
from repro.optim import adamw_init
from repro.train import Trainer
from repro.train.step import TrainState, make_train_step


def _cfg():
    return registry.reduced_config("qwen1.5-0.5b").replace(vocab=96)


def test_loss_decreases(tmp_path):
    tcfg = TrainConfig(lr=2e-3, warmup_steps=3, total_steps=40,
                       checkpoint_every=1000,
                       checkpoint_dir=str(tmp_path / "ck"))
    tr = Trainer(_cfg(), tcfg, global_batch=8, seq_len=32,
                 log=lambda *_: None)
    first = None
    for i in range(4):
        m = tr.run(10)
        if first is None:
            first = m["loss"]
    assert m["loss"] < first - 0.15, (first, m["loss"])


def test_microbatch_equals_full_batch_gradients():
    cfg = _cfg()
    t_full = TrainConfig(lr=1e-3, microbatch=0, remat=False)
    t_micro = TrainConfig(lr=1e-3, microbatch=2, remat=False)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = TrainState(params, adamw_init(params), {})
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=8)
    t, l = ds.batch(0)
    batch = {"tokens": t, "labels": l}
    s1, m1 = jax.jit(make_train_step(cfg, t_full))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, t_micro))(state, batch)
    np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=1e-4)
    # resulting params identical within fp tolerance
    diff = jax.tree.reduce(jnp.maximum, jax.tree.map(
        lambda a, b: jnp.abs(a - b).max(), s1.params, s2.params))
    assert float(diff) < 2e-5


def test_checkpoint_restart_continues_exactly(tmp_path):
    ck = str(tmp_path / "ck")
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=100,
                       checkpoint_every=10, checkpoint_dir=ck)
    tr = Trainer(_cfg(), tcfg, global_batch=4, seq_len=16,
                 log=lambda *_: None)
    tr.run(10)                                    # saves at step 10
    loss_after_20 = Trainer(_cfg(), tcfg, global_batch=4, seq_len=16,
                            log=lambda *_: None)
    assert loss_after_20.start_step == 10        # resumed
    m_resumed = loss_after_20.run(10)
    # continuous run reference
    tcfg2 = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=100,
                        checkpoint_every=1000,
                        checkpoint_dir=str(tmp_path / "ck2"))
    tr2 = Trainer(_cfg(), tcfg2, global_batch=4, seq_len=16,
                  log=lambda *_: None)
    m_cont = tr2.run(20)
    np.testing.assert_allclose(m_resumed["loss"], m_cont["loss"], rtol=1e-4)


def test_straggler_monitor_flags_slow_step(tmp_path):
    tcfg = TrainConfig(total_steps=50, checkpoint_every=1000,
                       checkpoint_dir=str(tmp_path / "ck"))
    tr = Trainer(_cfg(), tcfg, global_batch=4, seq_len=16,
                 log=lambda *_: None)
    for i in range(8):
        tr._watch_straggler(i, 0.1)
    tr._watch_straggler(8, 0.9)                  # 9x the EMA
    assert 8 in tr.straggler_steps


def test_grad_compress_trains(tmp_path):
    tcfg = TrainConfig(lr=2e-3, warmup_steps=3, total_steps=30,
                       grad_compress=True, checkpoint_every=1000,
                       checkpoint_dir=str(tmp_path / "ck"))
    tr = Trainer(_cfg(), tcfg, global_batch=8, seq_len=32,
                 log=lambda *_: None)
    m0 = tr.run(5)
    m1 = tr.run(25)
    assert m1["loss"] < m0["loss"]


def test_elastic_remesh_restore(tmp_path, subproc):
    """Save on 1 device; restore + continue on a 2x4 mesh (8 devices)."""
    ck = str(tmp_path / "ck")
    tcfg = TrainConfig(lr=1e-3, total_steps=100, checkpoint_every=5,
                       checkpoint_dir=ck)
    tr = Trainer(_cfg(), tcfg, global_batch=8, seq_len=16,
                 log=lambda *_: None)
    tr.run(5)
    tr.store.wait()
    code = f'''
import jax
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.train import Trainer
from repro.launch.mesh import auto_mesh
mesh = auto_mesh((2, 4), ("data", "model"))
cfg = registry.reduced_config("qwen1.5-0.5b").replace(vocab=96)
tcfg = TrainConfig(lr=1e-3, total_steps=100, checkpoint_every=50,
                   checkpoint_dir={ck!r})
tr = Trainer.from_checkpoint(cfg, tcfg, 8, 16, mesh=mesh,
                             log=lambda *_: None)
assert tr.start_step == 5, tr.start_step
m = tr.run(3)
assert m["loss"] > 0
print("ELASTIC_OK", m["loss"])
'''
    out = subproc(code, n_devices=8)
    assert "ELASTIC_OK" in out
