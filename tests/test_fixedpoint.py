"""Fixed-point substrate: bit-level invariants (unit + hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.fixedpoint import (EXP_FRAC, I32, IN_FRAC, IN_MAX, IN_MIN,
                                   T_FRAC, dequantize, floor_log2,
                                   mantissa_frac, quantize, sat_rshift)


def test_quantize_range_saturates():
    q = quantize(jnp.asarray([1e9, -1e9, 0.0]))
    assert int(q[0]) == IN_MAX and int(q[1]) == IN_MIN and int(q[2]) == 0


def test_quantize_dequantize_grid():
    # every representable S5.10 value roundtrips exactly
    grid = np.arange(IN_MIN, IN_MAX + 1, 7, dtype=np.int32)
    x = grid.astype(np.float32) / (1 << IN_FRAC)
    q = quantize(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(q), grid)
    np.testing.assert_allclose(np.asarray(dequantize(q)), x, atol=0)


@given(st.floats(-31.9, 31.9))
@settings(max_examples=200, deadline=None)
def test_quantize_error_bound(x):
    err = abs(float(dequantize(quantize(jnp.asarray(x)))) - x)
    assert err <= 0.5 / (1 << IN_FRAC) + 1e-7


@given(st.integers(1, 2**31 - 1))
@settings(max_examples=300, deadline=None)
def test_floor_log2_bitexact(v):
    assert int(floor_log2(jnp.asarray(v, jnp.int32))) == v.bit_length() - 1


@given(st.integers(1, 2**30))
@settings(max_examples=200, deadline=None)
def test_mantissa_frac_reconstructs(v):
    e = v.bit_length() - 1
    frac = int(mantissa_frac(jnp.asarray(v, jnp.int32),
                             jnp.asarray(e, jnp.int32)))
    # frac/2^T_FRAC ~ v/2^e - 1 within shift truncation
    approx = (1 + frac / (1 << T_FRAC)) * (1 << e)
    assert abs(approx - v) <= max(1.0, v / (1 << T_FRAC) * 2)


def test_sat_rshift_clamps():
    x = jnp.asarray([1 << 20], jnp.int32)
    assert int(sat_rshift(x, jnp.asarray([40]))[0]) == 0       # clamp at 31
    assert int(sat_rshift(x, jnp.asarray([-5]))[0]) == 1 << 20  # clamp at 0
