"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import gc
import os
import subprocess
import sys

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560):
    """Run `code` in a fresh python with n fake devices; return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices


@pytest.fixture(autouse=True, scope="module")
def _bound_xla_state():
    """A full single-process run accumulates hundreds of live XLA
    executables; past ~550 tests the CPU compiler segfaults on the next
    large compile. Dropping JAX caches at module boundaries keeps the
    process well under that tipping point (modules rarely share shapes,
    so cross-module cache hits were negligible anyway)."""
    yield
    jax.clear_caches()
    gc.collect()
