"""Paged KV cache: BlockPool allocator invariants (property-tested),
pool write/gather round-trips, and the block-table flash-decode kernel's
parity against the pure-JAX paged fold oracle and the dense paths.

The allocator property test is hypothesis-compatible: when the
`hypothesis` package is present the operation sequences are drawn by it;
otherwise a seeded PRNG drives the SAME property function (no dependency
is installed for this — the image decides)."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import dispatch, tiling
from repro.kernels.flash_decode import (flash_decode_paged,
                                        flash_decode_pallas)
from repro.models.attention import paged_gather, paged_write
from repro.models.flash import flash_attention_paged_ref
from repro.models.transformer import init_lm
from repro.serve import Request, ServeEngine
from repro.serve.paged_cache import BlockPool, chain_hashes

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------- allocator properties ----------------

def _pool_invariants(pool: BlockPool):
    live = set(pool._ref)
    free = set(pool._free)
    cached = set(pool._cached)
    # block 0 is the write sentinel: never allocatable, never live
    assert 0 not in live and 0 not in free and 0 not in cached
    # no block is simultaneously live/free/cached (no double-alloc)
    assert not (live & free) and not (live & cached) and not (free & cached)
    # no leak: every non-sentinel block is in exactly one of the sets
    assert live | free | cached == set(range(1, pool.num_blocks))
    assert all(r >= 1 for r in pool._ref.values())


def _run_ops(ops):
    """Interpret a sequence of (op, arg) against a small pool, checking
    invariants after every step.  Ops: alloc n / free i-th held ref /
    share (re-take refs on a registered prefix) / register held blocks."""
    pool = BlockPool(num_blocks=9, block_size=4)
    held = []                 # (block, token_prefix_hash) refs we own
    registered = []           # hash chains we registered
    next_tok = [0]
    for op, arg in ops:
        if op == "alloc":
            got = pool.alloc(arg)
            if got is not None:
                assert len(got) == arg
                assert len(set(got)) == arg          # no dup in one grant
                for b in got:
                    held.append(b)
            else:
                assert pool.available() < arg        # refusal was honest
        elif op == "free" and held:
            pool.decref(held.pop(arg % len(held)))
        elif op == "register" and held:
            toks = list(range(next_tok[0], next_tok[0] + 4))
            next_tok[0] += 4
            hs = chain_hashes(toks, 4)
            b = held[arg % len(held)]
            pool.register(hs, [b])
            registered.append((hs, b))
        elif op == "share" and registered:
            hs, b = registered[arg % len(registered)]
            got = pool.match_prefix(hs)
            for g in got:
                held.append(g)
        _pool_invariants(pool)
    # refcount round-trip: dropping every held ref empties the live set
    for b in held:
        pool.decref(b)
    _pool_invariants(pool)
    assert pool.in_use() == 0
    assert pool.available() == pool.num_blocks - 1


_OP_NAMES = ("alloc", "free", "register", "share")


def _random_ops(seed, n=60):
    rng = random.Random(seed)
    return [(rng.choice(_OP_NAMES), rng.randrange(6)) for _ in range(n)]


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(_OP_NAMES),
                              st.integers(0, 5)), max_size=80))
    def test_block_pool_invariants(ops):
        _run_ops(ops)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_block_pool_invariants(seed):
        _run_ops(_random_ops(seed))


def test_block_pool_alloc_all_or_nothing():
    pool = BlockPool(num_blocks=5, block_size=4)
    got = pool.alloc(4)
    assert got is not None and len(got) == 4
    assert pool.alloc(1) is None                 # empty: refuse
    assert pool.in_use() == 4                    # and nothing half-taken
    pool.decref(got[0])
    assert pool.alloc(2) is None                 # still short: refuse whole
    assert pool.alloc(1) == [got[0]]


def test_block_pool_prefix_revival_and_eviction():
    """Refcount-0 registered blocks stay matchable (LRU cache) until
    capacity pressure evicts them — then the hash is gone too."""
    pool = BlockPool(num_blocks=4, block_size=2)
    hs = chain_hashes([1, 2, 3, 4], 2)
    blocks = pool.alloc(2)
    pool.register(hs, blocks)
    for b in blocks:
        pool.decref(b)
    assert pool.in_use() == 0
    assert pool.match_prefix(hs) == blocks       # revived from the LRU
    for b in blocks:
        pool.decref(b)
    assert pool.alloc(3) is not None             # evicts both cached blocks
    assert pool.match_prefix(hs) == []           # index dropped on eviction


def test_chain_hashes_left_context_sensitivity():
    # same block tokens, different left context -> different hash
    a = chain_hashes([1, 2, 3, 4, 5, 6], 2)
    b = chain_hashes([9, 9, 3, 4, 5, 6], 2)
    assert a[0] != b[0] and a[1] != b[1] and a[2] != b[2]
    assert chain_hashes([1, 2, 3], 2) == a[:1]   # partial block: no hash


# ---------------- pool write / gather ----------------

def test_paged_write_gather_round_trip():
    key = jax.random.PRNGKey(0)
    bs, nblk, b = 8, 4, 3
    pool = jnp.zeros((1 + b * nblk, bs, 2, 4), jnp.float32)
    # shuffled physical layout: logical order != physical order
    tables = jnp.asarray(np.random.RandomState(0).permutation(
        np.arange(1, 1 + b * nblk)).reshape(b, nblk).astype(np.int32))
    new = jax.random.normal(key, (b, 13, 2, 4))
    pool = paged_write(pool, new, jnp.asarray([0, 3, 19]), tables)
    dense = paged_gather(pool, tables)
    for i, off in enumerate([0, 3, 19]):
        np.testing.assert_array_equal(np.asarray(dense[i, off:off + 13]),
                                      np.asarray(new[i]))
    # out-of-range rows (pos 19 + 13 == 32 == capacity) never touched
    # the sentinel guard: writing past the table clamps to block 0
    over = paged_write(pool, new, jnp.asarray([25, 25, 25]), tables)
    np.testing.assert_array_equal(np.asarray(paged_gather(over, tables)
                                             [:, :25]),
                                  np.asarray(dense[:, :25]))


# ---------------- kernel parity ----------------

def _mk_paged_case(seed, b, kh, g, hd, hv, nblk, bs, shuffle=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    n_pool = 1 + b * nblk
    q = jax.random.normal(ks[0], (b, 1, kh, g, hd))
    k_pool = jax.random.normal(ks[1], (n_pool, bs, kh, hd))
    v_pool = jax.random.normal(ks[2], (n_pool, bs, kh, hv))
    ids = np.arange(1, n_pool)
    if shuffle:
        ids = np.random.RandomState(seed).permutation(ids)
    tables = jnp.asarray(ids.reshape(b, nblk).astype(np.int32))
    t = nblk * bs
    q_pos = jax.random.randint(ks[3], (b, 1), 0, t)
    kv_valid = jnp.arange(t)[None, :] <= q_pos
    return q, k_pool, v_pool, tables, q_pos, kv_valid


@pytest.mark.parametrize("num_splits", [1, 2, 4])
@pytest.mark.parametrize("gqa", [(4, 1), (2, 3)])
def test_flash_decode_paged_matches_oracle_and_dense(num_splits, gqa):
    """The block-table kernel == the pure-JAX paged fold oracle == the
    dense split-KV kernel fed a gathered cache — with PHYSICALLY
    SHUFFLED tables, so any confusion of physical block id with logical
    position shows up as a mismatch."""
    kh, g = gqa
    q, k_pool, v_pool, tables, q_pos, kv_valid = _mk_paged_case(
        1, b=3, kh=kh, g=g, hd=16, hv=16, nblk=8, bs=16)
    got = flash_decode_paged(q, k_pool, v_pool, block_tables=tables,
                             q_pos=q_pos, kv_valid=kv_valid,
                             num_splits=num_splits, interpret=True)
    ref = flash_attention_paged_ref(q, k_pool, v_pool, block_tables=tables,
                                    q_pos=q_pos, kv_valid=kv_valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    dense = flash_decode_pallas(q, paged_gather(k_pool, tables),
                                paged_gather(v_pool, tables), q_pos=q_pos,
                                kv_valid=kv_valid, num_splits=num_splits,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               atol=1e-5)


def test_flash_decode_paged_mla_head_dims():
    # MLA decode shape: shared latent head, hv != hd
    q, k_pool, v_pool, tables, q_pos, kv_valid = _mk_paged_case(
        2, b=2, kh=1, g=4, hd=24, hv=16, nblk=4, bs=16)
    got = flash_decode_paged(q, k_pool, v_pool, block_tables=tables,
                             q_pos=q_pos, kv_valid=kv_valid, interpret=True)
    ref = flash_attention_paged_ref(q, k_pool, v_pool, block_tables=tables,
                                    q_pos=q_pos, kv_valid=kv_valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_flash_decode_paged_table_permutation_invariance():
    """Permuting PHYSICAL block placement (and the tables with it) must
    not change a single output word — masking is logical-position-only."""
    q, k_pool, v_pool, tables, q_pos, kv_valid = _mk_paged_case(
        3, b=2, kh=2, g=2, hd=16, hv=16, nblk=4, bs=16, shuffle=False)
    base = flash_decode_paged(q, k_pool, v_pool, block_tables=tables,
                              q_pos=q_pos, kv_valid=kv_valid,
                              interpret=True)
    perm = np.random.RandomState(7).permutation(k_pool.shape[0] - 1) + 1
    inv = np.zeros(k_pool.shape[0], np.int32)
    inv[perm] = np.arange(1, k_pool.shape[0])
    k2 = jnp.concatenate([k_pool[:1], k_pool[perm]], 0)
    v2 = jnp.concatenate([v_pool[:1], v_pool[perm]], 0)
    t2 = jnp.asarray(inv)[tables]
    moved = flash_decode_paged(q, k2, v2, block_tables=t2, q_pos=q_pos,
                               kv_valid=kv_valid, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(moved))


def test_paged_registry_entry():
    fn = dispatch.get_paged_attention("flash_decode")
    assert fn is not None
    assert dispatch.get_paged_attention("naive") is None
    # dualmode on the paged entry runs the snapped int split path (ISSUE 7)
    # and matches the dense dual-mode decode on the gathered cache exactly:
    # same words, same split fold, block tables only change the addressing
    q, k_pool, v_pool, tables, q_pos, kv_valid = _mk_paged_case(
        4, b=1, kh=2, g=2, hd=16, hv=16, nblk=2, bs=16)
    got = fn(q, k_pool, v_pool, block_tables=tables, q_pos=q_pos,
             kv_valid=kv_valid, causal=True, scale=None,
             softmax_impl="dualmode")
    dense = flash_decode_pallas(q, paged_gather(k_pool, tables),
                                paged_gather(v_pool, tables), q_pos=q_pos,
                                kv_valid=kv_valid, interpret=True,
                                softmax_impl="dualmode")
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               atol=1e-6)


# ---------------- engine fast path (paged) ----------------

def test_paged_engine_decode_routes_through_kernel():
    """A long-cache PAGED engine resolves flash_decode and its compiled
    decode step contains the pallas_call — the block-table gather is the
    kernel's scalar-prefetch index map, not a dense materialization."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=2,
                      max_seq=tiling.DECODE_FLASH_MIN_KV,
                      cache_mode="paged")
    assert eng.decode_attn_impl == "flash_decode"
    toks = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray([5, 9], jnp.int32)
    tables = jnp.zeros((2, eng.max_blocks), jnp.int32)
    from repro.serve.engine import make_paged_decode_step
    jaxpr = str(jax.make_jaxpr(make_paged_decode_step(
        cfg.replace(attn_impl="flash_decode")))(
        params, eng.caches, toks, pos, tables))
    assert "pallas_call" in jaxpr
    # ...and a gather of the full pool into a dense (B,T,...) cache is
    # exactly what the kernel avoids: no reshape to the dense kv shape
    out = eng.run([Request(rid=0, prompt=[1, 2, 3], max_new=3),
                   Request(rid=1, prompt=[4, 5], max_new=3)])
    ref = ServeEngine(cfg, params, n_slots=2,
                      max_seq=tiling.DECODE_FLASH_MIN_KV,
                      cache_mode="contiguous", prefill_buckets=(8,)).run(
        [Request(rid=0, prompt=[1, 2, 3], max_new=3),
         Request(rid=1, prompt=[4, 5], max_new=3)])
    assert out == ref
