"""The shared datapath library (ISSUE 1 tentpole).

Covers: (a) the single-definition acceptance criterion — the log2e /
GELU-cubic ROM constants exist in exactly one float (kernels/datapath.py)
and one int (core/softmax_unit.py) home in src/; (b) bit-identical parity
of the refactored kernel bodies with the pre-refactor arithmetic (spelled
out literally here, frozen at the pre-refactor state); (c) the streamed
online-softmax step telescoping back to the row softmax; (d) the unified
mask constant."""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from jax.experimental import pallas as pl

from repro.kernels import datapath as dp
from repro.kernels import tiling
from repro.kernels.dualmode_softmax import pair_act_pallas, softmax_pallas
from repro.kernels.fused_ffn import fused_glu_pallas

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")
ALLOWED = {os.path.join("repro", "kernels", "datapath.py"),
           os.path.join("repro", "core", "softmax_unit.py")}

RNG = np.random.default_rng(11)


# ---------------- (a) single-definition criterion ----------------

@pytest.mark.parametrize("rom_word", ["1.4426950408889634", "0.044715"])
def test_datapath_constants_have_one_definition(rom_word):
    offenders = []
    for root, _, files in os.walk(SRC):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(root, fn)
            rel = os.path.relpath(path, SRC)
            if rel in ALLOWED:
                continue
            with open(path) as fh:
                if rom_word in fh.read():
                    offenders.append(rel)
    assert not offenders, (
        f"ROM constant {rom_word} duplicated outside the datapath: "
        f"{offenders}")


def test_no_stray_mask_literals_in_models():
    """The -30.0 / -1e30 mask split is gone: models use dp.MASK_VALUE."""
    models = os.path.join(SRC, "repro", "models")
    pat = re.compile(r"-\s*(30\.0|1e30)\b")
    offenders = []
    for root, _, files in os.walk(models):
        for fn in files:
            if fn.endswith(".py"):
                with open(os.path.join(root, fn)) as fh:
                    if pat.search(fh.read()):
                        offenders.append(fn)
    assert not offenders, offenders


# ---------------- (b) pre-refactor bit parity ----------------
# The frozen seed-commit bodies, run through pallas_call with the same
# block shapes as the refactored kernels, must produce the same BITS —
# the refactor moved the arithmetic, it did not change it.  (The int path
# is covered bit-exactly against repro.core.softmax_unit in
# tests/test_kernels.py.)

def _pre_refactor_float_softmax_body(x_ref, o_ref):
    """kernels/dualmode_softmax.py float body as of the seed commit."""
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    t = (x - m) * 1.4426950408889634
    e = jnp.exp2(t)
    s = jnp.sum(e, axis=-1, keepdims=True)
    w = t - jnp.log2(s)
    o_ref[...] = jnp.exp2(w).astype(o_ref.dtype)


def _pre_refactor_epilogue(g, mode):
    """kernels/fused_ffn.py / dualmode_softmax.py epilogue as of the seed."""
    if mode == "gelu":
        k = 0.7978845608028654 * (g + 0.044715 * g * g * g)
    else:
        k = 0.5 * g
    amax = jnp.abs(k)
    l2e = 1.4426950408889634
    t1 = (k - amax) * l2e
    t2 = (-k - amax) * l2e
    sig = jnp.exp2(t1 - jnp.log2(jnp.exp2(t1) + jnp.exp2(t2)))
    return g * sig


def _whole_array_call(body, x):
    return pl.pallas_call(
        body, grid=(1,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec(x.shape, lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True)(x)


def test_float_softmax_body_bit_identical_to_pre_refactor():
    x = jnp.asarray(RNG.normal(size=(16, 256)) * 4, jnp.float32)
    got = softmax_pallas(x, precision="float", interpret=True)
    want = _whole_array_call(_pre_refactor_float_softmax_body, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode", ["gelu", "silu"])
def test_pair_act_body_bit_identical_to_pre_refactor(mode):
    z = jnp.asarray(RNG.normal(size=(16, 256)) * 3, jnp.float32)
    got = pair_act_pallas(z, mode=mode, precision="float", interpret=True)

    def body(z_ref, o_ref):     # seed-commit _pair_act_body, float branch
        zz = z_ref[...].astype(jnp.float32)
        if mode == "gelu":
            k = 0.7978845608028654 * (zz + 0.044715 * zz * zz * zz)
        else:
            k = 0.5 * zz
        amax = jnp.abs(k)
        l2e = 1.4426950408889634
        t1 = (k - amax) * l2e
        t2 = (-k - amax) * l2e
        s = jnp.exp2(t1) + jnp.exp2(t2)
        sig = jnp.exp2(t1 - jnp.log2(s))
        o_ref[...] = (zz * sig).astype(o_ref.dtype)

    want = _whole_array_call(body, z)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode", ["gelu", "silu"])
def test_fused_ffn_epilogue_bit_identical_to_pre_refactor(mode):
    x = jnp.asarray(RNG.normal(size=(32, 64)) * 0.5, jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(64, 128)) * 0.1, jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(64, 128)) * 0.1, jnp.float32)
    got = fused_glu_pallas(x, wg, wu, mode=mode, interpret=True,
                           bm=32, bf=128)

    def body(x_ref, wg_ref, wu_ref, o_ref):   # seed-commit _ffn_body
        xx = x_ref[...]
        g = jnp.dot(xx, wg_ref[...], preferred_element_type=jnp.float32)
        u = jnp.dot(xx, wu_ref[...], preferred_element_type=jnp.float32)
        o_ref[...] = (_pre_refactor_epilogue(g, mode) * u).astype(o_ref.dtype)

    want = pl.pallas_call(
        body, grid=(1, 1),
        in_specs=[pl.BlockSpec((32, 64), lambda i, j: (0, 0)),
                  pl.BlockSpec((64, 128), lambda i, j: (0, 0)),
                  pl.BlockSpec((64, 128), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((32, 128), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 128), x.dtype),
        interpret=True)(x, wg, wu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------- (c) online softmax telescopes to Eq. 10 ----------------

@pytest.mark.parametrize("block", [4, 16, 64])
def test_online_update_telescopes_to_row_softmax(block):
    s = jnp.asarray(RNG.normal(size=(8, 64)) * 4, jnp.float32)
    m = jnp.full((8, 1), dp.MASK_VALUE, jnp.float32)
    l = jnp.zeros((8, 1), jnp.float32)
    ps = []
    for i in range(0, 64, block):
        m, l, p, corr = dp.online_softmax_update(m, l, s[:, i:i + block])
        ps = [q * corr for q in ps] + [p]
    probs = jnp.concatenate(ps, axis=-1) / l
    np.testing.assert_allclose(np.asarray(probs),
                               np.asarray(dp.row_softmax(s)), atol=1e-6)


def test_pair_sigmoid_is_sigmoid_of_2k():
    k = jnp.linspace(-10, 10, 513)
    import jax
    np.testing.assert_allclose(np.asarray(dp.pair_sigmoid(k)),
                               np.asarray(jax.nn.sigmoid(2.0 * k)),
                               atol=1e-6)


# ---------------- (d) mask + tiling policy ----------------

def test_mask_value_is_s510_saturation_regime():
    """-30 sits inside the S5.10 saturation band: exp already underflows."""
    assert dp.MASK_VALUE == -30.0
    from repro.core.fixedpoint import quantize
    assert int(quantize(jnp.asarray(dp.MASK_VALUE))) == -30 * 1024


@pytest.mark.parametrize("n,mult,want", [(37, 128, 128), (128, 128, 128),
                                         (129, 128, 256)])
def test_tiling_pad_unpad_roundtrip(n, mult, want):
    x = jnp.asarray(RNG.normal(size=(3, n)), jnp.float32)
    xp, _ = tiling.pad_dim(x, 1, mult)
    assert xp.shape == (3, want)
    np.testing.assert_array_equal(np.asarray(tiling.unpad(xp, 1, n)),
                                  np.asarray(x))


def test_tiling_blocks_never_degenerate():
    """Odd/prime shapes keep lane-aligned blocks (the old divisor search
    collapsed to 1-wide)."""
    bm, bn = tiling.tile2d(997, 131)
    assert bm % tiling.SUBLANE == 0 and bn % tiling.LANE == 0
    assert tiling.row_block(7, 100) % tiling.SUBLANE == 0
    bm, bf = tiling.matmul_blocks(48, 72)
    assert bm % tiling.SUBLANE == 0 and bf % tiling.LANE == 0


# ---------------- (e) online_softmax_merge: the ring monoid ----------------
# The algebraic fact sequence-parallel ring attention relies on: partial
# (m, l, acc) states form a commutative monoid under the merge, with the
# empty-shard sentinel (MASK_VALUE, 0, 0) — the float twin of the int
# path's PHANTOM_Q — as identity, and the fold is invariant to HOW the
# key set was split (kernels/ring_attention.py is this fold across
# devices; models/flash.flash_attention_merged is it on one host).

def _partials(seed: int, n_chunks: int, chunk: int, d: int = 4,
              spread: float = 4.0):
    """n_chunks independent (m, l, acc) partial states of one 2-row set."""
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.normal(size=(2, n_chunks * chunk)) * spread,
                    jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, n_chunks * chunk, d)), jnp.float32)
    parts = [dp.online_softmax_partial(s[:, i * chunk:(i + 1) * chunk],
                                       v[:, i * chunk:(i + 1) * chunk])
             for i in range(n_chunks)]
    return s, v, parts


def _finish(part):
    return np.asarray(dp.online_softmax_finish(part[1], part[2]))


@given(st.integers(0, 6), st.integers(1, 8), st.floats(0.5, 8.0))
@settings(max_examples=24, deadline=None)
def test_merge_is_associative(seed, chunk, spread):
    _, _, (a, b, c) = _partials(seed, 3, chunk, spread=spread)
    left = dp.online_softmax_merge(dp.online_softmax_merge(a, b), c)
    right = dp.online_softmax_merge(a, dp.online_softmax_merge(b, c))
    np.testing.assert_allclose(_finish(left), _finish(right), atol=1e-6)
    np.testing.assert_allclose(np.asarray(left[0]), np.asarray(right[0]))


@given(st.integers(0, 6), st.integers(1, 8))
@settings(max_examples=24, deadline=None)
def test_merge_is_commutative_bitwise(seed, chunk):
    """max and IEEE addition are symmetric, so a<->b is EXACT, not just
    close — the ring may merge hops in any arrival order."""
    _, _, (a, b) = _partials(seed, 2, chunk)
    ab = dp.online_softmax_merge(a, b)
    ba = dp.online_softmax_merge(b, a)
    for x, y in zip(ab, ba):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(st.integers(0, 6), st.integers(1, 8), st.booleans())
@settings(max_examples=24, deadline=None)
def test_merge_identity_is_empty_shard_sentinel(seed, chunk, left_side):
    """(MASK_VALUE, 0, 0) — what a fully-phantom shard produces — merges
    as a bit-exact no-op: every streamed path starts its running max at
    MASK_VALUE, so real partials never carry a smaller max."""
    _, _, (a,) = _partials(seed, 1, chunk)
    ident = (jnp.full_like(a[0], dp.MASK_VALUE), jnp.zeros_like(a[1]),
             jnp.zeros_like(a[2]))
    got = (dp.online_softmax_merge(ident, a) if left_side
           else dp.online_softmax_merge(a, ident))
    for x, y in zip(got, a):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_all_phantom_block_produces_identity():
    """online_softmax_partial of an all--inf (tiling-phantom) block IS the
    sentinel — no NaNs from exp2(-inf + inf)."""
    s = jnp.full((2, 8), -jnp.inf, jnp.float32)
    v = jnp.ones((2, 8, 4), jnp.float32)
    m, l, acc = dp.online_softmax_partial(s, v)
    assert float(m.min()) == dp.MASK_VALUE
    np.testing.assert_array_equal(np.asarray(l), 0.0)
    np.testing.assert_array_equal(np.asarray(acc), 0.0)


@given(st.integers(0, 6), st.sampled_from([1, 2, 3, 4, 6, 8, 12, 24]))
@settings(max_examples=24, deadline=None)
def test_merge_invariant_to_kv_split_points(seed, n_chunks):
    """Folding ANY split of the key set reproduces the whole-row softmax
    combine — the exact invariance ring attention needs when the shard
    count (mesh size) changes."""
    chunk = 24 // n_chunks
    s, v, parts = _partials(seed, n_chunks, chunk)
    acc = parts[0]
    for p in parts[1:]:
        acc = dp.online_softmax_merge(acc, p)
    want = jnp.einsum("rn,rnd->rd", dp.row_softmax(s), v)
    np.testing.assert_allclose(_finish(acc), np.asarray(want), atol=1e-6)


@given(st.integers(0, 6), st.sampled_from([1, 2, 3, 4, 6, 8]))
@settings(max_examples=24, deadline=None)
def test_merge_n_matches_pairwise_fold(seed, n_chunks):
    """The vectorized n-way fold (the split-KV decode combine) computes
    the same finished output as folding the partials pairwise with
    online_softmax_merge — same monoid, one max + one rescaled sum."""
    chunk = 24 // n_chunks
    s, v, parts = _partials(seed, n_chunks, chunk)
    pair = parts[0]
    for p in parts[1:]:
        pair = dp.online_softmax_merge(pair, p)
    m = jnp.stack([p[0] for p in parts], 0)
    l = jnp.stack([p[1] for p in parts], 0)
    acc = jnp.stack([p[2] for p in parts], 0)
    m_n, l_n, acc_n = dp.online_softmax_merge_n(m, l, acc, axis=0)
    # the max is order-independent: exact
    np.testing.assert_array_equal(np.asarray(m_n[0]), np.asarray(pair[0]))
    np.testing.assert_allclose(_finish((m_n[0], l_n[0], acc_n[0])),
                               _finish(pair), atol=1e-6)
    np.testing.assert_allclose(_finish((m_n[0], l_n[0], acc_n[0])),
                               np.asarray(jnp.einsum(
                                   "rn,rnd->rd", dp.row_softmax(s), v)),
                               atol=1e-6)


@given(st.integers(0, 6), st.integers(1, 4))
@settings(max_examples=24, deadline=None)
def test_merge_n_sentinel_splits_are_bit_exact_noops(seed, n_sentinels):
    """Empty splits (every key skipped/phantom) contribute exact IEEE
    zeros to the n-way fold — padding the split axis with sentinels
    changes no bits, which is why the decode kernel may run more splits
    than the cache has tiles."""
    _, _, parts = _partials(seed, 2, 8)
    m = jnp.stack([p[0] for p in parts], 0)
    l = jnp.stack([p[1] for p in parts], 0)
    acc = jnp.stack([p[2] for p in parts], 0)
    want = dp.online_softmax_merge_n(m, l, acc, axis=0)
    sent_m = jnp.full((n_sentinels,) + parts[0][0].shape, dp.MASK_VALUE)
    pad = lambda x, s: jnp.concatenate([x, s], 0)
    got = dp.online_softmax_merge_n(
        pad(m, sent_m), pad(l, jnp.zeros_like(sent_m)),
        pad(acc, jnp.zeros((n_sentinels,) + parts[0][2].shape)), axis=0)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fit_block_minimizes_padding():
    """Block choice never inflates padding beyond hardware alignment:
    513 cols pad to 640 with 128-wide blocks, not to 1024 with a blind
    512 block."""
    assert tiling.fit_block(513, 128, 512) == 128       # 640 = 5*128
    assert tiling.fit_block(1024, 128, 512) == 512      # exact
    assert tiling.fit_block(1408, 128, 512) == 128      # 11*128, 11 prime
    assert tiling.fit_block(16, 8, 128) == 16
    assert tiling.fit_block(7, 8, 4096) == 8
    for n in (1, 37, 127, 128, 129, 513, 640, 1000):
        b = tiling.fit_block(n, 128, 512)
        assert tiling.round_up(n, 128) % b == 0
        assert b % 128 == 0 and b <= 512
