"""Bit-accurate int flash attention (ISSUE 2 tentpole, ISSUE 7 snapping).

Two int kernels, two oracles, tested separately:

  1. WORDS, three-sweep — the blocked three-sweep recurrence
     (``flash_pallas_int3``) telescopes to the EXACT whole-row
     ``softmax_int`` words for any blocking, and the Pallas kernel
     carries those words end-to-end (proved with an identity-matrix v,
     which turns the output into the raw probability words: no float
     accumulation).
  2. WORDS, one-sweep — the snapped-max online kernel
     (``flash_pallas_int``) carries the whole-row ``softmax_snap`` words:
     snapping the running max to a power of two makes every rescale an
     exact shift, so ONE kv sweep suffices and the same identity-v probe
     pins it bitwise against the naive 'dualmode_snap' reference.
  3. OUTPUTS — with a real v the only remaining difference vs the
     matching naive reference is f32 numerator@v reduction order
     (blocked vs whole-row), bounded at ~1e-7 of the row mass; snapped
     vs CLASSIC unsnapped words differ by <~1e-3 (the max-quantization
     step the Table-2 bench quantifies).

Plus the dispatch guarantee: softmax_impl='dualmode' can no longer be
silently dropped by ANY attention impl resolution.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import softmax_unit as unit
from repro.core.fixedpoint import quantize
from repro.kernels import dispatch
from repro.kernels.flash_attention_int import (
    flash_attention_pallas_int, flash_attention_pallas_int3)
from repro.models.attention import _naive_sdpa, _sdpa

RNG = np.random.default_rng(11)


def _mk(b, s, t, k, g, h, hv=None, scale=1.0):
    hv = hv or h
    q = jnp.asarray(RNG.normal(size=(b, s, k, g, h)) * scale, jnp.float32)
    kk = jnp.asarray(RNG.normal(size=(b, t, k, h)) * scale, jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, t, k, hv)), jnp.float32)
    return q, kk, v


# ---------------- the telescoping proof (pure int words) ----------------

@pytest.mark.parametrize("n,block", [(8, 8), (33, 8), (100, 16), (7, 3),
                                     (1000, 128), (513, 512)])
def test_blocked_int_recurrence_telescopes_bitexact(n, block):
    """Any blocking of the three-sweep recurrence == whole-row words,
    including non-divisible tails and rows long enough to engage the
    guard shift path bound."""
    x = quantize(jnp.asarray(RNG.normal(size=(16, n)) * 5, jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(unit.softmax_int_blocked(x, block)),
        np.asarray(unit.softmax_int(x)))


def test_blocked_int_guard_shift_long_row():
    """Rows past 2**16 elements force guard_shift > 0 in the whole-row
    unit; the blocked carry must use the identical guard so the int32
    accumulator never overflows and words stay pinned."""
    n = (1 << 16) + 17                      # bit_length 17 -> guard 1
    x = quantize(jnp.asarray(RNG.normal(size=(2, n)) * 3, jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(unit.softmax_int_blocked(x, 1 << 12)),
        np.asarray(unit.softmax_int(x)))


def test_phantom_word_carries_exactly_zero_mass():
    """The PHANTOM_Q sentinel must be invisible: appending phantoms to a
    row changes neither the max, the sum carry, nor any prob word."""
    x = quantize(jnp.asarray(RNG.normal(size=(4, 37)) * 5, jnp.float32))
    xp = jnp.concatenate(
        [x, jnp.full((4, 27), unit.PHANTOM_Q, jnp.int32)], axis=-1)
    # guard from the REAL row length, like the kernel computes it
    g = max(0, 37 .bit_length() - 16)
    got = unit.softmax_int_blocked(xp, 16, guard_shift=g)[:, :37]
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(unit.softmax_int(x, guard_shift=g)))
    assert int(jnp.abs(
        unit.softmax_int_blocked(xp, 16, guard_shift=g)[:, 37:]).max()) == 0


# ---------------- the Pallas kernel vs the naive dual-mode oracle -------

def _ids(b, t, k):
    """v = per-head identity: attention output IS the dequantized
    probability words (each output element one p*1.0 product, every other
    term an exact float zero) — a bitwise probe through the kernel."""
    eye = jnp.eye(t, dtype=jnp.float32)
    return jnp.broadcast_to(eye[None, :, None, :], (b, t, k, t))


@pytest.mark.parametrize("causal", [True, False])
def test_int3_kernel_prob_words_bit_identical_to_naive_dualmode(causal):
    b, s, t, k, g, h = 2, 24, 40, 2, 2, 8
    q, kk, _ = _mk(b, s, t, k, g, h)
    v = _ids(b, t, k)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (b, s))
    kv_valid = jnp.asarray(RNG.random((b, t)) > 0.25)
    want = _naive_sdpa(q, kk, v, q_pos=q_pos, kv_valid=kv_valid,
                       causal=causal, softmax_impl="dualmode")
    # small explicit blocks force REAL streaming (3 sweeps x 3 kv tiles);
    # identity-v keeps the cross-block accumulation exact (all-zero terms)
    got = flash_attention_pallas_int3(q, kk, v, q_pos=q_pos,
                                      kv_valid=kv_valid, causal=causal,
                                      block_q=8, block_kv=16,
                                      interpret=True)
    # SAME int32/S5.10-pipeline words: exact equality, not allclose
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("causal", [True, False])
def test_onesweep_prob_words_bit_identical_to_naive_dualmode_snap(causal):
    """The ISSUE-7 word contract: ONE kv sweep, snapped recurrence, and
    the output words equal the whole-row snapped unit's bitwise — the
    identity-v probe makes every output element a single p*2^-d*1.0
    product, so any word drift in (p, d, l) would surface exactly."""
    b, s, t, k, g, h = 2, 24, 40, 2, 2, 8
    q, kk, _ = _mk(b, s, t, k, g, h)
    v = _ids(b, t, k)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (b, s))
    kv_valid = jnp.asarray(RNG.random((b, t)) > 0.25)
    want = _naive_sdpa(q, kk, v, q_pos=q_pos, kv_valid=kv_valid,
                       causal=causal, softmax_impl="dualmode_snap")
    got = flash_attention_pallas_int(q, kk, v, q_pos=q_pos,
                                     kv_valid=kv_valid, causal=causal,
                                     block_q=8, block_kv=16,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_onesweep_matches_threesweep_and_wholerow_words():
    """Acceptance: one-sweep snapped == whole-row snapped (bitwise via
    identity-v above) and tracks the three-sweep oracle within the
    snapped-vs-classic max-quantization bound."""
    b, s, t, k, g, h = 1, 16, 48, 2, 2, 8
    q, kk, v = _mk(b, s, t, k, g, h)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (b, s))
    kv_valid = jnp.ones((b, t), bool)
    one = flash_attention_pallas_int(q, kk, v, q_pos=q_pos,
                                     kv_valid=kv_valid, causal=True,
                                     interpret=True)
    three = flash_attention_pallas_int3(q, kk, v, q_pos=q_pos,
                                        kv_valid=kv_valid, causal=True,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(one), np.asarray(three),
                               atol=2e-3)


@pytest.mark.parametrize("shape", [
    (2, 64, 128, 2, 3, 16, None),    # GQA: G=3 query groups per KV head
    (2, 32, 32, 4, 1, 24, 12),       # MLA-style: v head dim != qk head dim
    (1, 17, 33, 2, 2, 8, None),      # non-divisible S/T (tiling pad path)
    (1, 5, 100, 1, 2, 8, None),
])
def test_kernel_output_matches_naive_dualmode(shape):
    b, s, t, k, g, h, hv = shape
    q, kk, v = _mk(b, s, t, k, g, h, hv)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (b, s))
    kv_valid = jnp.asarray(RNG.random((b, t)) > 0.3)
    kv_valid = kv_valid.at[:, 0].set(True)
    want = _naive_sdpa(q, kk, v, q_pos=q_pos, kv_valid=kv_valid,
                       causal=True, softmax_impl="dualmode")
    got = flash_attention_pallas_int3(q, kk, v, q_pos=q_pos,
                                      kv_valid=kv_valid, causal=True,
                                      block_q=8, block_kv=16,
                                      interpret=True)
    assert got.shape == want.shape
    # identical prob words; only f32 prob@v reduction order may differ
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)
    # one-sweep: same contract vs ITS whole-row reference
    want_s = _naive_sdpa(q, kk, v, q_pos=q_pos, kv_valid=kv_valid,
                         causal=True, softmax_impl="dualmode_snap")
    got_s = flash_attention_pallas_int(q, kk, v, q_pos=q_pos,
                                       kv_valid=kv_valid, causal=True,
                                       block_q=8, block_kv=16,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-6)


def test_kernel_all_rows_saturated_matches_naive():
    """Every real score below the S5.10 floor: the quantizer clips them
    all to the same word (uniform row) — phantoms must still carry zero
    mass rather than joining the uniform mass."""
    b, s, t, k, g, h = 1, 8, 100, 1, 1, 16
    q = jnp.full((b, s, k, g, h), 3.0, jnp.float32)
    kk = jnp.full((b, t, k, h), -3.0, jnp.float32)    # scores << -32
    v = jnp.asarray(RNG.normal(size=(b, t, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (b, s))
    kv_valid = jnp.ones((b, t), bool)
    want = _naive_sdpa(q, kk, v, q_pos=q_pos, kv_valid=kv_valid,
                       causal=False, softmax_impl="dualmode")
    got = flash_attention_pallas_int3(q, kk, v, q_pos=q_pos,
                                      kv_valid=kv_valid, causal=False,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)
    want_s = _naive_sdpa(q, kk, v, q_pos=q_pos, kv_valid=kv_valid,
                         causal=False, softmax_impl="dualmode_snap")
    got_s = flash_attention_pallas_int(q, kk, v, q_pos=q_pos,
                                       kv_valid=kv_valid, causal=False,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-6)


def test_sdpa_routes_dualmode_to_int_kernel():
    q, kk, v = _mk(1, 48, 48, 2, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(48)[None], (1, 48))
    kv_valid = jnp.ones((1, 48), bool)
    got = _sdpa(q, kk, v, q_pos=q_pos, kv_valid=kv_valid,
                softmax_impl="dualmode", attn_impl="flash_pallas_int")
    want = _sdpa(q, kk, v, q_pos=q_pos, kv_valid=kv_valid,
                 softmax_impl="dualmode", attn_impl="naive")
    # snapped kernel vs the CLASSIC whole-row unit: within the
    # max-quantization bound (p word error of one snapped octave frac)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3)
    got3 = _sdpa(q, kk, v, q_pos=q_pos, kv_valid=kv_valid,
                 softmax_impl="dualmode", attn_impl="flash_pallas_int3")
    np.testing.assert_allclose(np.asarray(got3), np.asarray(want),
                               atol=1e-6)


# ---------------- dispatch: dualmode can never be dropped ---------------

def test_registry_has_int_impl():
    assert callable(dispatch.get_attention("flash_pallas_int"))
    assert callable(dispatch.get_attention("flash_pallas_int3"))
    assert callable(dispatch.get_softmax("dualmode_snap"))


def test_resolve_auto_dualmode_routes_to_int_paths():
    # short rows: whole-row unit on the naive path
    assert dispatch.resolve_attention(
        "auto", 64, 64, softmax_impl="dualmode") == "naive"
    # blocked shapes: the int kernel, NEVER the float blocked paths
    assert dispatch.resolve_attention(
        "auto", 4096, 4096, softmax_impl="dualmode") == "flash_pallas_int"
    # float softmax keeps the float auto rule untouched
    assert dispatch.resolve_attention("auto", 4096, 4096) == "flash"


@pytest.mark.parametrize("impl", ["flash", "flash_pallas"])
def test_explicit_float_blocked_plus_dualmode_raises(impl):
    with pytest.raises(ValueError, match="dualmode"):
        dispatch.resolve_attention(impl, 4096, 4096,
                                   softmax_impl="dualmode")


def test_int_impl_requires_dualmode():
    with pytest.raises(ValueError, match="dualmode"):
        dispatch.resolve_attention("flash_pallas_int", 64, 64,
                                   softmax_impl="float")
    with pytest.raises(ValueError):
        flash = dispatch.get_attention("flash_pallas_int")
        q, kk, v = _mk(1, 8, 8, 1, 1, 8)
        flash(q, kk, v, q_pos=jnp.zeros((1, 8), jnp.int32),
              kv_valid=jnp.ones((1, 8), bool), causal=True, scale=None,
              softmax_impl="float")


@pytest.mark.parametrize("impl", ["flash", "flash_pallas"])
def test_float_blocked_entries_refuse_dualmode_directly(impl):
    """Even bypassing resolve_attention, the registered float entries
    refuse to silently run fp32 in place of the unit."""
    q, kk, v = _mk(1, 8, 8, 1, 1, 8)
    with pytest.raises(ValueError, match="dualmode"):
        dispatch.get_attention(impl)(
            q, kk, v, q_pos=jnp.zeros((1, 8), jnp.int32),
            kv_valid=jnp.ones((1, 8), bool), causal=True, scale=None,
            softmax_impl="dualmode")


def test_naive_plus_dualmode_still_resolves():
    assert dispatch.resolve_attention(
        "naive", 4096, 4096, softmax_impl="dualmode") == "naive"


def test_model_end_to_end_int_kernel_matches_naive_dualmode():
    """configs -> transformer -> dispatch -> int kernels, full vertical
    slice: a dualmode LM forward through either blocked int kernel must
    match the same model on the naive whole-row unit (the three-sweep
    oracle word-exactly; the snapped one-sweep within the
    max-quantization bound)."""
    import jax
    from repro.configs import registry
    from repro.models.transformer import init_lm, lm_apply

    cfg = registry.reduced_config("qwen1.5-0.5b").replace(
        softmax_impl="dualmode", attn_impl="flash_pallas_int3")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)
    logits, _, _ = lm_apply(params, cfg, toks, pos=0)
    ref_cfg = cfg.replace(attn_impl="naive")
    want, _, _ = lm_apply(params, ref_cfg, toks, pos=0)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               atol=1e-5)
    snap_cfg = cfg.replace(attn_impl="flash_pallas_int")
    logits_s, _, _ = lm_apply(params, snap_cfg, toks, pos=0)
    np.testing.assert_allclose(np.asarray(logits_s), np.asarray(want),
                               atol=5e-3)
