"""MoE dispatch: sort-path vs dense oracle, capacity semantics, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoESpec, moe_apply, moe_init


def _spec(**kw):
    base = dict(d_model=32, d_ff=64, n_experts=4, top_k=2, n_shared=0,
                capacity_factor=1.25, activation="silu", dispatch="sort")
    base.update(kw)
    return MoESpec(**base)


def _x(b=2, s=8, d=32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, s, d)) * 0.5


def test_sort_dropless_matches_dense():
    s_sort = _spec()
    s_dense = _spec(dispatch="dense")
    p = moe_init(jax.random.PRNGKey(1), s_sort, jnp.float32)
    x = _x()
    y_sort, aux1 = moe_apply(p, s_sort, x, dropless=True)
    y_dense, aux2 = moe_apply(p, s_dense, x)
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_dense),
                               atol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), atol=1e-6)


def test_capacity_drops_tokens_when_tight():
    s_tight = _spec(capacity_factor=0.25)
    p = moe_init(jax.random.PRNGKey(1), s_tight, jnp.float32)
    x = _x()
    y_tight, _ = moe_apply(p, s_tight, x)
    y_free, _ = moe_apply(p, s_tight, x, dropless=True)
    # with tight capacity SOME token outputs must differ (drops)
    assert float(jnp.abs(y_tight - y_free).max()) > 1e-6


def test_shared_experts_added():
    s = _spec(n_shared=1)
    p = moe_init(jax.random.PRNGKey(2), s, jnp.float32)
    x = _x()
    y, _ = moe_apply(p, s, x, dropless=True)
    # zeroing shared expert changes output
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    y2, _ = moe_apply(p2, s, x, dropless=True)
    assert float(jnp.abs(y - y2).max()) > 1e-6


def test_aux_loss_uniform_router_is_one():
    """Balanced routing gives aux ~ 1 (Switch normalization)."""
    s = _spec(n_experts=8, top_k=2)
    p = moe_init(jax.random.PRNGKey(3), s, jnp.float32)
    p["router"] = jnp.zeros_like(p["router"])      # uniform probs
    x = _x(b=8, s=32)
    _, aux = moe_apply(p, s, x, dropless=True)
    assert abs(float(aux) - 1.0) < 0.2


def test_custom_vjp_matches_dense_oracle_grads():
    """The dispatch/combine custom VJPs (built to keep GSPMD-friendly
    scatter forms in backward) must match autodiff of the dense path."""
    s_sort = _spec()
    s_dense = _spec(dispatch="dense")
    p = moe_init(jax.random.PRNGKey(7), s_sort, jnp.float32)
    x = _x(seed=9)
    tgt = jax.random.normal(jax.random.PRNGKey(8), x.shape)

    def loss(p_, spec):
        y, aux = moe_apply(p_, spec, x, dropless=True)
        return jnp.sum((y - tgt) ** 2) + 0.1 * aux

    g_sort = jax.grad(loss)(p, s_sort)
    g_dense = jax.grad(loss)(p, s_dense)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4),
        g_sort, g_dense)

    gx_sort = jax.grad(lambda x_: jnp.sum(
        moe_apply(p, s_sort, x_, dropless=True)[0] ** 2))(x)
    gx_dense = jax.grad(lambda x_: jnp.sum(
        moe_apply(p, s_dense, x_)[0] ** 2))(x)
    np.testing.assert_allclose(np.asarray(gx_sort), np.asarray(gx_dense),
                               atol=2e-4, rtol=2e-4)


def test_moe_grads_flow_to_experts():
    s = _spec()
    p = moe_init(jax.random.PRNGKey(4), s, jnp.float32)
    x = _x()

    def loss(p_):
        y, aux = moe_apply(p_, s, x, dropless=True)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    gn = float(jnp.abs(g["gate"]).sum() + jnp.abs(g["router"]).sum())
    assert np.isfinite(gn) and gn > 0
