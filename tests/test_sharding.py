"""Sharding rules: spec validity for every arch, FSDP wrap, cache SP
fallback, and an 8-device execution equivalence test (sharded == single)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.models.transformer import init_caches, init_lm


def _check_tree(mesh_shape, axis_names, specs, shapes):
    sizes = dict(zip(axis_names, mesh_shape))

    def check(path, leaf, spec):
        assert len(spec) <= len(leaf.shape), (path, leaf.shape, spec)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= sizes[a]
            assert leaf.shape[i] % n == 0, (path, leaf.shape, spec)
    jax.tree_util.tree_map_with_path(check, shapes, specs)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible_all_archs(arch, fsdp, subproc=None):
    # use FULL configs: this is exactly what the production mesh sees
    code_mesh = (16, 16)
    import repro.distributed.sharding as sh

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = registry.get_config(arch)
    shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    specs = sh.param_pspecs(shapes, FakeMesh(), fsdp=fsdp)
    _check_tree(code_mesh, ("data", "model"), specs, shapes)


@pytest.mark.parametrize("arch", ["qwen3-14b", "jamba-v0.1-52b",
                                  "deepseek-v2-lite-16b", "rwkv6-1.6b"])
@pytest.mark.parametrize("batch", [1, 32, 128])
def test_cache_specs_divisible(arch, batch):
    import repro.distributed.sharding as sh

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = registry.get_config(arch)
    shapes = jax.eval_shape(lambda: init_caches(cfg, batch, 2048))
    specs = sh.cache_pspecs(shapes, FakeMesh(), batch)
    _check_tree((16, 16), ("data", "model"), specs, shapes)
    if batch == 1 and arch != "rwkv6-1.6b":
        # SP fallback: some KV-cache seq dim must be sharded over 'data'
        # (rwkv has no seq-dim caches — O(1) recurrent state only)
        found = []
        jax.tree_util.tree_map_with_path(
            lambda p, s: found.append("data" in tuple(s)), specs,
            is_leaf=lambda x: isinstance(x, P))
        assert any(found)


@pytest.mark.parametrize("max_seq", [2048, 2050])
def test_cache_specs_ring_axis_shards_kv_sequence(max_seq):
    """ISSUE 4 bugfix regression: with a ring_axis, KV-cache sequence
    dims shard over that axis (so ring shards place where the rotation
    expects them) — guarded, so a non-divisible sequence (2050 % 16 != 0)
    replicates instead of silently padding — and the same axis is never
    booked twice in one spec (head dims yield to the ring)."""
    import repro.distributed.sharding as sh

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 16}
    cfg = registry.get_config("qwen3-14b")
    batch = 4                                  # divisible: batch-DP active
    shapes = jax.eval_shape(lambda: init_caches(cfg, batch, max_seq))
    specs = sh.cache_pspecs(shapes, FakeMesh(), batch, ring_axis="model")
    _check_tree((2, 16), ("data", "model"), specs, shapes)
    divisible = max_seq % 16 == 0

    def seq_axes(spec_tree):
        """(kv-seq-dim axis, spec) per k/v leaf + a double-booking scan."""
        seqs, booked = [], []

        def visit(path, spec):
            parts = [p for p in tuple(spec) if p is not None]
            booked.append(len(parts) != len(set(parts)))
            names = [str(getattr(e, "key", getattr(e, "idx", "")))
                     for e in path]
            if names and names[-1] in ("k", "v"):
                seq_idx = 2 if "periods" in names else 1
                seqs.append(spec[seq_idx] if len(spec) > seq_idx else None)
        jax.tree_util.tree_map_with_path(
            visit, spec_tree, is_leaf=lambda x: isinstance(x, P))
        return seqs, booked

    seqs, booked = seq_axes(specs)
    assert not any(booked)
    assert seqs and all(
        (s == "model") == divisible for s in seqs), seqs
    # without the knob the old behavior is untouched: batch-DP shards,
    # sequence dims stay unsharded
    base_seqs, base_booked = seq_axes(sh.cache_pspecs(shapes, FakeMesh(),
                                                      batch))
    assert not any(base_booked)
    assert base_seqs and all(s is None for s in base_seqs)


def test_tp_sharded_training_matches_single_device(subproc):
    """Gold test: loss on a (2,4) DP x TP mesh == unsharded loss."""
    code = '''
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data import SyntheticLM
from repro.models.transformer import init_lm
from repro.optim import adamw_init
from repro.train.step import TrainState, make_train_step, state_pspecs
from repro.launch.mesh import auto_mesh

cfg = registry.reduced_config("qwen3-14b").replace(vocab=128)
tcfg = TrainConfig(lr=1e-3, remat=True)
ds = SyntheticLM(vocab=128, seq_len=32, global_batch=8)
t, l = ds.batch(0)
batch = {"tokens": t, "labels": l}
params = init_lm(jax.random.PRNGKey(0), cfg)
state = TrainState(params, adamw_init(params), {})

# single-device reference
s1, m1 = jax.jit(make_train_step(cfg, tcfg))(state, batch)

# sharded
mesh = auto_mesh((2, 4), ("data", "model"))
_, spec = state_pspecs(cfg, tcfg, mesh)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                  is_leaf=lambda x: isinstance(x, P))
state_sh = jax.device_put(state, sh)
bsh = NamedSharding(mesh, P("data", None))
batch_sh = jax.tree.map(lambda x: jax.device_put(x, bsh), batch)
with mesh:
    step = jax.jit(make_train_step(cfg, tcfg, mesh),
                   in_shardings=(sh, bsh), out_shardings=(sh, None))
    s2, m2 = step(state_sh, batch_sh)
np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=2e-5)
np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                           rtol=1e-3)
d = jax.tree.reduce(jnp.maximum, jax.tree.map(
    lambda a, b: jnp.abs(a - b).max(), s1.params,
    jax.device_get(s2.params)))
assert float(d) < 3e-5, float(d)
print("TP_EQUIV_OK", float(m2["ce"]))
'''
    out = subproc(code, n_devices=8)
    assert "TP_EQUIV_OK" in out


def test_moe_ep_sharded_matches_single(subproc):
    """Expert-parallel MoE arch on a mesh == single device."""
    code = '''
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data import SyntheticLM
from repro.models.transformer import init_lm
from repro.optim import adamw_init
from repro.train.step import TrainState, make_train_step, state_pspecs
from repro.launch.mesh import auto_mesh

cfg = registry.reduced_config("granite-moe-3b-a800m").replace(vocab=128)
tcfg = TrainConfig(lr=1e-3, remat=False)
ds = SyntheticLM(vocab=128, seq_len=16, global_batch=4)
t, l = ds.batch(0)
batch = {"tokens": t, "labels": l}
params = init_lm(jax.random.PRNGKey(0), cfg)
state = TrainState(params, adamw_init(params), {})
_, m1 = jax.jit(make_train_step(cfg, tcfg))(state, batch)
mesh = auto_mesh((2, 4), ("data", "model"))
_, spec = state_pspecs(cfg, tcfg, mesh)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                  is_leaf=lambda x: isinstance(x, P))
state_sh = jax.device_put(state, sh)
bsh = NamedSharding(mesh, P("data", None))
batch_sh = jax.tree.map(lambda x: jax.device_put(x, bsh), batch)
with mesh:
    _, m2 = jax.jit(make_train_step(cfg, tcfg, mesh),
                    in_shardings=(sh, bsh), out_shardings=(sh, None)
                    )(state_sh, batch_sh)
np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=5e-5)
print("EP_EQUIV_OK")
'''
    out = subproc(code, n_devices=8)
    assert "EP_EQUIV_OK" in out
