"""Cross-implementation attention parity matrix (ISSUE 4 satellite).

THE single contract: every attention implementation in the dispatch
registry — ``naive`` / ``flash`` / ``flash_pallas`` / ``flash_ring``
(and ``flash_pallas_int`` where dualmode applies, ``flash_decode`` at
its s_q=1 decode rows) — must agree on outputs AND gradients across
GQA / MLA-style head dims / ragged validity / bf16 / non-divisible
shapes.  This matrix supersedes the
per-file parity checks (test_flash*.py keep their targeted
regressions; agreement itself is asserted here, once, for all impls).

``flash_ring`` runs over the largest power-of-two device ring dividing
the case's sequence dims: a size-1 ring in the plain tier-1 run, the
real 8-wide rotation under the CI multi-device lane
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.launch.mesh import auto_mesh

RNG_SEED = 23

CASES = {
    "gqa": dict(b=2, s=64, t=64, k=2, g=3, h=16),
    "mla_hv": dict(b=1, s=32, t=32, k=4, g=1, h=24, hv=12),
    "ragged": dict(b=2, s=48, t=96, k=1, g=2, h=8, ragged=True),
    "noncausal": dict(b=2, s=32, t=64, k=2, g=2, h=16, causal=False),
    "bf16": dict(b=2, s=48, t=64, k=2, g=2, h=32, dtype="bfloat16"),
    "non_divisible": dict(b=1, s=17, t=33, k=2, g=2, h=8),
}
# the float contract; 'naive' is the oracle the others are pinned against
FLOAT_IMPLS = ("flash", "flash_pallas", "flash_ring")
# forward tolerance: f32 reduction-order noise vs bf16 output rounding
ATOL = {"float32": 1e-5, "bfloat16": 2e-2}
GRAD_ATOL = {"float32": 2e-5, "bfloat16": 3e-2}


@functools.lru_cache(maxsize=None)
def _case(name):
    c = dict(CASES[name])
    rng = np.random.default_rng(RNG_SEED)
    b, s, t = c["b"], c["s"], c["t"]
    k, g, h = c["k"], c["g"], c["h"]
    hv = c.get("hv", h)
    dtype = jnp.dtype(c.get("dtype", "float32"))
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), dtype)
    kk = jnp.asarray(rng.normal(size=(b, t, k, h)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, k, hv)), dtype)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (b, s))
    if c.get("ragged"):
        kv_valid = jnp.asarray(rng.random((b, t)) > 0.3).at[:, 0].set(True)
    else:
        kv_valid = jnp.ones((b, t), bool)
    return (q, kk, v, q_pos, kv_valid, c.get("causal", True),
            str(dtype))


def _run(impl, q, k, v, q_pos, kv_valid, causal):
    fn = dispatch.get_attention(impl)
    call = functools.partial(fn, q_pos=q_pos, kv_valid=kv_valid,
                             causal=causal, scale=None,
                             softmax_impl="float", ring_axis="model")
    if impl != "flash_ring":
        return call(q, k, v)
    s, t = q.shape[1], k.shape[1]
    n = len(jax.devices())
    while n > 1 and (s % n or t % n):
        n //= 2
    with auto_mesh((n,), ("model",)):
        return call(q, k, v)


@pytest.mark.parametrize("impl", FLOAT_IMPLS)
@pytest.mark.parametrize("case", sorted(CASES))
def test_outputs_match_naive(case, impl):
    q, k, v, q_pos, kv_valid, causal, dtype = _case(case)
    want = _run("naive", q, k, v, q_pos, kv_valid, causal)
    got = _run(impl, q, k, v, q_pos, kv_valid, causal)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype])


@pytest.mark.parametrize("impl", FLOAT_IMPLS)
@pytest.mark.parametrize("case", sorted(CASES))
def test_grads_match_naive(case, impl):
    q, k, v, q_pos, kv_valid, causal, dtype = _case(case)

    def g_of(f):
        return jax.grad(
            lambda q_, k_, v_: f(q_, k_, v_).astype(jnp.float32).sum(),
            argnums=(0, 1, 2))(q, k, v)

    got = g_of(lambda *a: _run(impl, *a, q_pos, kv_valid, causal))
    want = g_of(lambda *a: _run("naive", *a, q_pos, kv_valid, causal))
    for name, a, b in zip(("dq", "dk", "dv"), got, want):
        assert bool(jnp.all(jnp.isfinite(a.astype(jnp.float32)))), name
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=GRAD_ATOL[dtype],
                                   err_msg=f"{case}/{impl}/{name}")


# ---------------- flash_decode: the s_q=1 split-KV rows ----------------
# Decode attends one query row against the whole cache, so the matrix
# cases are re-run at s_q=1 (the LAST query row of each case, keeping its
# position/validity/causality) across split counts.  The split-count
# invariance — output independent of WHERE the cache was split — is the
# partial-merge contract, pinned here against both the naive oracle and
# the one-host fold home flash_attention_merged.

DECODE_SPLITS = (1, 2, 4, 8)


def _decode_case(name):
    q, k, v, q_pos, kv_valid, causal, dtype = _case(name)
    return q[:, -1:], k, v, q_pos[:, -1:], kv_valid, causal, dtype


def _run_decode(q, k, v, q_pos, kv_valid, causal, n_splits):
    from repro.kernels.flash_decode import flash_decode_pallas
    return flash_decode_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                               causal=causal, num_splits=n_splits)


@pytest.mark.parametrize("n_splits", DECODE_SPLITS)
@pytest.mark.parametrize("case", sorted(CASES))
def test_flash_decode_outputs_match_naive(case, n_splits):
    q, k, v, q_pos, kv_valid, causal, dtype = _decode_case(case)
    want = _run("naive", q, k, v, q_pos, kv_valid, causal)
    got = _run_decode(q, k, v, q_pos, kv_valid, causal, n_splits)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype])


@pytest.mark.parametrize("case", sorted(CASES))
def test_flash_decode_split_count_invariance(case):
    """The fold is invariant to the split count: every n_splits produces
    the same words (to f32 sum-order noise), and where the cache length
    divides, the kernel's split partials merge to exactly what the
    one-host oracle fold (models/flash.flash_attention_merged) merges."""
    from repro.models.flash import flash_attention_merged
    q, k, v, q_pos, kv_valid, causal, dtype = _decode_case(case)
    ref = _run_decode(q, k, v, q_pos, kv_valid, causal, 1)
    for n_splits in DECODE_SPLITS[1:]:
        got = _run_decode(q, k, v, q_pos, kv_valid, causal, n_splits)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=ATOL[dtype],
                                   err_msg=f"n_splits={n_splits}")
        if k.shape[1] % n_splits == 0:
            merged = flash_attention_merged(
                q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
                n_splits=n_splits)
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(merged, np.float32),
                                       atol=ATOL[dtype],
                                       err_msg=f"merged n_splits={n_splits}")


DUALMODE_CASES = [c for c in sorted(CASES) if "dtype" not in CASES[c]]


@pytest.mark.parametrize("case", DUALMODE_CASES)
def test_dualmode_words_int_kernels_vs_naive(case):
    """Where dualmode applies (f32 operands): the three-sweep oracle
    carries the whole-row CLASSIC unit's words, the one-sweep snapped
    kernel the whole-row SNAPPED unit's words; each residual vs its own
    naive reference is pure numerator@v reduction-order noise, and the
    two units agree within the max-quantization bound."""
    q, k, v, q_pos, kv_valid, causal, _ = _case(case)
    naive = dispatch.get_attention("naive")(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=None, softmax_impl="dualmode")
    got3 = dispatch.get_attention("flash_pallas_int3")(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=None, softmax_impl="dualmode")
    np.testing.assert_allclose(np.asarray(got3), np.asarray(naive),
                               atol=1e-5)
    naive_snap = dispatch.get_attention("naive")(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=None, softmax_impl="dualmode_snap")
    got1 = dispatch.get_attention("flash_pallas_int")(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=None, softmax_impl="dualmode")
    np.testing.assert_allclose(np.asarray(got1), np.asarray(naive_snap),
                               atol=1e-5)
    # vs the CLASSIC unit the slack is the max-quantization octave
    # fraction — relative in the prob words, so a touch over 2e-3 on
    # O(1) outputs at the matrix's score scales
    np.testing.assert_allclose(np.asarray(got1), np.asarray(naive),
                               atol=4e-3)


@pytest.mark.parametrize("case", DUALMODE_CASES)
def test_dualmode_decode_row(case):
    """ISSUE 7 decode row: the int split-KV path at the matrix's s_q=1
    rows vs the whole-row snapped unit, across split counts (the int
    monoid's split invariance on real shapes)."""
    from repro.kernels.flash_decode import flash_decode_pallas
    q, k, v, q_pos, kv_valid, causal, _ = _decode_case(case)
    want = dispatch.get_attention("naive")(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=None, softmax_impl="dualmode_snap")
    for n_splits in (1, 4):
        got = flash_decode_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                  causal=causal, num_splits=n_splits,
                                  softmax_impl="dualmode")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5,
                                   err_msg=f"n_splits={n_splits}")


@pytest.mark.parametrize("case", [c for c in DUALMODE_CASES
                                  if CASES[c]["s"] % 2 == 0
                                  and CASES[c]["t"] % 2 == 0])
def test_dualmode_ring_row(case):
    """ISSUE 7 ring row: hop partials folded with the int monoid match
    the single-device one-sweep kernel on the matrix cases (ring width =
    largest power-of-two dividing the sequence dims)."""
    from repro.kernels.ring_attention import ring_flash_attention
    q, k, v, q_pos, kv_valid, causal, _ = _case(case)
    s, t = q.shape[1], k.shape[1]
    n = len(jax.devices())
    while n > 1 and (s % n or t % n):
        n //= 2
    with auto_mesh((n,), ("model",)):
        got = ring_flash_attention(q, k, v, q_pos=q_pos,
                                   kv_valid=kv_valid, causal=causal,
                                   softmax_impl="dualmode")
    want = dispatch.get_attention("flash_pallas_int")(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=None, softmax_impl="dualmode")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


# ---------------- paged decode: block-table gather rows ----------------
# The same matrix cases re-run at s_q=1 through the BLOCK-TABLE kernel:
# the dense cache is scattered into a shuffled physical pool and read
# back through per-row tables.  Parity vs the naive oracle (dense cache)
# pins that the gather-by-table is invisible to the numerics: masking is
# logical-position-only, pad blocks carry no mass.

PAGED_BS = 16


def _paged_case(name):
    q, k, v, q_pos, kv_valid, causal, dtype = _decode_case(name)
    b, t = k.shape[0], k.shape[1]
    nblk = -(-t // PAGED_BS)
    t_pad = nblk * PAGED_BS
    kp = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    valid = jnp.pad(kv_valid, ((0, 0), (0, t_pad - t)))
    rng = np.random.default_rng(RNG_SEED + 1)
    ids = rng.permutation(np.arange(1, 1 + b * nblk))
    tables = jnp.asarray(ids.reshape(b, nblk).astype(np.int32))
    n_pool = 1 + b * nblk
    shp = lambda x: (n_pool, PAGED_BS) + x.shape[2:]
    k_pool = jnp.zeros(shp(kp), kp.dtype)
    v_pool = jnp.zeros(shp(vp), vp.dtype)
    flat = (jnp.take_along_axis(
        tables, jnp.arange(t_pad)[None, :] // PAGED_BS, axis=1)
        * PAGED_BS + jnp.arange(t_pad)[None, :] % PAGED_BS)
    k_pool = k_pool.reshape((n_pool * PAGED_BS,) + kp.shape[2:]).at[
        flat.reshape(-1)].set(kp.reshape((-1,) + kp.shape[2:])
                              ).reshape(shp(kp))
    v_pool = v_pool.reshape((n_pool * PAGED_BS,) + vp.shape[2:]).at[
        flat.reshape(-1)].set(vp.reshape((-1,) + vp.shape[2:])
                              ).reshape(shp(vp))
    return (q, k_pool, v_pool, tables, q_pos, valid, causal, dtype,
            k, v, kv_valid)


@pytest.mark.parametrize("n_splits", (1, 2, 4))
@pytest.mark.parametrize("case", sorted(CASES))
def test_flash_decode_paged_outputs_match_naive(case, n_splits):
    from repro.kernels.flash_decode import flash_decode_paged
    (q, k_pool, v_pool, tables, q_pos, valid, causal, dtype,
     k, v, kv_valid) = _paged_case(case)
    want = _run("naive", q, k, v, q_pos, kv_valid, causal)
    got = flash_decode_paged(q, k_pool, v_pool, block_tables=tables,
                             q_pos=q_pos, kv_valid=valid, causal=causal,
                             num_splits=n_splits)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype])


@pytest.mark.parametrize("case", sorted(CASES))
def test_flash_decode_paged_matches_fold_oracle(case):
    """Block-table kernel vs the pure-JAX paged fold
    (models/flash.flash_attention_paged_ref) — the paged twin of the
    merged-fold contract, exercised on the SAME shuffled tables."""
    from repro.kernels.flash_decode import flash_decode_paged
    from repro.models.flash import flash_attention_paged_ref
    (q, k_pool, v_pool, tables, q_pos, valid, causal, dtype,
     *_ ) = _paged_case(case)
    got = flash_decode_paged(q, k_pool, v_pool, block_tables=tables,
                             q_pos=q_pos, kv_valid=valid, causal=causal)
    ref = flash_attention_paged_ref(q, k_pool, v_pool,
                                    block_tables=tables, q_pos=q_pos,
                                    kv_valid=valid, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=ATOL[dtype])


# ---------------- norm seams: the normalization resident's rows --------
# PR 9 makes RMSNorm/LayerNorm the third resident of the exp/log unit
# and fuses the block's norm seams (kernels/fused_norm.py).  The same
# matrix cases, re-read as token streams (m = b*s tokens of width
# d = k*g*h), pin the fused residual-add+norm epilogue against the dense
# pinned contract — outputs AND every gradient leg (dx, dr, dg, db) —
# including ragged (whole zero rows: the eps guard carries them) and
# non-divisible row counts vs the kernel's bm grid.

NORM_EPS = 1e-6
NORM_KINDS = ("rms", "layer")
NORM_CASES = ("gqa", "ragged", "bf16", "non_divisible")
# dead ragged rows ride the eps guard: dx there is O(1/sqrt(eps)), so
# the f32 leg needs a (tiny) rtol; bf16 weight-grads accumulate input
# rounding over the m rows, hence the wider atol
NORM_RTOL = {"float32": 1e-5, "bfloat16": 2e-2}
NORM_GRAD_ATOL = {"float32": 2e-5, "bfloat16": 1e-1}


def _norm_case(name, kind):
    c = CASES[name]
    m, d = c["b"] * c["s"], c["k"] * c["g"] * c["h"]
    dtype = jnp.dtype(c.get("dtype", "float32"))
    rng = np.random.default_rng(RNG_SEED)
    x = rng.normal(size=(m, d))
    r = rng.normal(size=(m, d))
    if c.get("ragged"):
        dead = rng.random(m) > 0.7      # padded token rows, x + r == 0
        x[dead] = 0.0
        r[dead] = 0.0
    x, r = jnp.asarray(x, dtype), jnp.asarray(r, dtype)
    g = jnp.asarray(1.0 + 0.1 * rng.normal(size=(d,)), dtype)
    b = (jnp.asarray(0.1 * rng.normal(size=(d,)), dtype)
         if kind == "layer" else None)
    co = jnp.asarray(rng.normal(size=(2, m, d)), jnp.float32)
    return x, r, g, b, co, str(dtype)


def _norm_pair(kind):
    from repro.kernels import datapath as dp
    from repro.kernels.fused_norm import fused_residual_norm

    def dense(x, r, g, b):
        s = x + r
        y = (dp.rmsnorm(s, g, NORM_EPS) if kind == "rms"
             else dp.layernorm(s, g, b, NORM_EPS))
        return s, y.astype(x.dtype)

    def fused(x, r, g, b):
        return fused_residual_norm(x, r, g, b, kind=kind, eps=NORM_EPS,
                                   interpret=True, bm=8)

    return dense, fused


@pytest.mark.parametrize("kind", NORM_KINDS)
@pytest.mark.parametrize("case", NORM_CASES)
def test_norm_epilogue_outputs_match_dense(case, kind):
    x, r, g, b, _, dtype = _norm_case(case, kind)
    dense, fused = _norm_pair(kind)
    want, got = dense(x, r, g, b), fused(x, r, g, b)
    for i in range(2):
        assert got[i].shape == want[i].shape
        assert got[i].dtype == want[i].dtype
        np.testing.assert_allclose(np.asarray(got[i], np.float32),
                                   np.asarray(want[i], np.float32),
                                   atol=ATOL[dtype], rtol=NORM_RTOL[dtype],
                                   err_msg=f"{case}/{kind}[{i}]")


@pytest.mark.parametrize("kind", NORM_KINDS)
@pytest.mark.parametrize("case", NORM_CASES)
def test_norm_epilogue_grads_match_dense(case, kind):
    x, r, g, b, co, dtype = _norm_case(case, kind)
    dense, fused = _norm_pair(kind)
    args = (x, r, g) + ((b,) if kind == "layer" else ())
    names = ("dx", "dr", "dg") + (("db",) if kind == "layer" else ())

    def g_of(f):
        def loss(*a):
            xb = a + (None,) if kind == "rms" else a
            s, y = f(*xb)
            return (jnp.vdot(s.astype(jnp.float32), co[0])
                    + jnp.vdot(y.astype(jnp.float32), co[1]))
        return jax.grad(loss, argnums=tuple(range(len(args))))(*args)

    got, want = g_of(fused), g_of(dense)
    for name, a_, b_ in zip(names, got, want):
        assert bool(jnp.all(jnp.isfinite(a_.astype(jnp.float32)))), name
        np.testing.assert_allclose(np.asarray(a_, np.float32),
                                   np.asarray(b_, np.float32),
                                   atol=NORM_GRAD_ATOL[dtype],
                                   rtol=NORM_RTOL[dtype],
                                   err_msg=f"{case}/{kind}/{name}")
