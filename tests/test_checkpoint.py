"""Checkpoint store: atomicity, gc, async, restore-with-resharding."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore, latest_step


def _tree(k=0):
    key = jax.random.PRNGKey(k)
    return {"a": jax.random.normal(key, (8, 4)),
            "nested": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.int32)},
            "lst": [jnp.ones((3,)), jnp.zeros((2, 2))]}


def test_roundtrip_exact(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(3, t)
    like = jax.eval_shape(lambda: _tree())
    got, step, _ = store.restore(like)
    assert step == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)


def test_latest_step_ignores_incomplete(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, _tree())
    # fake a crashed save: dir without manifest
    os.makedirs(tmp_path / "step_9")
    assert latest_step(str(tmp_path)) == 1


def test_gc_keeps_newest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree())
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_3", "step_4"]


def test_async_save_then_wait(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(7, _tree(), block=False)
    store.wait()
    assert latest_step(str(tmp_path)) == 7


def test_restore_missing_raises(tmp_path):
    store = CheckpointStore(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        store.restore(_tree())


def test_restore_extra_metadata(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(2, _tree(), extra={"arch": "x", "note": 1})
    _, _, extra = store.restore(jax.eval_shape(lambda: _tree()))
    assert extra == {"arch": "x", "note": 1}


def test_restore_casts_dtype(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, {"w": jnp.ones((4,), jnp.float32)})
    like = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    got, _, _ = store.restore(like)
    assert got["w"].dtype == jnp.bfloat16
