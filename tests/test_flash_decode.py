"""Split-KV flash-decode kernel + serve-engine decode fast path (ISSUE 5,
dual-mode decode ISSUE 7).

Covers what the parity matrix doesn't: the split-count heuristic, the
dispatch guards (s_q=1 only, 'auto' resolution at decode shapes), the
ragged per-slot tile skip, the dual-mode int split path, and the
engine-level contract — a long-cache ServeEngine resolves its decode
program through ``flash_decode`` (jaxpr-proved) for BOTH float and
dualmode configs, while short caches stay on whole-row naive.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import dispatch, tiling
from repro.kernels.flash_decode import flash_decode_pallas
from repro.models.attention import _naive_sdpa
from repro.models.transformer import init_lm
from repro.serve import Request, ServeEngine
from repro.serve.engine import make_decode_step

RNG = np.random.default_rng(29)


def _mk(b, t, kh, g, h, hv=None, dtype=jnp.float32):
    hv = hv or h
    q = jnp.asarray(RNG.normal(size=(b, 1, kh, g, h)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, t, kh, h)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, t, kh, hv)), dtype)
    return q, k, v


# ---------------- kernel ----------------

def test_ragged_slot_depths_match_naive():
    """Every batch row at its own cache depth — the continuous-batching
    shape: the per-row causal tile skip must reproduce the naive mask."""
    b, t = 4, 1024
    q, k, v = _mk(b, t, 2, 2, 16)
    # slot depths spread from nearly-empty to nearly-full bucket
    q_pos = jnp.asarray([[3], [129], [700], [1023]], jnp.int32)
    kv_valid = jnp.arange(t)[None, :] <= q_pos          # (B, T) ragged
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid)
    for ns in (1, 2, 4, 8):
        got = flash_decode_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                  num_splits=ns)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, err_msg=f"n_splits={ns}")


def test_hv_off_lane_grid():
    """hv=72 exercises the lane-rounded acc scratch (MLA-style v dim)."""
    q, k, v = _mk(1, 200, 1, 2, 16, hv=72)
    q_pos = jnp.full((1, 1), 199, jnp.int32)
    kv_valid = jnp.ones((1, 200), bool)
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid)
    got = flash_decode_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                              num_splits=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_more_splits_than_tiles_emits_sentinels():
    """num_splits beyond the tile count: the surplus splits hold only
    phantom keys, emit the (MASK_VALUE, 0, 0) sentinel, and the merge is
    unchanged — the degenerate end of the split-invariance law."""
    q, k, v = _mk(1, 100, 2, 1, 8)
    q_pos = jnp.full((1, 1), 99, jnp.int32)
    kv_valid = jnp.ones((1, 100), bool)
    ref = flash_decode_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                              num_splits=1)
    got = flash_decode_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                              num_splits=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_rejects_wide_query_tiles():
    q = jnp.zeros((1, 2, 1, 1, 8), jnp.float32)
    k = jnp.zeros((1, 16, 1, 8), jnp.float32)
    v = jnp.zeros((1, 16, 1, 8), jnp.float32)
    with pytest.raises(ValueError, match="s_q=1"):
        flash_decode_pallas(q, k, v, q_pos=jnp.zeros((1, 2), jnp.int32),
                            kv_valid=jnp.ones((1, 16), bool))


# ---------------- tiling heuristic ----------------

def test_decode_splits_heuristic():
    """Sized from cache length, capped, and degenerating to 1 split (=
    plain blocked streaming) at short caches."""
    assert tiling.decode_splits(256, max_splits=8) == 1
    assert tiling.decode_splits(2048, max_splits=8) == 1
    assert tiling.decode_splits(4096, max_splits=8) == 2
    assert tiling.decode_splits(16384, max_splits=8) == 8
    assert tiling.decode_splits(65536, max_splits=8) == 8
    assert tiling.decode_splits(65536, max_splits=4) == 4
    # default cap: min(core count, DECODE_MAX_SPLITS), always >= 1
    assert 1 <= tiling.decode_splits(1 << 20) <= tiling.DECODE_MAX_SPLITS


def test_decode_kv_block_lane_aligned():
    for t in (100, 1024, 4096, 65536):
        for ns in (1, 2, 4, 8):
            b = tiling.decode_kv_block(t, ns)
            assert b % tiling.LANE == 0 and b <= 512


# ---------------- dispatch resolution ----------------

def test_auto_resolution_decode_shapes():
    assert dispatch.resolve_attention(
        "auto", 1, tiling.DECODE_FLASH_MIN_KV) == "flash_decode"
    assert dispatch.resolve_attention("auto", 1, 65536) == "flash_decode"
    # short cache: whole-row naive stays
    assert dispatch.resolve_attention("auto", 1, 256) == "naive"
    # dualmode decode: flash_decode routes to the int split path inside
    # the entry — the unit streams split-KV instead of whole-row naive
    assert dispatch.resolve_attention(
        "auto", 1, 65536, softmax_impl="dualmode") == "flash_decode"
    # wide-q shapes never pick the decode kernel
    assert dispatch.resolve_attention("auto", 2, 65536) != "flash_decode"


def test_auto_decode_pick_is_mesh_gated():
    """flash_decode is a single-device kernel: under an ambient mesh
    (sharded serving, the 512-device dry-run cells) an unshardable
    pallas_call would gather every slot's full cache per chip, so the
    'auto' decode pick stays on the shardable whole-row naive graph."""
    from repro.launch.mesh import auto_mesh
    assert dispatch.resolve_attention("auto", 1, 65536) == "flash_decode"
    mesh = auto_mesh((len(jax.devices()),), ("model",))
    with mesh:
        assert dispatch.resolve_attention("auto", 1, 65536) == "naive"
    assert dispatch.resolve_attention("auto", 1, 65536) == "flash_decode"


def test_explicit_flash_decode_dualmode_resolves_and_runs():
    """ISSUE 7: dualmode + flash_decode is a supported pairing — it
    resolves, and the entry runs the snapped int split path whose output
    matches the naive whole-row SNAPPED unit (word-identical recurrence,
    f32 numerator@v order the only slack)."""
    assert dispatch.resolve_attention(
        "flash_decode", 1, 4096, softmax_impl="dualmode") == "flash_decode"
    b, t = 2, 512
    q, k, v = _mk(b, t, 2, 2, 16)
    q_pos = jnp.asarray([[100], [511]], jnp.int32)
    kv_valid = jnp.arange(t)[None, :] <= q_pos
    entry = dispatch.get_attention("flash_decode")
    got = entry(q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=True,
                scale=None, softmax_impl="dualmode")
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                       softmax_impl="dualmode_snap")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # and vs the CLASSIC whole-row unit: the max-quantization bound
    want_c = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                         softmax_impl="dualmode")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_c),
                               atol=2e-3)


def test_dualmode_decode_split_invariance():
    """The int monoid fold: WHERE the cache splits cannot change words."""
    b, t = 2, 1024
    q, k, v = _mk(b, t, 2, 2, 16)
    q_pos = jnp.asarray([[40], [1000]], jnp.int32)
    kv_valid = jnp.arange(t)[None, :] <= q_pos
    ref = flash_decode_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                              num_splits=1, softmax_impl="dualmode")
    for ns in (2, 4, 8):
        got = flash_decode_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                  num_splits=ns, softmax_impl="dualmode")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-6, err_msg=f"n_splits={ns}")


# ---------------- serve engine fast path ----------------

def test_engine_decode_resolves_flash_decode_at_long_kv():
    """Long-cache engine: decode resolves the split-KV kernel and the
    jitted decode step really routes through it (a pallas_call in the
    jaxpr); short-cache and dualmode engines stay on naive."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=2048,
                      prefill_buckets=(8,), cache_mode="contiguous")
    assert eng.decode_attn_impl == "flash_decode"
    step = make_decode_step(cfg.replace(attn_impl=eng.decode_attn_impl))
    toks = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray([5, 9], jnp.int32)
    jaxpr = jax.make_jaxpr(step)(params, eng.caches, toks, pos)
    assert "pallas_call" in str(jaxpr), \
        "decode step does not route through the flash_decode kernel"
    # short cache: naive decode, and NO pallas_call in its decode step
    short = ServeEngine(cfg, params, n_slots=2, max_seq=64,
                        prefill_buckets=(8,), cache_mode="contiguous")
    assert short.decode_attn_impl == "naive"
    jaxpr_s = jax.make_jaxpr(make_decode_step(
        cfg.replace(attn_impl=short.decode_attn_impl)))(
        params, short.caches, toks, pos)
    assert "pallas_call" not in str(jaxpr_s)
    # dualmode engine decode takes the split-KV fast path too (ISSUE 7:
    # the int monoid made flash_decode softmax-aware)
    dual = ServeEngine(cfg.replace(softmax_impl="dualmode"), params,
                      n_slots=2, max_seq=2048, prefill_buckets=(8,),
                      cache_mode="contiguous")
    assert dual.decode_attn_impl == "flash_decode"


def test_engine_decode_step_logits_match_naive():
    """The fast path is numerics-neutral: one batched decode step through
    flash_decode matches the naive decode step's logits at mixed slot
    depths (the ragged continuous-batching state)."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=3, max_seq=1024,
                      prefill_buckets=(8,), cache_mode="contiguous")
    assert eng.decode_attn_impl == "flash_decode"
    # mixed-depth slots over a prefilled cache
    outs = eng.run([Request(rid=0, prompt=[1, 2, 3], max_new=2),
                    Request(rid=1, prompt=[5] * 7, max_new=2),
                    Request(rid=2, prompt=[4, 9], max_new=2)])
    assert sorted(outs) == [0, 1, 2]
    toks = jnp.asarray([[3], [7], [11]], jnp.int32)
    pos = jnp.asarray([4, 8, 3], jnp.int32)
    fast = make_decode_step(cfg.replace(attn_impl="flash_decode"))
    slow = make_decode_step(cfg.replace(attn_impl="naive"))
    lf, _ = fast(params, eng.caches, toks, pos)
    ls, _ = slow(params, eng.caches, toks, pos)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(ls), atol=2e-4)
