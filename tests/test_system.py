"""End-to-end system test: train -> checkpoint -> restore -> serve.

The full lifecycle a production framework must support, on a reduced
config: the Trainer fits a synthetic bigram LM, checkpoints; a fresh
process-equivalent restore feeds the serving engine; generated text must
reflect the learned bigram structure (better-than-chance next-token hits).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.data import SyntheticLM
from repro.serve import Request, ServeEngine
from repro.train import Trainer


def test_train_checkpoint_serve_lifecycle(tmp_path):
    cfg = registry.reduced_config("qwen1.5-0.5b").replace(vocab=64)
    ck = str(tmp_path / "ck")
    tcfg = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                       checkpoint_every=40, checkpoint_dir=ck, remat=True)
    data = SyntheticLM(vocab=64, seq_len=32, global_batch=16, seed=0)
    trainer = Trainer(cfg, tcfg, global_batch=16, seq_len=32, data=data,
                      log=lambda *_: None)
    m0 = trainer.run(10)
    m1 = trainer.run(110)
    assert m1["loss"] < m0["loss"] - 0.5, (m0["loss"], m1["loss"])
    trainer.save(trainer.start_step)

    # fresh restore (as a new process would)
    store = CheckpointStore(ck)
    state_like = jax.eval_shape(lambda: trainer.state)
    restored, step, _ = store.restore(state_like)
    assert step == trainer.start_step
    params = restored.params

    # serve with the trained weights; outputs should follow the bigram LM
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=64,
                      prefill_buckets=(8,))
    tbl = np.asarray(jax.nn.softmax(data._tbl, axis=-1))
    prompts = [[int(t) for t in data.batch(999)[0][i, :6]]
               for i in range(4)]
    outs = eng.run([Request(rid=i, prompt=p, max_new=12)
                    for i, p in enumerate(prompts)])
    hits = total = 0
    for i, p in enumerate(prompts):
        seq = p + outs[i]
        for a, b in zip(seq[:-1], seq[1:]):
            # learned transitions should land in the bigram's top-8 set
            hits += int(b in np.argsort(tbl[a])[-8:])
            total += 1
    rate = hits / total
    assert rate > 0.35, f"served tokens ignore learned bigram: {rate:.2f}"
