"""Normalization joins the unit: the dense contract, its VJP homes, and
the fused Pallas seams (PR 9).

The dense contract (models/layers.py -> kernels/datapath.py): moments AND
gain/bias entirely in f32, ONE downcast on the finished result, ``eps``
always threaded from config (never a default).  These tests pin that
contract (bf16-vs-f32 regression, eps-required, call-site audit), prove
the datapath VJP homes against autodiff, and hold every fused seam
(kernels/fused_norm.py) to dense parity — outputs AND gradients — across
norm kind, dtype and non-divisible shapes, in interpret mode.  The int
counterpart (core/softmax_unit.rmsnorm_int/layernorm_int: SOLE-style
guaranteed normalization, rsqrt as the unit's exp2/log2 traversal) is
pinned against the float home at lattice tolerance.
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import softmax_unit as unit
from repro.kernels import datapath as dp
from repro.kernels import dispatch
from repro.kernels.fused_norm import (fused_norm_glu, fused_norm_linear,
                                      fused_residual_norm)
from repro.models import layers

EPS = 1e-6
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

KINDS = ("rms", "layer")
# (name, dtype, m, d, f): even tiles, everything-ragged, bf16 stream
SHAPES = [
    ("f32_even", "float32", 64, 128, 256),
    ("f32_ragged", "float32", 23, 72, 120),
    ("bf16", "bfloat16", 32, 96, 192),
]
ATOL = {"float32": 1e-5, "bfloat16": 2e-2}
GRAD_ATOL = {"float32": 2e-5, "bfloat16": 1e-1}
# bf16 rounds at ~2**-8 relative; large-magnitude grads need the rtol leg
RTOL = {"float32": 0.0, "bfloat16": 2e-2}


def _data(m, d, f, dtype, kind, seed=3):
    rng = np.random.default_rng(seed)
    dt = jnp.dtype(dtype)
    x = jnp.asarray(rng.normal(size=(m, d)), dt)
    r = jnp.asarray(rng.normal(size=(m, d)), dt)
    g = jnp.asarray(1.0 + 0.1 * rng.normal(size=(d,)), dt)
    b = (jnp.asarray(0.1 * rng.normal(size=(d,)), dt)
         if kind == "layer" else None)
    w = jnp.asarray(rng.normal(size=(d, f)) / d ** 0.5, dt)
    wu = jnp.asarray(rng.normal(size=(d, f)) / d ** 0.5, dt)
    return x, r, g, b, w, wu


def _dense_norm(x, g, b, kind):
    y = (dp.rmsnorm(x, g, EPS) if kind == "rms"
         else dp.layernorm(x, g, b, EPS))
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# the pinned dense contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_norm_op_order_bf16_matches_f32_reference(kind):
    """The op-order contract: a bf16 input must produce BITWISE the f32
    computation downcast once at the end — moments and gain/bias never
    run in bf16 (the regression this pins: g applied after the downcast,
    or a bf16 mean, breaks the equality)."""
    rng = np.random.default_rng(11)
    m, d = 24, 96
    x16 = jnp.asarray(rng.normal(size=(m, d)) * 8.0, jnp.bfloat16)
    g16 = jnp.asarray(1.0 + 0.5 * rng.normal(size=(d,)), jnp.bfloat16)
    b16 = jnp.asarray(0.5 * rng.normal(size=(d,)), jnp.bfloat16)
    if kind == "rms":
        got = layers.rmsnorm({"g": g16}, x16, EPS)
        want = dp.rmsnorm(x16.astype(jnp.float32), g16, EPS)
    else:
        got = layers.layernorm({"g": g16, "b": b16}, x16, EPS)
        want = dp.layernorm(x16.astype(jnp.float32), g16, b16, EPS)
    assert got.dtype == jnp.bfloat16
    assert want.dtype == jnp.float32          # the single downcast is ours
    assert jnp.array_equal(got, want.astype(jnp.bfloat16))


@pytest.mark.parametrize("kind", KINDS)
def test_layernorm_onepass_var_never_negative(kind):
    """Constant rows make E[x^2] - mu^2 slightly negative in floats; the
    one-pass clamp keeps the rsqrt argument at eps, not NaN."""
    x = jnp.full((4, 64), 3.14159, jnp.float32)
    g = jnp.ones((64,), jnp.float32)
    y = (dp.rmsnorm(x, g, EPS) if kind == "rms"
         else dp.layernorm(x, g, jnp.zeros((64,)), EPS))
    assert bool(jnp.all(jnp.isfinite(y)))


def test_eps_is_required_not_defaulted():
    """No 1e-6 default anywhere: a call that forgets to thread
    cfg.norm_eps must fail loudly, not silently normalize with a
    hard-coded epsilon."""
    p = {"g": jnp.ones((8,), jnp.float32), "b": jnp.zeros((8,), jnp.float32)}
    x = jnp.ones((4, 8), jnp.float32)
    with pytest.raises(TypeError):
        layers.rmsnorm(p, x)
    with pytest.raises(TypeError):
        layers.layernorm(p, x)
    with pytest.raises(TypeError):
        dp.rmsnorm(x, p["g"])
    with pytest.raises(TypeError):
        dp.layernorm(x, p["g"], p["b"])


def _call_sites(text, name):
    """Argument text of every bare ``name(...)`` call (defs excluded)."""
    sites = []
    for m in re.finditer(rf"(?<![\w.])({name})\(", text):
        line_start = text.rfind("\n", 0, m.start()) + 1
        if text[line_start:m.start()].lstrip().startswith("def "):
            continue
        depth, i = 1, m.end()
        while depth:
            if text[i] == "(":
                depth += 1
            elif text[i] == ")":
                depth -= 1
            i += 1
        sites.append(text[m.end():i - 1])
    return sites


def test_every_model_norm_call_threads_eps():
    """Source audit of src/repro/models: every rmsnorm/layernorm call
    site passes an eps expression (qk-norm, the MLA latent norms, block
    norms, the final LM norm) — the companion to eps having no default."""
    models = os.path.join(REPO, "src", "repro", "models")
    found = 0
    for fname in sorted(os.listdir(models)):
        if not fname.endswith(".py"):
            continue
        with open(os.path.join(models, fname)) as fh:
            text = fh.read()
        for name in ("rmsnorm", "layernorm"):
            for args in _call_sites(text, name):
                found += 1
                assert "eps" in args, (
                    f"{fname}: {name}({args}) does not thread an eps — "
                    "norm eps must come from config, never a default")
    assert found >= 4        # qk-norm x2 + MLA latent norms at minimum


# ---------------------------------------------------------------------------
# datapath VJP homes vs autodiff
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_datapath_norm_vjp_matches_autodiff(kind):
    rng = np.random.default_rng(5)
    m, d = 12, 40
    x = jnp.asarray(rng.normal(size=(m, d)) * 2.0, jnp.float32)
    g = jnp.asarray(1.0 + 0.3 * rng.normal(size=(d,)), jnp.float32)
    b = jnp.asarray(0.3 * rng.normal(size=(d,)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    if kind == "rms":
        dx_ad, dg_ad = jax.grad(
            lambda x_, g_: jnp.vdot(dp.rmsnorm(x_, g_, EPS), dy),
            argnums=(0, 1))(x, g)
        dx, dg_hat = dp.rmsnorm_vjp(x, g, EPS, dy)
        db, db_ad = None, None
    else:
        dx_ad, dg_ad, db_ad = jax.grad(
            lambda x_, g_, b_: jnp.vdot(dp.layernorm(x_, g_, b_, EPS), dy),
            argnums=(0, 1, 2))(x, g, b)
        dx, dg_hat, db_hat = dp.layernorm_vjp(x, g, EPS, dy)
        db = jnp.sum(db_hat, axis=0)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ad), atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.sum(dg_hat, axis=0)),
                               np.asarray(dg_ad), atol=1e-5)
    if db is not None:
        np.testing.assert_allclose(np.asarray(db), np.asarray(db_ad),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# fused seams vs the dense contract: outputs AND gradients
# ---------------------------------------------------------------------------


def _grads(loss_fn, args):
    return jax.grad(loss_fn, argnums=tuple(range(len(args))))(*args)


def _assert_tree_close(got, want, atol, tag, rtol=0.0):
    for i, (a, b) in enumerate(zip(got, want)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol,
                                   rtol=rtol, err_msg=f"{tag}[{i}]")


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("name,dtype,m,d,f", SHAPES)
def test_fused_residual_norm_matches_dense(kind, name, dtype, m, d, f):
    x, r, g, b, _, _ = _data(m, d, f, dtype, kind)
    co = jnp.asarray(np.random.default_rng(9).normal(size=(2, m, d)),
                     jnp.float32)

    def dense(*a):
        x_, r_, g_ = a[:3]
        s = x_ + r_
        return s, _dense_norm(s, g_, a[3] if kind == "layer" else None, kind)

    def fused(*a):
        return fused_residual_norm(
            a[0], a[1], a[2], a[3] if kind == "layer" else None,
            kind=kind, eps=EPS, interpret=True, bm=8)

    args = (x, r, g) + ((b,) if kind == "layer" else ())
    out_d, out_f = dense(*args), fused(*args)
    atol = ATOL[dtype]
    assert out_f[0].dtype == out_f[1].dtype == jnp.dtype(dtype)
    _assert_tree_close(out_f, out_d, atol, f"{name}/{kind}/out")

    def loss(fn):
        return lambda *a: (
            jnp.vdot(fn(*a)[0].astype(jnp.float32), co[0])
            + jnp.vdot(fn(*a)[1].astype(jnp.float32), co[1]))

    _assert_tree_close(_grads(loss(fused), args), _grads(loss(dense), args),
                       GRAD_ATOL[dtype], f"{name}/{kind}/grad",
                       rtol=RTOL[dtype])


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("name,dtype,m,d,f", SHAPES)
def test_fused_norm_linear_matches_dense(kind, name, dtype, m, d, f):
    x, _, g, b, w, _ = _data(m, d, f, dtype, kind)
    co = jnp.asarray(np.random.default_rng(9).normal(size=(m, f)),
                     jnp.float32)

    def dense(*a):
        x_, g_, w_ = a[0], a[1], a[-1]
        h = _dense_norm(x_, g_, a[2] if kind == "layer" else None, kind)
        return h @ w_

    def fused(*a):
        return fused_norm_linear(
            a[0], a[1], a[2] if kind == "layer" else None, a[-1],
            kind=kind, eps=EPS, interpret=True, bm=8, bf=128)

    args = (x, g) + ((b,) if kind == "layer" else ()) + (w,)
    out_d, out_f = dense(*args), fused(*args)
    assert out_f.dtype == jnp.dtype(dtype)
    _assert_tree_close([out_f], [out_d], ATOL[dtype], f"{name}/{kind}/out",
                       rtol=RTOL[dtype])

    def loss(fn):
        return lambda *a: jnp.vdot(fn(*a).astype(jnp.float32), co)

    _assert_tree_close(_grads(loss(fused), args), _grads(loss(dense), args),
                       GRAD_ATOL[dtype], f"{name}/{kind}/grad",
                       rtol=RTOL[dtype])


@pytest.mark.parametrize("mode", ["gelu", "silu"])
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("name,dtype,m,d,f", SHAPES)
def test_fused_norm_glu_matches_dense(kind, name, dtype, m, d, f, mode):
    x, _, g, b, wg, wu = _data(m, d, f, dtype, kind)
    co = jnp.asarray(np.random.default_rng(9).normal(size=(m, f)),
                     jnp.float32)

    def dense(*a):
        x_, g_ = a[0], a[1]
        wg_, wu_ = a[-2], a[-1]
        h = _dense_norm(x_, g_, a[2] if kind == "layer" else None, kind)
        h32 = h.astype(jnp.float32)
        return (dp.pair_act(h32 @ wg_.astype(jnp.float32), mode)
                * (h32 @ wu_.astype(jnp.float32))).astype(x_.dtype)

    def fused(*a):
        return fused_norm_glu(
            a[0], a[1], a[2] if kind == "layer" else None, a[-2], a[-1],
            kind=kind, eps=EPS, mode=mode, interpret=True, bm=8, bf=128)

    args = (x, g) + ((b,) if kind == "layer" else ()) + (wg, wu)
    out_d, out_f = dense(*args), fused(*args)
    assert out_f.dtype == jnp.dtype(dtype)
    _assert_tree_close([out_f], [out_d], ATOL[dtype], f"{name}/{kind}/out",
                       rtol=RTOL[dtype])

    def loss(fn):
        return lambda *a: jnp.vdot(fn(*a).astype(jnp.float32), co)

    _assert_tree_close(_grads(loss(fused), args), _grads(loss(dense), args),
                       GRAD_ATOL[dtype], f"{name}/{kind}/grad",
                       rtol=RTOL[dtype])


# ---------------------------------------------------------------------------
# the provider registry + end-to-end block threading
# ---------------------------------------------------------------------------


def test_norm_provider_carries_every_seam():
    prov = dispatch.get_norm("fused_pallas")
    assert prov is not None
    for seam in dispatch.NORM_SEAMS:
        assert callable(prov.get(seam)), seam
    assert dispatch.get_norm("dense") is None
    assert dispatch.resolve_norm("auto") in dispatch._NORM
    with pytest.raises(ValueError, match="unknown norm impl"):
        dispatch.get_norm("nope")


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "yi-6b"])
def test_block_fused_norm_impl_matches_dense_end_to_end(arch):
    """The whole stack through models/transformer.block_apply: logits and
    parameter gradients with norm_impl='fused_pallas' (every seam fused:
    norm->QKV prologue, residual+norm epilogue, norm->GLU prologue) vs
    the dense reference."""
    import dataclasses

    from repro.configs import registry
    from repro.models.transformer import init_lm, lm_apply

    cfg = registry.reduced_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)

    def logits_of(c):
        return lm_apply(params, c, toks)[0]

    def loss_of(c):
        return lambda p: lm_apply(p, c, toks)[0].astype(jnp.float32).sum()

    fused_cfg = dataclasses.replace(cfg, norm_impl="fused_pallas")
    out_d, out_f = logits_of(cfg), logits_of(fused_cfg)
    np.testing.assert_allclose(np.asarray(out_f, np.float32),
                               np.asarray(out_d, np.float32), atol=5e-4)
    from jax.flatten_util import ravel_pytree
    gd = jax.grad(loss_of(cfg))(params)
    gf = jax.grad(loss_of(fused_cfg))(params)
    flat_d, _ = ravel_pytree(gd)
    flat_f, _ = ravel_pytree(gf)
    np.testing.assert_allclose(np.asarray(flat_f), np.asarray(flat_d),
                               atol=5e-3)


def test_block_fused_respects_megatron_pins():
    """With Megatron inner pins active (ctx.pin_full/pin_sp), the block
    must NOT fuse: the pins need the residual stream and the normed
    stream as SEPARATE shardable values.  So under pins the fused config
    runs the IDENTICAL dense graph — bitwise, not just close."""
    import dataclasses

    from jax.sharding import Mesh, PartitionSpec as P

    from repro.configs import registry
    from repro.models.transformer import init_lm, lm_apply

    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    fused_cfg = dataclasses.replace(cfg, norm_impl="fused_pallas")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("model",))
    pspec = P(None, "model", None)
    with mesh:
        out_d = lm_apply(params, cfg, toks,
                         act_pspec=pspec, inner_pins=True)[0]
        out_f = lm_apply(params, fused_cfg, toks,
                         act_pspec=pspec, inner_pins=True)[0]
    assert jnp.array_equal(out_f, out_d)


# ---------------------------------------------------------------------------
# the int counterpart: guaranteed normalization on the word lattice
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_int_norm_tracks_the_float_home(kind):
    """rmsnorm_int/layernorm_int run rsqrt as the unit's log2 -> shift ->
    exp2 traversal, entirely in int32 (the purity pass audits the path);
    vs the float home the residual is lattice quantization + PWL error —
    well under one S5.10 step times the gain."""
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(16, 128)) * 2.0, jnp.float32)
    g = jnp.asarray(1.0 + 0.1 * rng.normal(size=(128,)), jnp.float32)
    b = jnp.asarray(0.1 * rng.normal(size=(128,)), jnp.float32)
    if kind == "rms":
        got = unit.rmsnorm_dualmode(x, g, eps=EPS)
        want = dp.rmsnorm(x, g, EPS)
    else:
        got = unit.layernorm_dualmode(x, g, b, eps=EPS)
        want = dp.layernorm(x, g, b, EPS)
    err = float(jnp.abs(got - want).max())
    assert err <= 8e-3, err
    assert bool(jnp.all(jnp.isfinite(got)))


@pytest.mark.parametrize("kind", KINDS)
def test_int_norm_output_is_unit_scale(kind):
    """Guaranteed normalization: even a wildly mis-scaled input comes out
    at unit RMS (the property eps exists to protect in float — on the
    lattice the clamp + saturation rails play that role)."""
    rng = np.random.default_rng(23)
    for scale in (0.05, 1.0, 10.0):
        x = jnp.asarray(rng.normal(size=(8, 128)) * scale, jnp.float32)
        fn = unit.rmsnorm_int if kind == "rms" else unit.layernorm_int
        y = unit.dequantize(fn(unit.quantize(x)), unit.IN_FRAC)
        ms = float(jnp.sqrt(jnp.mean(jnp.square(y))))
        assert 0.8 <= ms <= 1.2, (scale, ms)
