"""Sequence-parallel ring flash attention (ISSUE 4 tentpole).

In-process tests build the ring mesh over however many devices exist —
one in the plain tier-1 run, eight under the CI multi-device lane
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — so the same
suite exercises the real rotation when devices are available.  The gold
acceptance test (output and dq/dk/dv parity vs the single-device Pallas
kernel <= 1e-5 on an emulated 8-device mesh) always runs multi-device
via the subprocess fixture.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ring_attention import ring_flash_attention
from repro.launch.mesh import auto_mesh
from repro.models.attention import _naive_sdpa
from repro.models.flash import flash_attention_merged

RNG = np.random.default_rng(13)


def _mk(b, s, t, k, g, h, hv=None):
    hv = hv or h
    q = jnp.asarray(RNG.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(RNG.normal(size=(b, t, k, h)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, t, k, hv)), jnp.float32)
    return q, kk, v


def _ring_mesh(s: int, t: int):
    """Largest power-of-two device ring that divides both sequence dims."""
    n = len(jax.devices())
    while n > 1 and (s % n or t % n):
        n //= 2
    return auto_mesh((n,), ("model",)), n


@pytest.mark.parametrize("causal", [True, False])
def test_ring_matches_naive_and_single_device(causal):
    q, k, v = _mk(2, 64, 64, 2, 2, 16)
    q_pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    kv_valid = jnp.asarray(RNG.random((2, 64)) > 0.2).at[:, 0].set(True)
    mesh, _ = _ring_mesh(64, 64)
    got = ring_flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                               mesh=mesh, causal=causal, interpret=True)
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                       causal=causal)
    sd = flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                causal=causal, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(sd), atol=1e-5)


def test_ring_merged_stats_match_single_device_residual_contract():
    """The MERGED (m, l) must equal the single-device kernel's saved
    whole-row statistics — the residual contract IS the ring interface."""
    q, k, v = _mk(1, 32, 32, 2, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(32)[None], (1, 32))
    kv_valid = jnp.ones((1, 32), bool)
    mesh, _ = _ring_mesh(32, 32)
    _, m_r, l_r = ring_flash_attention(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, mesh=mesh,
        interpret=True, return_stats=True)
    _, m_s, l_s = flash_attention_pallas(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, interpret=True,
        return_stats=True)
    np.testing.assert_allclose(np.asarray(m_r), np.asarray(m_s), atol=1e-6)
    np.testing.assert_allclose(np.asarray(l_r), np.asarray(l_s),
                               rtol=1e-5, atol=1e-6)


def test_ring_grads_match_naive():
    q, k, v = _mk(1, 32, 32, 1, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(32)[None], (1, 32))
    kv_valid = jnp.ones((1, 32), bool)
    mesh, _ = _ring_mesh(32, 32)

    def g_of(fn):
        return jax.grad(lambda q_, k_, v_: fn(q_, k_, v_).sum(),
                        argnums=(0, 1, 2))(q, k, v)

    gr = g_of(lambda q_, k_, v_: ring_flash_attention(
        q_, k_, v_, q_pos=q_pos, kv_valid=kv_valid, mesh=mesh,
        interpret=True))
    gn = g_of(lambda q_, k_, v_: _naive_sdpa(
        q_, k_, v_, q_pos=q_pos, kv_valid=kv_valid))
    for name, a, b in zip(("dq", "dk", "dv"), gr, gn):
        assert bool(jnp.all(jnp.isfinite(a))), name
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, err_msg=name)


def test_ring_skip_masked_hops_is_parity_neutral():
    """Skipped causal hops drop only the exp(MASK_VALUE) mass of fully
    masked keys — forcing every hop must agree within float tolerance."""
    q, k, v = _mk(1, 32, 32, 1, 1, 8)
    q_pos = jnp.broadcast_to(jnp.arange(32)[None], (1, 32))
    kv_valid = jnp.ones((1, 32), bool)
    mesh, _ = _ring_mesh(32, 32)
    kw = dict(q_pos=q_pos, kv_valid=kv_valid, mesh=mesh, causal=True,
              interpret=True)
    fast = ring_flash_attention(q, k, v, skip_masked_hops=True, **kw)
    full = ring_flash_attention(q, k, v, skip_masked_hops=False, **kw)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(full),
                               atol=1e-6)


def test_ring_matches_pure_jax_merged_reference():
    """kernels/ring_attention.py across devices == the one-host fold in
    models/flash.flash_attention_merged (the pure-JAX home of the
    partial-merge contract) — for any split count."""
    q, k, v = _mk(1, 16, 48, 2, 1, 8, hv=12)
    q_pos = jnp.broadcast_to(jnp.arange(32, 48)[None], (1, 16))
    kv_valid = jnp.asarray(RNG.random((1, 48)) > 0.25).at[:, 0].set(True)
    want = _naive_sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid, causal=True)
    for n_splits in (1, 2, 4):
        got = flash_attention_merged(q, k, v, q_pos=q_pos,
                                     kv_valid=kv_valid, n_splits=n_splits,
                                     causal=True, block=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, err_msg=f"n={n_splits}")


def test_ring_requires_mesh_and_divisible_shapes():
    q, k, v = _mk(1, 16, 16, 1, 1, 8)
    q_pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16))
    kv_valid = jnp.ones((1, 16), bool)
    with pytest.raises(ValueError, match="mesh"):
        ring_flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid)
    if len(jax.devices()) > 1:
        mesh = auto_mesh((len(jax.devices()),), ("model",))
        q2, k2, v2 = _mk(1, 17, 17, 1, 1, 8)
        with pytest.raises(ValueError, match="divide"):
            ring_flash_attention(
                q2, k2, v2, q_pos=jnp.broadcast_to(
                    jnp.arange(17)[None], (1, 17)),
                kv_valid=jnp.ones((1, 17), bool), mesh=mesh)


# ---------------- dispatch resolution ----------------

def test_resolve_ring_upgrade_is_mesh_and_knob_gated():
    n = len(jax.devices())
    mesh = auto_mesh((n,), ("model",))
    # no ambient mesh -> never ring, knob or not
    assert dispatch.resolve_attention(
        "auto", 4096, 4096, ring_axis="model") == "flash"
    with mesh:
        got = dispatch.resolve_attention("auto", 4096, 4096,
                                         ring_axis="model")
        assert got == ("flash_ring" if n > 1 else "flash")
        # knob off -> today's resolution, mesh or not
        assert dispatch.resolve_attention("auto", 4096, 4096) == "flash"
        # non-divisible sequence dims stay on the single-device pick
        assert dispatch.resolve_attention(
            "auto", 4097, 4099, ring_axis="model") == "flash"
        # dualmode is a numerics contract the ring now honors (ISSUE 7):
        # blocked dualmode streams the snapped int kernel, and the ring
        # upgrade applies on top of it exactly like the float path
        assert dispatch.resolve_attention(
            "auto", 4096, 4096, softmax_impl="dualmode",
            ring_axis="model") == (
                "flash_ring" if n > 1 else "flash_pallas_int")
        # short rows never stream, ring or not
        assert dispatch.resolve_attention(
            "auto", 1, 4096, ring_axis="model") == "naive"


def test_explicit_ring_plus_dualmode_resolves_and_matches():
    """ISSUE 7: dualmode + ring is a supported pairing — each hop runs
    the one-sweep snapped kernel and partials fold with the int monoid,
    so the ring output matches the single-device snapped kernel."""
    assert dispatch.resolve_attention(
        "flash_ring", 4096, 4096,
        softmax_impl="dualmode") == "flash_ring"
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >1 device for a ring")
    from repro.kernels.flash_attention_int import flash_attention_pallas_int
    mesh = auto_mesh((n,), ("model",))
    b, s, t = 2, 4 * n, 8 * n
    q, k, v = _mk(b, s, t, 2, 2, 16)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None] + (t - s), (b, s))
    kv_valid = jnp.ones((b, t), bool)
    with mesh:
        got = ring_flash_attention(q, k, v, q_pos=q_pos,
                                   kv_valid=kv_valid,
                                   softmax_impl="dualmode")
    want = flash_attention_pallas_int(q, k, v, q_pos=q_pos,
                                      kv_valid=kv_valid, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_serve_engine_resolves_ring_prefill_per_phase():
    """An engine given a mesh + a ring_axis config resolves long-context
    prefill to the ring path while decode (s_q=1) stays naive."""
    from repro.configs import registry
    from repro.models.transformer import init_lm
    from repro.serve import ServeEngine
    n = len(jax.devices())
    cfg = registry.reduced_config("qwen1.5-0.5b").replace(
        vocab=64, ring_axis="model")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    mesh = auto_mesh((n,), ("model",))
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=4096,
                      prefill_buckets=(2048,), mesh=mesh)
    want_prefill = "flash_ring" if n > 1 else "flash"
    assert eng.prefill_attn_impl == want_prefill
    assert eng.decode_attn_impl == "naive"
    # the compiled prefill runs at EVERY bucket: one non-dividing bucket
    # (36 % ring != 0) must veto the ring for the whole phase, not crash
    # the first short prompt at runtime
    eng2 = ServeEngine(cfg, params, n_slots=2, max_seq=4096,
                       prefill_buckets=(36, 2048), mesh=mesh)
    assert eng2.prefill_attn_impl == "flash"


def test_gqa_layer_forward_through_ring_matches_naive():
    """The full model-layer path (AttnSpec.ring_axis -> _sdpa -> registry
    entry -> shard_map) with an EXPLICIT flash_ring impl under a mesh."""
    from repro.models.attention import AttnSpec, gqa_apply, gqa_init
    mesh, _ = _ring_mesh(32, 32)
    base = dict(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    spec_ring = AttnSpec(**base, attn_impl="flash_ring",
                         ring_axis="model")
    spec_naive = AttnSpec(**base, attn_impl="naive")
    p = gqa_init(jax.random.PRNGKey(0), spec_ring, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 32, 32)), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    with mesh:
        got, _ = gqa_apply(p, spec_ring, x, positions=positions)
    want, _ = gqa_apply(p, spec_naive, x, positions=positions)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


# ---------------- the 8-device gold test (acceptance criterion) ----------

def test_ring_8dev_parity_vs_single_device_pallas(subproc):
    """flash_ring output and dq/dk/dv vs single-device flash_pallas
    <= 1e-5 on an emulated 8-device mesh — ISSUE 4 acceptance."""
    code = '''
import jax, jax.numpy as jnp
import numpy as np
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ring_attention import ring_flash_attention
from repro.launch.mesh import auto_mesh

rng = np.random.default_rng(3)
b, s, t, kh, g, h, hv = 2, 64, 128, 2, 3, 16, 16
q = jnp.asarray(rng.normal(size=(b, s, kh, g, h)), jnp.float32)
k = jnp.asarray(rng.normal(size=(b, t, kh, h)), jnp.float32)
v = jnp.asarray(rng.normal(size=(b, t, kh, hv)), jnp.float32)
q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (b, s))
valid = jnp.asarray(rng.random((b, t)) > 0.2).at[:, 0].set(True)
mesh = auto_mesh((8,), ("model",))
assert mesh.shape["model"] == 8

out_r = ring_flash_attention(q, k, v, q_pos=q_pos, kv_valid=valid,
                             mesh=mesh, interpret=True)
out_s = flash_attention_pallas(q, k, v, q_pos=q_pos, kv_valid=valid,
                               interpret=True)
d_out = float(jnp.abs(out_r - out_s).max())
assert d_out <= 1e-5, d_out

def g_of(fn):
    return jax.grad(lambda q_, k_, v_: fn(q_, k_, v_).sum(),
                    argnums=(0, 1, 2))(q, k, v)
g_r = g_of(lambda *a: ring_flash_attention(
    *a, q_pos=q_pos, kv_valid=valid, mesh=mesh, interpret=True))
g_s = g_of(lambda *a: flash_attention_pallas(
    *a, q_pos=q_pos, kv_valid=valid, interpret=True))
for name, a, b_ in zip(("dq", "dk", "dv"), g_r, g_s):
    d = float(jnp.abs(a - b_).max())
    assert d <= 1e-5, (name, d)
print("RING_8DEV_OK", d_out)
'''
    assert "RING_8DEV_OK" in subproc(code, n_devices=8)
