"""Make ``hypothesis`` optional for the test suite.

The real library is used when installed (see requirements-dev.txt).  When
it is missing — the tier-1 CI image ships only jax + pytest — property
tests fall back to a small deterministic sweep over each strategy's
boundary/representative values instead of failing at collection time.
The fallback intentionally mirrors only the four strategies this suite
uses (integers, floats, booleans, sampled_from).
"""
from __future__ import annotations

import itertools

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class st:  # noqa: N801  (mimics hypothesis.strategies module)
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            return _Strategy(dict.fromkeys([min_value, mid, max_value]))

        @staticmethod
        def floats(min_value, max_value):
            mid = 0.5 * (min_value + max_value)
            return _Strategy(dict.fromkeys([min_value, mid, max_value]))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    def settings(**_kwargs):
        return lambda f: f

    def given(*strategies):
        def deco(f):
            def wrapper():
                pools = [s.samples for s in strategies]
                for combo in itertools.islice(
                        itertools.product(*pools), 32):
                    f(*combo)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
