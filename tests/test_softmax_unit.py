"""The paper's dual-mode unit: accuracy claims of §IV / Table I.

Bounds mirror the paper: proposed GELU error ~1e-3 regime, strictly
better than i-GELU; softmax within fixed-point tolerance of FP32.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import igelu, softmax_unit as unit
from repro.core.activations import (gelu_exact, gelu_tanh, gelu_via_softmax,
                                    silu)
from repro.core.pwl import pwl_max_error

RNG = np.random.default_rng(0)


def test_pwl_fit_quality():
    e_exp, e_log = pwl_max_error()
    assert e_exp < 2e-3, e_exp     # 8-piece PWL of 2^v on [0,1)
    assert e_log < 4e-3, e_log


# ---------------- softmax (normal mode) ----------------

@pytest.mark.parametrize("n", [2, 8, 32, 128, 1000])
def test_softmax_matches_fp32(n):
    x = jnp.asarray(RNG.normal(size=(16, n)) * 4, jnp.float32)
    y = unit.softmax_dualmode(x)
    ref = jax.nn.softmax(x, axis=-1)
    assert float(jnp.abs(y - ref).max()) < 6e-3


def test_softmax_rows_sum_to_one():
    x = jnp.asarray(RNG.normal(size=(64, 33)) * 8, jnp.float32)
    y = unit.softmax_dualmode(x)
    np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, atol=2e-2)


@given(st.integers(2, 64), st.floats(0.1, 10.0))
@settings(max_examples=50, deadline=None)
def test_softmax_bounded_and_finite(n, scale):
    x = jnp.asarray(RNG.normal(size=(4, n)) * scale, jnp.float32)
    y = unit.softmax_dualmode(x)
    assert bool(jnp.all((y >= 0) & (y <= 1.0 + 1e-3)))


def test_softmax_extreme_inputs():
    x = jnp.asarray([[-32.0, 31.9, 0.0, -31.9]], jnp.float32)
    y = unit.softmax_dualmode(x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(y[0, 1]) > 0.99


# ---------------- GELU mode (Table I analogue) ----------------

def _act_inputs():
    """Activation-scale inputs: pre-GELU values in transformers are
    O(1)-O(5); include tails."""
    return jnp.asarray(np.concatenate([
        RNG.normal(size=4096) * 1.5,
        RNG.normal(size=512) * 5.0,
        np.linspace(-8, 8, 512)]), jnp.float32)


def test_gelu_mae_matches_paper_regime():
    z = _act_inputs()
    mae_prop = float(jnp.abs(unit.gelu_dualmode(z) - gelu_exact(z)).mean())
    mae_igelu = float(jnp.abs(igelu.igelu_quant(z) - gelu_exact(z)).mean())
    # paper Table I: proposed 3.9e-3..1.5e-2, i-GELU 5.4e-2..1.8e-1 (model
    # outputs); at activation level both are smaller but strictly ordered
    assert mae_prop < 2e-2, mae_prop
    assert mae_prop < mae_igelu, (mae_prop, mae_igelu)


def test_gelu_mode_vs_float_identity():
    """Eq. 8 in float == tanh-GELU (exact algebraic identity)."""
    z = _act_inputs()
    np.testing.assert_allclose(np.asarray(gelu_via_softmax(z)),
                               np.asarray(gelu_tanh(z)), atol=1e-5)


def test_gelu_int_error_vs_tanh_reference():
    """The quantized unit approximates ITS OWN math (tanh form) tightly."""
    z = _act_inputs()
    err = float(jnp.abs(unit.gelu_dualmode(z) - gelu_tanh(z)).max())
    assert err < 2e-2, err


@given(st.floats(-30.0, 30.0))
@settings(max_examples=200, deadline=None)
def test_gelu_pointwise_sane(z):
    y = float(unit.gelu_dualmode(jnp.asarray([z], jnp.float32))[0])
    ref = float(gelu_exact(jnp.asarray([z], jnp.float32))[0])
    assert abs(y - ref) < 0.06 + 0.002 * abs(z)


def test_silu_exact_identity_mode():
    z = _act_inputs()
    err = float(jnp.abs(unit.silu_dualmode(z) - silu(z)).max())
    assert err < 2e-2, err


def test_gelu_monotone_on_positive():
    z = jnp.linspace(0.0, 8.0, 256)
    y = np.asarray(unit.gelu_dualmode(z))
    assert (np.diff(y) >= -2e-3).all()     # quantization jitter allowed
