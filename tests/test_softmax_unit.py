"""The paper's dual-mode unit: accuracy claims of §IV / Table I.

Bounds mirror the paper: proposed GELU error ~1e-3 regime, strictly
better than i-GELU; softmax within fixed-point tolerance of FP32.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import igelu, softmax_unit as unit
from repro.core.activations import (gelu_exact, gelu_tanh, gelu_via_softmax,
                                    silu)
from repro.core.pwl import pwl_max_error

RNG = np.random.default_rng(0)


def test_pwl_fit_quality():
    e_exp, e_log = pwl_max_error()
    assert e_exp < 2e-3, e_exp     # 8-piece PWL of 2^v on [0,1)
    assert e_log < 4e-3, e_log


# ---------------- softmax (normal mode) ----------------

@pytest.mark.parametrize("n", [2, 8, 32, 128, 1000])
def test_softmax_matches_fp32(n):
    x = jnp.asarray(RNG.normal(size=(16, n)) * 4, jnp.float32)
    y = unit.softmax_dualmode(x)
    ref = jax.nn.softmax(x, axis=-1)
    assert float(jnp.abs(y - ref).max()) < 6e-3


def test_softmax_rows_sum_to_one():
    x = jnp.asarray(RNG.normal(size=(64, 33)) * 8, jnp.float32)
    y = unit.softmax_dualmode(x)
    np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, atol=2e-2)


@given(st.integers(2, 64), st.floats(0.1, 10.0))
@settings(max_examples=50, deadline=None)
def test_softmax_bounded_and_finite(n, scale):
    x = jnp.asarray(RNG.normal(size=(4, n)) * scale, jnp.float32)
    y = unit.softmax_dualmode(x)
    assert bool(jnp.all((y >= 0) & (y <= 1.0 + 1e-3)))


def test_softmax_extreme_inputs():
    x = jnp.asarray([[-32.0, 31.9, 0.0, -31.9]], jnp.float32)
    y = unit.softmax_dualmode(x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(y[0, 1]) > 0.99


# ---------------- GELU mode (Table I analogue) ----------------

def _act_inputs():
    """Activation-scale inputs: pre-GELU values in transformers are
    O(1)-O(5); include tails."""
    return jnp.asarray(np.concatenate([
        RNG.normal(size=4096) * 1.5,
        RNG.normal(size=512) * 5.0,
        np.linspace(-8, 8, 512)]), jnp.float32)


def test_gelu_mae_matches_paper_regime():
    z = _act_inputs()
    mae_prop = float(jnp.abs(unit.gelu_dualmode(z) - gelu_exact(z)).mean())
    mae_igelu = float(jnp.abs(igelu.igelu_quant(z) - gelu_exact(z)).mean())
    # paper Table I: proposed 3.9e-3..1.5e-2, i-GELU 5.4e-2..1.8e-1 (model
    # outputs); at activation level both are smaller but strictly ordered
    assert mae_prop < 2e-2, mae_prop
    assert mae_prop < mae_igelu, (mae_prop, mae_igelu)


def test_gelu_mode_vs_float_identity():
    """Eq. 8 in float == tanh-GELU (exact algebraic identity)."""
    z = _act_inputs()
    np.testing.assert_allclose(np.asarray(gelu_via_softmax(z)),
                               np.asarray(gelu_tanh(z)), atol=1e-5)


def test_gelu_int_error_vs_tanh_reference():
    """The quantized unit approximates ITS OWN math (tanh form) tightly."""
    z = _act_inputs()
    err = float(jnp.abs(unit.gelu_dualmode(z) - gelu_tanh(z)).max())
    assert err < 2e-2, err


@given(st.floats(-30.0, 30.0))
@settings(max_examples=200, deadline=None)
def test_gelu_pointwise_sane(z):
    y = float(unit.gelu_dualmode(jnp.asarray([z], jnp.float32))[0])
    ref = float(gelu_exact(jnp.asarray([z], jnp.float32))[0])
    assert abs(y - ref) < 0.06 + 0.002 * abs(z)


def test_silu_exact_identity_mode():
    z = _act_inputs()
    err = float(jnp.abs(unit.silu_dualmode(z) - silu(z)).max())
    assert err < 2e-2, err


def test_gelu_monotone_on_positive():
    z = jnp.linspace(0.0, 8.0, 256)
    y = np.asarray(unit.gelu_dualmode(z))
    assert (np.diff(y) >= -2e-3).all()     # quantization jitter allowed


# ---------------- snapped-max word monoid (ISSUE 7) ----------------
# The power-of-two max snap makes the online int recurrence a TRUE word
# monoid: (m, S, acc) partials merge with exact shifts, associatively,
# with (SNAP_MIN, 0, 0) the identity.  These properties are what the
# one-sweep kernel, the dual-mode decode fold, and the dual-mode ring
# all lean on, so they are pinned here at the word level.

def _snap_part(x, guard, v=None):
    return unit.online_partial_int(x, guard, v)


def _assert_parts_equal(a, b, acc_rtol=0.0):
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    if acc_rtol:
        # acc is f32: the power-of-two rescales are exact but the @v adds
        # are order-dependent, so the slack is RELATIVE f32 epsilon
        np.testing.assert_allclose(np.asarray(a[2]), np.asarray(b[2]),
                                   rtol=acc_rtol, atol=1e-4)
    else:
        np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[2]))


def test_snap_softmax_tracks_float_and_classic_unit():
    x = jnp.asarray(RNG.normal(size=(16, 64)) * 4, jnp.float32)
    y = unit.softmax_snap(unit.quantize(x))
    ref = jax.nn.softmax(x, axis=-1)
    assert float(jnp.abs(y - ref).max()) < 6e-3
    classic = unit.softmax_dualmode(x)
    # snapping the max moves prob words by at most one octave fraction
    assert float(jnp.abs(y - classic).max()) < 2e-3
    np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, atol=2e-2)


@pytest.mark.parametrize("n,block", [(8, 8), (33, 8), (100, 16), (7, 3),
                                     (1000, 128), (513, 512)])
def test_snap_blocked_telescopes_bitexact(n, block):
    """Any blocking of the snapped online fold == whole-row snapped
    words, bit for bit — including non-divisible tails."""
    x = unit.quantize(jnp.asarray(RNG.normal(size=(16, n)) * 5,
                                  jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(unit.softmax_snap_blocked(x, block)),
        np.asarray(unit.softmax_snap(x)))


@given(st.integers(1, 46), st.integers(1, 20))
@settings(max_examples=40, deadline=None)
def test_snap_merge_associative(i, j):
    """(a . b) . c == a . (b . c) on the int words for ANY chunking —
    the law the ring's hop order and the decode's split fold rely on."""
    n = 48
    x = unit.quantize(jnp.asarray(RNG.normal(size=(4, n)) * 5,
                                  jnp.float32))
    v = jnp.asarray(RNG.normal(size=(4, n, 8)), jnp.float32)
    lo, hi = sorted((i, min(n - 1, i + j)))
    if lo == hi:
        hi = lo + 1
    g = 0
    a = _snap_part(x[:, :lo], g, v[:, :lo])
    b = _snap_part(x[:, lo:hi], g, v[:, lo:hi])
    c = _snap_part(x[:, hi:], g, v[:, hi:])
    left = unit.online_merge_int(unit.online_merge_int(a, b), c)
    right = unit.online_merge_int(a, unit.online_merge_int(b, c))
    # m and S are pure int words: exact.  acc is f32 with power-of-two
    # rescales (exact) but order-dependent adds: allclose at f32 eps.
    _assert_parts_equal(left, right, acc_rtol=1e-5)


@given(st.integers(1, 47))
@settings(max_examples=40, deadline=None)
def test_snap_split_point_invariance(i):
    """Folding [0:i] with [i:n] reproduces the whole-row partial's words
    exactly, for every split point."""
    n = 48
    x = unit.quantize(jnp.asarray(RNG.normal(size=(4, n)) * 5,
                                  jnp.float32))
    v = jnp.asarray(RNG.normal(size=(4, n, 8)), jnp.float32)
    whole = _snap_part(x, 0, v)
    merged = unit.online_merge_int(_snap_part(x[:, :i], 0, v[:, :i]),
                                   _snap_part(x[:, i:], 0, v[:, i:]))
    _assert_parts_equal(whole, merged, acc_rtol=1e-5)


def test_snap_merge_sentinel_identity():
    """(SNAP_MIN, 0, 0) is the exact identity on BOTH sides — empty
    splits/hops are bitwise no-ops, not approximate ones."""
    x = unit.quantize(jnp.asarray(RNG.normal(size=(4, 32)) * 5,
                                  jnp.float32))
    part = _snap_part(x, 0)
    ident = (jnp.full_like(part[0], unit.SNAP_MIN),
             jnp.zeros_like(part[1]), jnp.zeros_like(part[2]))
    _assert_parts_equal(unit.online_merge_int(part, ident), part)
    _assert_parts_equal(unit.online_merge_int(ident, part), part)


def test_snap_merge_n_matches_pairwise():
    """The vectorized n-way fold == the pairwise fold, word-exact."""
    x = unit.quantize(jnp.asarray(RNG.normal(size=(4, 64)) * 5,
                                  jnp.float32))
    v = jnp.asarray(RNG.normal(size=(4, 64, 8)), jnp.float32)
    parts = [_snap_part(x[:, i:i + 16], 0, v[:, i:i + 16])
             for i in range(0, 64, 16)]
    m = jnp.stack([p[0] for p in parts])
    S = jnp.stack([p[1] for p in parts])
    acc = jnp.stack([p[2] for p in parts])
    mn, Sn, accn = unit.online_merge_n_int(m, S, acc, axis=0)
    pair = parts[0]
    for p in parts[1:]:
        pair = unit.online_merge_int(pair, p)
    np.testing.assert_array_equal(np.asarray(mn[0]), np.asarray(pair[0]))
    np.testing.assert_array_equal(np.asarray(Sn[0]), np.asarray(pair[1]))
    np.testing.assert_allclose(np.asarray(accn[0]), np.asarray(pair[2]),
                               rtol=1e-5, atol=1e-4)


def test_snap_guard_shift_long_rows():
    """Rows past 2**16 engage guard_shift > 0; the blocked fold must use
    the identical guard so the bucket words never overflow int32 and the
    whole-row telescoping stays bitwise."""
    n = (1 << 16) + 17                       # bit_length 17 -> guard 1
    x = unit.quantize(jnp.asarray(RNG.normal(size=(2, n)) * 3,
                                  jnp.float32))
    got = unit.softmax_snap_blocked(x, 1 << 12)
    want = unit.softmax_snap(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert bool(jnp.all(jnp.isfinite(want)))
    # at 65k keys the floor losses in l (one word per bucket per block of
    # the >> d) bias the sum a few percent high — bounded, not drifting
    np.testing.assert_allclose(float(want.sum(-1).max()), 1.0, atol=1e-1)


def test_snap_phantom_words_carry_zero_mass():
    """PHANTOM_Q maps to the SNAP_MIN sentinel in the t domain: appending
    phantoms changes neither the snapped max, any bucket word, nor any
    output word."""
    x = unit.quantize(jnp.asarray(RNG.normal(size=(4, 37)) * 5,
                                  jnp.float32))
    xp = jnp.concatenate(
        [x, jnp.full((4, 27), unit.PHANTOM_Q, jnp.int32)], axis=-1)
    got = unit.softmax_snap(xp, guard_shift=0)[:, :37]
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(unit.softmax_snap(x, guard_shift=0)))
    assert float(jnp.abs(unit.softmax_snap(xp, guard_shift=0)[:, 37:]).max()) == 0.0
