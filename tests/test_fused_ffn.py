"""Fused GLU kernel (interpret mode) vs unfused oracle: shape/dtype/mode
sweep per the kernel-testing requirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_ffn import fused_glu_pallas
from repro.kernels.ref import fused_glu_ref

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("m,k,f", [(16, 32, 64), (64, 128, 256),
                                   (128, 64, 512), (32, 100, 96)])
@pytest.mark.parametrize("mode", ["silu", "gelu"])
def test_fused_glu_matches_ref(m, k, f, mode):
    x = jnp.asarray(RNG.normal(size=(m, k)) * 0.5, jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(k, f)) / k ** 0.5, jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(k, f)) / k ** 0.5, jnp.float32)
    y = fused_glu_pallas(x, wg, wu, mode=mode, interpret=True, bm=16, bf=32)
    want = fused_glu_ref(x, wg, wu, mode)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_glu_dtypes(dtype):
    x = jnp.asarray(RNG.normal(size=(32, 64)), dtype)
    wg = jnp.asarray(RNG.normal(size=(64, 128)) * 0.1, dtype)
    wu = jnp.asarray(RNG.normal(size=(64, 128)) * 0.1, dtype)
    y = fused_glu_pallas(x, wg, wu, interpret=True, bm=16, bf=64)
    assert y.dtype == dtype
    want = fused_glu_ref(x, wg, wu)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2)


def test_mlp_fused_impl_exact_for_non_fusable_activation():
    """ffn_impl='fused_pallas' must not silently approximate activations
    the fused epilogue cannot compute (relu2, dualmode/igelu variants) —
    those fall back to the dense path bit-for-bit."""
    import jax
    from repro.models.layers import mlp, mlp_init
    x = jnp.asarray(RNG.normal(size=(2, 6, 32)), jnp.float32)
    p = mlp_init(jax.random.PRNGKey(0), 32, 64, jnp.float32, gated=True)
    for act in ("relu2", "gelu_dualmode", "igelu", "gelu_exact"):
        fused = mlp(p, x, act, impl="fused_pallas")
        dense = mlp(p, x, act, impl="dense")
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(dense))
    # fusable activations really do take the kernel: bitwise-different
    # from the dense graph (different fusion) yet equal within tolerance
    for act in ("silu", "gelu_tanh"):
        fused = mlp(p, x, act, impl="fused_pallas")
        dense = mlp(p, x, act, impl="dense")
        assert not np.array_equal(np.asarray(fused), np.asarray(dense)), \
            f"{act}: fused path produced dense-path bits — kernel not taken?"
        np.testing.assert_allclose(np.asarray(fused), np.asarray(dense),
                                   atol=1e-5)


def test_ffn_auto_resolves_by_backend(monkeypatch):
    """ffn_impl='auto' (ROADMAP open item): fused_pallas on TPU, dense
    elsewhere — explicit strings pass through untouched on any backend."""
    from repro.kernels import dispatch
    assert dispatch.resolve_ffn("auto") == "dense"        # this CPU host
    assert dispatch.resolve_ffn("dense") == "dense"
    assert dispatch.resolve_ffn("fused_pallas") == "fused_pallas"
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert dispatch.resolve_ffn("auto") == "fused_pallas"
    assert dispatch.resolve_ffn("dense") == "dense"
    assert dispatch.resolve_ffn("fused_pallas") == "fused_pallas"
    with pytest.raises(ValueError, match="unknown ffn impl"):
        dispatch.get_ffn("no_such_impl")


def test_mlp_auto_is_dense_off_tpu():
    """On this host 'auto' IS the dense path — bit-identical output."""
    from repro.models.layers import mlp, mlp_init
    x = jnp.asarray(RNG.normal(size=(2, 6, 32)), jnp.float32)
    p = mlp_init(jax.random.PRNGKey(1), 32, 64, jnp.float32, gated=True)
    np.testing.assert_array_equal(np.asarray(mlp(p, x, "silu", impl="auto")),
                                  np.asarray(mlp(p, x, "silu",
                                                 impl="dense")))


def test_fused_glu_grad_matches_unfused_reference():
    """Custom VJP (backward via the unfused reference graph) — the train
    path with ffn_impl='fused_pallas' depends on this differentiating."""
    x = jnp.asarray(RNG.normal(size=(16, 32)) * 0.5, jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(32, 64)) * 0.2, jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(32, 64)) * 0.2, jnp.float32)
    gk = jax.grad(lambda *a: fused_glu_pallas(
        *a, mode="silu", interpret=True).sum(), argnums=(0, 1, 2))(x, wg, wu)
    gr = jax.grad(lambda *a: fused_glu_ref(*a, "silu").sum(),
                  argnums=(0, 1, 2))(x, wg, wu)
    for a, b in zip(gk, gr):
        assert bool(jnp.all(jnp.isfinite(a)))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_fused_glu_blocks_resolve_before_jit_no_recompile():
    """bm/bf used to be jit-static kwargs that tiling.matmul_blocks then
    second-guessed inside the trace: every distinct caller hint compiled
    a new kernel whose requested value was partially ignored.  Blocks now
    resolve BEFORE the jit boundary, so the default and an explicit hint
    equal to the resolved default share ONE cache entry — and explicit
    hints are honored (rounded up to the hardware alignment)."""
    from repro.kernels import tiling
    from repro.kernels.fused_ffn import _fused_glu_jit
    x = jnp.asarray(RNG.normal(size=(48, 32)) * 0.5, jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(32, 64)) * 0.2, jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(32, 64)) * 0.2, jnp.float32)
    rbm, rbf = tiling.matmul_blocks(48, 64)
    base = _fused_glu_jit._cache_size()
    y0 = fused_glu_pallas(x, wg, wu, interpret=True)            # policy
    y1 = fused_glu_pallas(x, wg, wu, interpret=True,
                          bm=rbm, bf=rbf)                       # same blocks
    assert _fused_glu_jit._cache_size() - base <= 1
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    # an explicit different hint IS honored (new compilation, same math)
    y2 = fused_glu_pallas(x, wg, wu, interpret=True, bm=16, bf=32)
    np.testing.assert_allclose(np.asarray(y2),
                               np.asarray(fused_glu_ref(x, wg, wu)),
                               atol=2e-5, rtol=2e-5)


def test_fused_glu_odd_tiles():
    """Block pickers must handle non-power-of-two dims."""
    x = jnp.asarray(RNG.normal(size=(48, 20)), jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(20, 72)) * 0.2, jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(20, 72)) * 0.2, jnp.float32)
    y = fused_glu_pallas(x, wg, wu, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(fused_glu_ref(x, wg, wu)),
                               atol=2e-5, rtol=2e-5)


def test_fusable_act_table_parity_pinned_per_entry():
    """The _FUSABLE_ACT table claims each entry agrees MATHEMATICALLY
    with the fused epilogue (datapath.pair_act) — identity-level, not
    bitwise (gelu_tanh routes through tanh(k) = 2*sigma(2k)-1, the
    *_via_softmax forms through the two-element pair softmax).  Pin the
    fused-vs-dense residual per entry: a few ULPs of reassociation, far
    below any approximation error — if an entry ever drifts past this,
    it no longer belongs in the table."""
    from repro.models.layers import _FUSABLE_ACT, mlp, mlp_init
    tol = {"gelu_tanh": 2e-6, "gelu_via_softmax": 1e-6,
           "silu": 1e-6, "silu_via_softmax": 1e-6}
    assert set(tol) == set(_FUSABLE_ACT)       # table and pins in lockstep
    x = jnp.asarray(RNG.normal(size=(2, 6, 64)), jnp.float32)
    p = mlp_init(jax.random.PRNGKey(2), 64, 128, jnp.float32, gated=True)
    for act, mode in _FUSABLE_ACT.items():
        assert mode in ("gelu", "silu")
        fused = mlp(p, x, act, impl="fused_pallas")
        dense = mlp(p, x, act, impl="dense")
        err = float(jnp.abs(fused - dense).max())
        assert err <= tol[act], (act, err)
