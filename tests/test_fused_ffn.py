"""Fused GLU kernel (interpret mode) vs unfused oracle: shape/dtype/mode
sweep per the kernel-testing requirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_ffn import fused_glu_pallas
from repro.kernels.ref import fused_glu_ref

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("m,k,f", [(16, 32, 64), (64, 128, 256),
                                   (128, 64, 512), (32, 100, 96)])
@pytest.mark.parametrize("mode", ["silu", "gelu"])
def test_fused_glu_matches_ref(m, k, f, mode):
    x = jnp.asarray(RNG.normal(size=(m, k)) * 0.5, jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(k, f)) / k ** 0.5, jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(k, f)) / k ** 0.5, jnp.float32)
    y = fused_glu_pallas(x, wg, wu, mode=mode, interpret=True, bm=16, bf=32)
    want = fused_glu_ref(x, wg, wu, mode)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_glu_dtypes(dtype):
    x = jnp.asarray(RNG.normal(size=(32, 64)), dtype)
    wg = jnp.asarray(RNG.normal(size=(64, 128)) * 0.1, dtype)
    wu = jnp.asarray(RNG.normal(size=(64, 128)) * 0.1, dtype)
    y = fused_glu_pallas(x, wg, wu, interpret=True, bm=16, bf=64)
    assert y.dtype == dtype
    want = fused_glu_ref(x, wg, wu)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2)


def test_fused_glu_odd_tiles():
    """Block pickers must handle non-power-of-two dims."""
    x = jnp.asarray(RNG.normal(size=(48, 20)), jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(20, 72)) * 0.2, jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(20, 72)) * 0.2, jnp.float32)
    y = fused_glu_pallas(x, wg, wu, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(fused_glu_ref(x, wg, wu)),
                               atol=2e-5, rtol=2e-5)
