"""Blocked online-softmax attention vs the naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models.attention import _sdpa
from repro.models.flash import flash_attention, use_flash

RNG = np.random.default_rng(2)


def _mk(b, s, t, k, g, h, hv=None):
    hv = hv or h
    q = jnp.asarray(RNG.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(RNG.normal(size=(b, t, k, h)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, t, k, hv)), jnp.float32)
    return q, kk, v


def _naive(q, k, v, q_pos, kv_valid, causal):
    return _sdpa(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                 softmax_impl="float", causal=causal)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("block", [16, 64, 128])
def test_flash_matches_naive(causal, block):
    q, k, v = _mk(2, 64, 128, 2, 3, 16)
    q_pos = jnp.broadcast_to(jnp.arange(64, 128)[None], (2, 64))
    kv_valid = jnp.ones((2, 128), bool)
    out = flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                          causal=causal, block=block)
    want = _naive(q, k, v, q_pos, kv_valid, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-6)


def test_flash_mla_style_hv_differs():
    q, k, v = _mk(2, 32, 32, 4, 1, 24, hv=12)   # qk head 24, v head 12
    q_pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    valid = jnp.ones((2, 32), bool)
    out = flash_attention(q, k, v, q_pos=q_pos, kv_valid=valid, block=8)
    want = _naive(q, k, v, q_pos, valid, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-6)


@given(st.integers(1, 3), st.sampled_from([32, 48, 64]),
       st.integers(0, 40), st.booleans())
@settings(max_examples=30, deadline=None)
def test_flash_partial_validity_property(b, t, n_valid, causal):
    n_valid = min(n_valid, t)
    q, k, v = _mk(b, 16, t, 1, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(t - 16, t)[None], (b, 16))
    kv_valid = jnp.broadcast_to(jnp.arange(t)[None] < max(n_valid, 1), (b, t))
    out = flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                          causal=causal, block=16)
    want = _naive(q, k, v, q_pos, kv_valid, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-6)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_flash_bf16_io():
    q, k, v = _mk(1, 32, 64, 2, 2, 16)
    q, k, v = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    q_pos = jnp.broadcast_to(jnp.arange(32, 64)[None], (1, 32))
    valid = jnp.ones((1, 64), bool)
    out = flash_attention(q, k, v, q_pos=q_pos, kv_valid=valid, block=16)
    want = _naive(q, k, v, q_pos, valid, True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


def test_use_flash_threshold():
    assert not use_flash(1, 32768)          # decode: split-KV/naive path
    assert use_flash(4096, 4096)            # train_4k: blocked
    assert use_flash(32768, 32768)          # prefill_32k: blocked
    assert not use_flash(64, 64)
    # no divisibility condition: pad-and-slice handles ragged T, so long
    # non-512-multiple contexts must NOT fall back to materialized scores
    assert use_flash(4096, 4097)
    assert use_flash(32768, 33000)


def test_auto_blocked_pick_is_backend_aware(monkeypatch):
    """'auto' streams through the compiled Pallas kernel on TPU and the
    pure-JAX blocked path on interpret backends (BENCH_flash.json:
    interpret-mode Pallas ~2.5x slower than flash_jax at the same
    shape).  Explicit impl strings are never rewritten."""
    from repro.kernels import dispatch
    from repro.models.flash import blocked_impl
    assert blocked_impl("tpu") == "flash_pallas"
    assert blocked_impl("cpu") == "flash"
    assert blocked_impl("gpu") == "flash"
    # this host (CPU/interpret): resolution unchanged from the seed rule
    assert dispatch.resolve_attention("auto", 4096, 4096) == "flash"
    assert dispatch.resolve_attention("auto", 64, 64) == "naive"
    # simulated TPU: blocked picks go to the compiled kernel; everything
    # else about resolution — naive short rows, dualmode routing, the
    # explicit-impl passthrough — is unchanged
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert dispatch.resolve_attention("auto", 4096, 4096) == "flash_pallas"
    assert dispatch.resolve_attention("auto", 64, 64) == "naive"
    assert dispatch.resolve_attention(
        "auto", 4096, 4096, softmax_impl="dualmode") == "flash_pallas_int"
    assert dispatch.resolve_attention("flash", 4096, 4096) == "flash"


def test_flash_grad_finite():
    q, k, v = _mk(1, 32, 32, 1, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(32)[None], (1, 32))
    valid = jnp.ones((1, 32), bool)

    def loss(q_):
        return flash_attention(q_, k, v, q_pos=q_pos, kv_valid=valid,
                               block=8).sum()

    g = jax.grad(loss)(q)
    assert bool(jnp.all(jnp.isfinite(g)))
    # matches naive-path gradient
    g2 = jax.grad(lambda q_: _naive(q_, k, v, q_pos, valid, True).sum())(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g2), atol=1e-5)
