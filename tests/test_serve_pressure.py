"""Serving under pressure: reactive admission + preemption (recompute
and swap), head-of-line skip-ahead, deadlines, the numeric sentry,
starvation surfacing, table-corruption containment, and the
byte-identical admission-rollback property.

The preemption parity tests are the load-bearing ones: a pool sized
well below the workload's worst-case demand must force preemptions, and
the outputs must still be TOKEN-FOR-TOKEN identical to an ample-pool
run — greedy decode makes recompute-on-resume exact, and swap restores
the very bytes it saved.

The rollback property test is hypothesis-compatible in the
test_paged_cache.py style: drawn by hypothesis when the package exists,
seeded PRNG otherwise."""
import random

import jax
import pytest

from repro.configs import registry
from repro.kernels import tiling
from repro.models.transformer import init_lm
from repro.serve import Request, ServeEngine
from repro.serve.engine import _QEntry
from repro.serve.faults import FaultInjector, chaos_soak
from repro.serve.paged_cache import BlockPool, chain_hashes

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def model():
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk_reqs():
    return [Request(rid=0, prompt=list(range(5, 25)), max_new=6),
            Request(rid=1, prompt=list(range(7, 40)), max_new=8),
            Request(rid=2, prompt=[3, 1, 4, 1, 5, 9, 2, 6], max_new=5),
            Request(rid=3, prompt=list(range(5, 25)), max_new=4)]


def _paged(cfg, params, **kw):
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_seq", 64)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("seed", 0)
    return ServeEngine(cfg, params, cache_mode="paged", **kw)


# ---------------- preemption parity ----------------

def test_tight_pool_preempts_and_matches_ample(model):
    """Pool well under worst-case demand: the engine must preempt (the
    ample run never does) yet produce identical tokens, terminate every
    request with a reason, and leak nothing."""
    cfg, params = model
    ample = _paged(cfg, params)
    out_a = ample.run(_mk_reqs())
    assert ample.stats["preemptions"] == 0
    tight = _paged(cfg, params, num_blocks=9)
    out_t = tight.run(_mk_reqs())
    assert out_t == out_a
    assert tight.stats["preemptions"] > 0
    assert tight.stats["resumes"] > 0
    assert tight.pool.in_use() == 0
    assert all(tight.reasons[r.rid] for r in _mk_reqs())
    assert not tight.stats["starved"]


def test_swap_preemption_matches_recompute(model):
    """preempt_mode='swap' restores the saved block bytes instead of
    re-prefilling — same tokens, swap counters move, nothing leaks."""
    cfg, params = model
    base = _paged(cfg, params).run(_mk_reqs())
    sw = _paged(cfg, params, num_blocks=9, preempt_mode="swap")
    out = sw.run(_mk_reqs())
    assert out == base
    assert sw.stats["preemptions"] > 0
    assert sw.stats["swap_outs"] > 0
    assert sw.stats["swap_ins"] > 0
    assert sw.pool.in_use() == 0


def test_reactive_beats_worst_case_concurrency(model):
    """At the same undersized pool, reactive admission reaches a
    strictly higher concurrency high-water than worst-case reservation
    — the whole point of reserving less up front — while producing the
    same tokens.  Three decode-heavy requests each have a worst-case
    reach of 6 blocks (16 prompt + 30 new = 46 tokens, bs=8): the
    8-data-block pool holds only ONE worst-case reservation at a time,
    but all three 2-block prompt reaches side by side."""
    cfg, params = model
    bs = tiling.paged_block_size(64)
    reqs = [Request(rid=i, prompt=[100 * i + j + 1 for j in range(16)],
                    max_new=30) for i in range(3)]
    assert all(tiling.cdiv(len(r.prompt) + r.max_new, bs) == 6
               for r in reqs)
    hwm, outs = {}, {}
    for adm in ("worst_case", "reactive"):
        eng = _paged(cfg, params, num_blocks=9, admission=adm)
        for r in reqs:
            eng.submit(Request(**vars(r)))
        h = 0
        while eng.pending():
            eng.step()
            h = max(h, eng.active)
        hwm[adm], outs[adm] = h, dict(eng.finished)
        assert eng.pool.in_use() == 0, adm
    assert outs["reactive"] == outs["worst_case"]
    assert hwm["reactive"] > hwm["worst_case"], hwm


def test_priority_protects_high_priority_victim(model):
    """Preemption victims are chosen lowest-priority-first, and a grower
    never evicts a strictly higher-priority slot — it yields instead."""
    cfg, params = model
    reqs = [Request(rid=0, prompt=list(range(5, 25)), max_new=6,
                    priority=1),
            Request(rid=1, prompt=list(range(7, 40)), max_new=8),
            Request(rid=2, prompt=[3, 1, 4, 1, 5, 9, 2, 6], max_new=5),
            Request(rid=3, prompt=list(range(5, 25)), max_new=4)]
    eng = _paged(cfg, params, num_blocks=9)
    out = eng.run([Request(**vars(r)) for r in reqs])
    assert eng.stats["preemptions"] > 0
    # the high-priority request matches the ample run regardless
    ample = _paged(cfg, params).run([Request(**vars(r)) for r in reqs])
    assert out == ample
    assert eng.pool.in_use() == 0


# ---------------- satellite: starvation surfaced ----------------

def test_starvation_is_surfaced_not_silent(model):
    """max_steps exhaustion must flush everything still live with
    reason 'starved', deliver partial output, refund every block, and
    list the rids in stats['starved'] — it used to silently return a
    short dict and leak the pool."""
    cfg, params = model
    eng = _paged(cfg, params)
    out = eng.run(_mk_reqs(), max_steps=3)
    assert eng.stats["starved"]
    for r in _mk_reqs():
        assert r.rid in out
        assert r.rid in eng.reasons
    assert all(eng.reasons[rid] == "starved"
               for rid in eng.stats["starved"])
    assert eng.pool.in_use() == 0
    assert eng.pending() == 0


# ---------------- satellite: head-of-line skip-ahead ----------------

def test_hol_skip_ahead_admits_small_past_blocked_giant(model):
    """A small request admits past a pool-blocked giant within the
    skip-ahead window (counted in stats['hol_skips']); the giant still
    completes once capacity frees up."""
    cfg, params = model
    eng = _paged(cfg, params, n_slots=2, num_blocks=8)
    eng.submit(Request(rid=0, prompt=list(range(1, 31)), max_new=4))
    while not any(s.decoding for s in eng._slots):
        eng.step()                        # rid 0 holds 4 of 7 blocks
    # disjoint from rid 0's prompt: a shared prefix would collapse the
    # giant's fresh-block demand below the pool and let it admit
    eng.submit(Request(rid=1, prompt=list(range(100, 140)), max_new=4))
    eng.submit(Request(rid=2, prompt=[9, 8, 7], max_new=3))
    eng.step()
    assert eng.stats["hol_skips"] >= 1    # rid 2 skipped past rid 1
    assert eng._slots[1].rid == 2 or eng._slots[0].rid == 2
    out = eng.run([])
    assert sorted(out) == [0, 1, 2]       # the giant was not starved
    assert all(len(out[r]) == n for r, n in ((0, 4), (1, 4), (2, 3)))
    assert eng.pool.in_use() == 0


def test_hol_window_one_preserves_strict_fcfs(model):
    """hol_window=1 restores the old strict head-of-line behavior."""
    cfg, params = model
    eng = _paged(cfg, params, n_slots=2, num_blocks=8, hol_window=1)
    eng.submit(Request(rid=0, prompt=list(range(1, 31)), max_new=4))
    while not any(s.decoding for s in eng._slots):
        eng.step()
    eng.submit(Request(rid=1, prompt=list(range(100, 140)), max_new=4))
    eng.submit(Request(rid=2, prompt=[9, 8, 7], max_new=3))
    eng.step()
    assert eng.stats["hol_skips"] == 0
    out = eng.run([])
    assert sorted(out) == [0, 1, 2]


# ---------------- deadlines ----------------

def test_deadline_expires_queued_and_running(model):
    cfg, params = model
    clk = {"t": 0.0}
    eng = _paged(cfg, params, n_slots=2, clock=lambda: clk["t"])
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=50,
                       deadline_s=5.0))
    eng.submit(Request(rid=1, prompt=[4, 5, 6], max_new=4))
    for _ in range(6):
        eng.step()
    assert len(eng._slots[0].out) > 0     # rid 0 was decoding
    clk["t"] = 10.0                       # past rid 0's budget
    eng.submit(Request(rid=2, prompt=[7, 8], max_new=5, deadline_s=-1.0))
    eng.step()
    assert eng.reasons[0] == "deadline"
    assert 0 < len(eng.finished[0]) < 50  # partial output delivered
    assert eng.reasons[2] == "deadline"   # expired while queued
    assert eng.finished[2] == []
    out = eng.run([])                     # rid 1 unaffected
    assert eng.reasons[1] in ("max_new", "eos")
    assert len(out[1]) <= 4
    assert eng.pool.in_use() == 0
    assert eng.stats["deadlines"] == 2


# ---------------- numeric sentry + table corruption ----------------

def test_numeric_sentry_quarantines_single_slot(model):
    """NaN logits on one decode row retire ONLY that slot (reason
    'numeric', blocks refunded); every other request's tokens are
    bitwise identical to the fault-free run."""
    cfg, params = model
    base = _paged(cfg, params).run(_mk_reqs())
    inj = FaultInjector(0, nan_decode_step=6)
    eng = _paged(cfg, params, faults=inj)
    out = eng.run(_mk_reqs())
    bad = [r for r, why in eng.reasons.items() if why == "numeric"]
    assert bad == sorted(inj.affected) and len(bad) == 1
    assert eng.stats["numeric"] == 1
    for r in _mk_reqs():
        if r.rid not in inj.affected:
            assert out[r.rid] == base[r.rid], r.rid
    assert eng.pool.in_use() == 0


def test_table_corruption_detected_and_contained(model):
    cfg, params = model
    inj = FaultInjector(0, corrupt_step=4)
    eng = _paged(cfg, params, faults=inj)
    out = eng.run(_mk_reqs())
    bad = [r for r, why in eng.reasons.items() if why == "corrupt"]
    assert bad == sorted(inj.affected) and len(bad) == 1
    assert eng.stats["corrupt"] == 1
    assert sorted(out) == [0, 1, 2, 3]    # everyone terminated
    assert eng.pool.in_use() == 0


# ---------------- chaos soak ----------------

def test_chaos_soak_invariants(model):
    report = chaos_soak(seed=0)
    assert report["ok"], report["violations"]
    assert report["stats"]["preemptions"] > 0     # pressure was real
    assert report["injections"] > 0


# ---------------- satellite: admission rollback property ----------------

def _snapshot(pool: BlockPool):
    """Full observable pool state, LRU order included."""
    return (dict(pool._ref), list(pool._free), list(pool._cached),
            dict(pool._hash_to_block), dict(pool._block_hash))


def _rollback_property(seed: int):
    """A failed reserve() (the _admit_paged shortfall path) must leave
    the pool BYTE-IDENTICAL: refcounts, free list, cached-LRU order,
    and both prefix indexes."""
    rng = random.Random(seed)
    pool = BlockPool(num_blocks=rng.randint(4, 12), block_size=4)
    registered = []
    for _ in range(rng.randint(0, 3)):
        n = rng.randint(1, 3)
        blocks = pool.alloc(n)
        if blocks is None:
            break
        toks = [rng.randrange(1000) for _ in range(4 * n)]
        pool.register(chain_hashes(toks, 4), blocks)
        registered.append(toks)
        if rng.random() < 0.6:
            for b in blocks:
                pool.decref(b)            # park in the cached LRU
    if pool.available() > 1:
        pool.alloc(rng.randint(0, pool.available() - 1))   # hog
    snap = _snapshot(pool)
    if registered and rng.random() < 0.7:
        prompt = list(rng.choice(registered)) + [rng.randrange(1000)]
    else:
        prompt = [rng.randrange(1000) for _ in range(rng.randint(1, 9))]
    hashes = chain_hashes(prompt, 4)[:(len(prompt) - 1) // 4]
    total = len(hashes) + rng.randint(1, pool.num_blocks)
    got = pool.reserve(hashes, total)
    if got is None:
        assert _snapshot(pool) == snap
    else:
        shared, fresh = got
        assert len(shared) + len(fresh) == total
        assert all(pool._ref[b] >= 1 for b in shared + fresh)


if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_reserve_shortfall_leaves_pool_byte_identical(seed):
        _rollback_property(seed)
else:
    @pytest.mark.parametrize("seed", range(100))
    def test_reserve_shortfall_leaves_pool_byte_identical(seed):
        _rollback_property(seed)


def test_admit_rollback_engine_level(model):
    """Through the real _admit_paged path: a shortfall admission that
    matched registered prefix blocks restores the pool exactly."""
    cfg, params = model
    eng = _paged(cfg, params, n_slots=2, num_blocks=9,
                 admission="worst_case")
    base = list(range(5, 45))                         # 5 full blocks (bs=8)
    eng.run([Request(rid=0, prompt=base, max_new=4)])
    assert len(eng.pool._cached) == 5                 # registered, parked
    eng.submit(Request(rid=1, prompt=[1, 2, 3, 4, 5, 6, 7, 8], max_new=8))
    eng._admit()                                      # hogs 2 more blocks
    snap = _snapshot(eng.pool)
    entry = _QEntry(req=Request(rid=2, prompt=base + [77], max_new=30))
    ok = eng._admit_paged(1, entry)
    assert not ok                  # needs 8 blocks, only 1 free + 5 cached
    assert _snapshot(eng.pool) == snap
    out = eng.run([])                                 # rid 1 finishes clean
    assert len(out[1]) == 8
    assert eng.pool.in_use() == 0
