"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps
+ bit-exactness of the int path (per-assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import dualmode_softmax as dk

RNG = np.random.default_rng(1)
SHAPES = [(8, 128), (16, 256), (4, 512), (32, 128), (2, 1024)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_softmax_kernel_int_bitexact(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape) * 4, dtype)
    y = dk.softmax_pallas(x, precision="int", interpret=True)
    want = ref.softmax_bitexact(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES)
def test_softmax_kernel_float_close(shape):
    x = jnp.asarray(RNG.normal(size=shape) * 4, jnp.float32)
    y = dk.softmax_pallas(x, precision="float", interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.softmax_exact(x)),
                               atol=3e-6)


def test_softmax_kernel_float_row_pad_is_finite_no_debug_nan():
    """Phantom ROWS (row count off the block grid) used to pad with the
    float column value -inf, so the kernel computed (-inf) - (-inf) = NaN
    on rows that were then sliced off — poisoning jax.debug_nans runs.
    Rows must pad with a finite value; only the column tail needs the
    no-mass pad."""
    x = jnp.asarray(RNG.normal(size=(5, 40)) * 4, jnp.float32)  # 5 rows: pads
    prev = jax.config.jax_debug_nans
    jax.config.update("jax_debug_nans", True)
    try:
        y = dk.softmax_pallas(x, precision="float", interpret=True)
    finally:
        jax.config.update("jax_debug_nans", prev)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.softmax_exact(x)), atol=3e-6)


def test_softmax_kernel_float_pad_captures_no_mass():
    """Float-path column padding must be -inf, not the finite MASK_VALUE:
    rows whose true scores all sit below -30 must still sum to 1 on
    non-lane-aligned shapes (regression: padded -30 columns dominated)."""
    x = jnp.full((8, 200), -40.0, jnp.float32)
    y = dk.softmax_pallas(x, precision="float", interpret=True)
    np.testing.assert_allclose(np.asarray(y.sum(-1)), 1.0, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.softmax_exact(x)), atol=3e-6)


@pytest.mark.parametrize("mode", ["gelu", "silu"])
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pair_act_kernel_int_bitexact(mode, shape, dtype):
    z = jnp.asarray(RNG.normal(size=shape) * 3, dtype)
    y = dk.pair_act_pallas(z, mode=mode, precision="int", interpret=True)
    want = (ref.gelu_bitexact(z) if mode == "gelu" else ref.silu_bitexact(z))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


@pytest.mark.parametrize("mode", ["gelu", "silu"])
def test_pair_act_kernel_float_close(mode):
    z = jnp.linspace(-8, 8, 2048).reshape(16, 128)
    y = dk.pair_act_pallas(z, mode=mode, precision="float", interpret=True)
    want = (ref.gelu_tanh_ref(z) if mode == "gelu" else ref.silu_exact_ref(z))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)


# ---------------- public ops (padding, vjp, rank handling) ----------------

def test_ops_softmax_arbitrary_rank_and_pad():
    x = jnp.asarray(RNG.normal(size=(2, 3, 37)) * 3, jnp.float32)   # odd col
    y = ops.softmax(x)
    ref_y = ref.softmax_bitexact(x.reshape(-1, 37)).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y), atol=1e-6)


def test_ops_gelu_grad_matches_surrogate():
    z = jnp.asarray(RNG.normal(size=(4, 64)), jnp.float32)
    g = jax.grad(lambda t: ops.gelu(t).sum())(z)
    from repro.core.activations import gelu_tanh
    want = jax.grad(lambda t: gelu_tanh(t).sum())(z)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), atol=1e-5)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_ops_softmax_grad_is_softmax_vjp():
    x = jnp.asarray(RNG.normal(size=(4, 32)), jnp.float32)
    g = jax.grad(lambda t: (ops.softmax(t) * jnp.arange(32)).sum())(x)
    assert bool(jnp.all(jnp.isfinite(g)))
    # rows of softmax jacobian have zero sum -> grad rows ~orthogonal to 1
    # (exactly true for the float vjp evaluated at the unit's output)
    y = ops.softmax(x)
    dot = (g * 0 + 1)  # placeholder sanity: finite & shaped
    assert g.shape == x.shape


def test_kernel_fallback_path_matches_kernel():
    x = jnp.asarray(RNG.normal(size=(8, 128)) * 3, jnp.float32)
    a = ops.softmax(x, use_kernel=True)
    b = ops.softmax(x, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    z = jnp.asarray(RNG.normal(size=(8, 128)), jnp.float32)
    a = ops.gelu(z, use_kernel=True)
    b = ops.gelu(z, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
