"""Dedicated Pallas backward kernels (ISSUE 3 tentpole).

Gradient parity: dq/dk/dv from the Pallas dq and dk/dv kernels (the
default grad path of ``flash_attention_pallas``) must match the reference
VJP through the pure-JAX blocked path (``models/flash.py``) AND through
the naive materialized path, across GQA/MLA/ragged/non-divisible shapes
and bf16 inputs.  Same for the fused GLU backward kernel vs the unfused
``_glu_reference`` graph.  Plus the residual contract: the forward's
saved per-row (m, l) statistics match the pure-JAX blocked reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_ffn import _glu_reference, fused_glu_pallas
from repro.models.attention import _naive_sdpa
from repro.models.flash import flash_attention

RNG = np.random.default_rng(23)


def _mk(b, s, t, k, g, h, hv=None, dtype=jnp.float32):
    hv = hv or h
    q = jnp.asarray(RNG.normal(size=(b, s, k, g, h)), dtype)
    kk = jnp.asarray(RNG.normal(size=(b, t, k, h)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, t, k, hv)), dtype)
    return q, kk, v


def _grads(fn, q, k, v, w):
    """d(sum(fn * w))/d(q, k, v) — the random cotangent w exercises a
    structured dO instead of the all-ones one."""
    return jax.grad(
        lambda q_, k_, v_: (fn(q_, k_, v_).astype(jnp.float32) * w).sum(),
        argnums=(0, 1, 2))(q, k, v)


def _check_bwd_parity(q, k, v, q_pos, kv_valid, causal, atol=1e-5,
                      block=16, scale=None):
    w = jnp.asarray(RNG.normal(size=(q.shape[0], q.shape[1], q.shape[2],
                                     q.shape[3], v.shape[-1])), jnp.float32)
    g_pl = _grads(lambda q_, k_, v_: flash_attention_pallas(
        q_, k_, v_, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=scale, interpret=True), q, k, v, w)
    g_jx = _grads(lambda q_, k_, v_: flash_attention(
        q_, k_, v_, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=scale, block=block), q, k, v, w)
    g_nv = _grads(lambda q_, k_, v_: _naive_sdpa(
        q_, k_, v_, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
        scale=scale), q, k, v, w)
    for name, a, b_, c in zip("dq dk dv".split(), g_pl, g_jx, g_nv):
        assert bool(jnp.all(jnp.isfinite(a.astype(jnp.float32)))), name
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b_, np.float32),
            atol=atol, err_msg=f"{name} vs models/flash.py reference VJP")
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(c, np.float32),
            atol=atol, err_msg=f"{name} vs naive reference VJP")


@pytest.mark.parametrize("causal", [True, False])
def test_bwd_gqa_groups(causal):
    q, k, v = _mk(2, 64, 128, 2, 3, 16)        # GQA: G=3 groups per KV head
    q_pos = jnp.broadcast_to(jnp.arange(64, 128)[None], (2, 64))
    kv_valid = jnp.ones((2, 128), bool)
    _check_bwd_parity(q, k, v, q_pos, kv_valid, causal)


def test_bwd_mla_style_hv_differs():
    q, k, v = _mk(2, 32, 32, 4, 1, 24, hv=12)   # qk head 24, v head 12
    q_pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    kv_valid = jnp.ones((2, 32), bool)
    _check_bwd_parity(q, k, v, q_pos, kv_valid, True, block=8)


def test_bwd_hv_off_lane_grid():
    """hv=72 exercises the lane-rounded scratch path in both directions."""
    q, k, v = _mk(1, 16, 32, 1, 2, 16, hv=72)
    q_pos = jnp.broadcast_to(jnp.arange(16, 32)[None], (1, 16))
    kv_valid = jnp.ones((1, 32), bool)
    _check_bwd_parity(q, k, v, q_pos, kv_valid, True)


@pytest.mark.parametrize("causal", [True, False])
def test_bwd_ragged_kv_valid(causal):
    q, k, v = _mk(2, 32, 96, 1, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(64, 96)[None], (2, 32))
    kv_valid = jnp.asarray(RNG.random((2, 96)) > 0.3)
    kv_valid = kv_valid.at[:, 0].set(True)
    _check_bwd_parity(q, k, v, q_pos, kv_valid, causal)


@pytest.mark.parametrize("s,t", [(17, 33), (5, 100), (130, 259)])
def test_bwd_non_divisible_lengths(s, t):
    """S/T off the block grid: the backward pads dO/m/l/D up to the same
    grid as the forward and phantom rows/keys must contribute exactly 0."""
    q, k, v = _mk(1, s, t, 2, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (1, s))
    kv_valid = jnp.ones((1, t), bool)
    _check_bwd_parity(q, k, v, q_pos, kv_valid, True)


def test_bwd_explicit_scale_grad_flows():
    """scale rides as a traced operand folded into q: its own gradient
    must flow through the fold-in multiply around the scale-free kernels."""
    q, k, v = _mk(1, 16, 16, 1, 1, 8)
    q_pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16))
    kv_valid = jnp.ones((1, 16), bool)
    _check_bwd_parity(q, k, v, q_pos, kv_valid, True, scale=0.25)
    g_sc = jax.grad(lambda sc: flash_attention_pallas(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, scale=sc,
        interpret=True).sum())(jnp.float32(0.25))
    g_ref = jax.grad(lambda sc: _naive_sdpa(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, scale=sc).sum())(
        jnp.float32(0.25))
    np.testing.assert_allclose(np.asarray(g_sc), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-5)


def test_bwd_bf16_inputs():
    q, k, v = _mk(1, 32, 64, 2, 2, 16, dtype=jnp.bfloat16)
    q_pos = jnp.broadcast_to(jnp.arange(32, 64)[None], (1, 32))
    kv_valid = jnp.ones((1, 64), bool)
    # bf16 cotangent/primal rounding dominates: compare at bf16 tolerance
    _check_bwd_parity(q, k, v, q_pos, kv_valid, True, atol=3e-2)
    g = jax.grad(lambda q_: flash_attention_pallas(
        q_, k, v, q_pos=q_pos, kv_valid=kv_valid,
        interpret=True).astype(jnp.float32).sum())(q)
    assert g.dtype == jnp.bfloat16


def test_forward_saved_stats_match_pure_jax_reference():
    """The residual contract: the kernel's saved (m, l) are the pure-JAX
    blocked path's per-row online-softmax statistics, laid out (B,K,G,S)."""
    q, k, v = _mk(2, 24, 40, 2, 2, 8)
    q_pos = jnp.broadcast_to(jnp.arange(16, 40)[None], (2, 24))
    kv_valid = jnp.asarray(RNG.random((2, 40)) > 0.25)
    kv_valid = kv_valid.at[:, 0].set(True)
    o_pl, m_pl, l_pl = flash_attention_pallas(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, interpret=True,
        return_stats=True)
    o_jx, m_jx, l_jx = flash_attention(
        q, k, v, q_pos=q_pos, kv_valid=kv_valid, block=16,
        return_stats=True)
    assert m_pl.shape == m_jx.shape == (2, 2, 2, 24)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_jx),
                               atol=1e-5)
    # m is an order-independent max: exact; l may differ by f32 sum order
    np.testing.assert_array_equal(np.asarray(m_pl), np.asarray(m_jx))
    np.testing.assert_allclose(np.asarray(l_pl), np.asarray(l_jx),
                               rtol=1e-6)


def test_bwd_no_nans_under_all_masked_rows():
    """Rows whose every key is user-invalid take the uniform MASK_VALUE
    softmax in the forward; their backward must stay finite and match the
    reference (which differentiates the same finite masking)."""
    q, k, v = _mk(1, 8, 16, 1, 1, 8)
    q_pos = jnp.broadcast_to(jnp.arange(8, 16)[None], (1, 8))
    kv_valid = jnp.zeros((1, 16), bool).at[:, :4].set(True)
    kv_valid = kv_valid.at[0, :].set(False)   # batch row fully invalid
    _check_bwd_parity(q, k, v, q_pos, kv_valid, False)


# ---------------- fused GLU backward kernel ----------------

@pytest.mark.parametrize("mode", ["silu", "gelu"])
@pytest.mark.parametrize("m,k,f", [(16, 32, 64), (48, 20, 72),
                                   (32, 100, 96)])
def test_fused_glu_bwd_kernel_matches_reference(mode, m, k, f):
    """d_wg/d_wu/dx through the fused backward kernel (pair_act_grad in
    VMEM) vs the unfused reference graph's VJP."""
    x = jnp.asarray(RNG.normal(size=(m, k)) * 0.5, jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(k, f)) / k ** 0.5, jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(k, f)) / k ** 0.5, jnp.float32)
    w = jnp.asarray(RNG.normal(size=(m, f)), jnp.float32)
    gk = jax.grad(lambda *a: (fused_glu_pallas(
        *a, mode=mode, interpret=True) * w).sum(), argnums=(0, 1, 2))(
        x, wg, wu)
    gr = jax.grad(lambda *a: (_glu_reference(*a, mode) * w).sum(),
                  argnums=(0, 1, 2))(x, wg, wu)
    for name, a, b in zip("dx dwg dwu".split(), gk, gr):
        assert bool(jnp.all(jnp.isfinite(a))), name
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   err_msg=name)


def test_pair_act_grad_is_the_datapath_derivative():
    """datapath.pair_act_grad (the kernels' single float home of the
    derivative) must equal jax.grad of datapath.pair_act elementwise."""
    from repro.kernels import datapath as dp
    z = jnp.linspace(-6, 6, 512)
    for mode in ("silu", "gelu"):
        want = jax.vmap(jax.grad(lambda t: dp.pair_act(t, mode)))(z)
        got = dp.pair_act_grad(z, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
    with pytest.raises(ValueError):
        dp.pair_act_grad(z, "relu")


def test_fused_glu_bwd_bf16():
    x = jnp.asarray(RNG.normal(size=(16, 32)) * 0.5, jnp.bfloat16)
    wg = jnp.asarray(RNG.normal(size=(32, 64)) * 0.2, jnp.bfloat16)
    wu = jnp.asarray(RNG.normal(size=(32, 64)) * 0.2, jnp.bfloat16)
    gk = jax.grad(lambda *a: fused_glu_pallas(
        *a, mode="silu", interpret=True).astype(jnp.float32).sum(),
        argnums=(0, 1, 2))(x, wg, wu)
    gr = jax.grad(lambda *a: _glu_reference(
        *a, "silu").astype(jnp.float32).sum(), argnums=(0, 1, 2))(x, wg, wu)
    for a, b in zip(gk, gr):
        assert a.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)


def test_default_grad_path_is_the_pallas_bwd_kernel():
    """The pure-JAX recompute must no longer be on the default grad path:
    differentiating the Pallas forward must trace the dedicated backward
    kernels (observable: the jaxpr of the VJP contains >1 pallas_call —
    forward + dq + dkdv — where the recompute fallback had exactly 1)."""
    q, k, v = _mk(1, 16, 16, 1, 1, 8)
    q_pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16))
    kv_valid = jnp.ones((1, 16), bool)
    jaxpr = jax.make_jaxpr(jax.grad(lambda q_: flash_attention_pallas(
        q_, k, v, q_pos=q_pos, kv_valid=kv_valid, interpret=True).sum()))(q)
    n_pallas = str(jaxpr).count("pallas_call")
    assert n_pallas >= 3, f"expected fwd+dq+dkdv pallas_calls, saw {n_pallas}"
