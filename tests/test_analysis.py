"""The auditor audits itself: each static pass is exercised on the real
tree (must be clean) AND on a seeded violation (must be caught).  An
analysis subsystem whose failure modes are untested is just decoration —
these tests are what keeps the four passes honest."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import pytest

from repro.analysis import dispatch_table, int_purity, schema, vmem
from repro.kernels import dispatch, tiling

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# schema: the one declarative validator
# ---------------------------------------------------------------------------


def test_schema_type_and_eq_and_in():
    assert schema.check(3, int) == []
    assert schema.check(True, int)          # bool is not an int here
    assert schema.check(3, float) == []     # ints pass float slots
    assert schema.check("x", ("eq", "x")) == []
    assert schema.check("y", ("eq", "x"))
    assert schema.check("a", ("in", {"a", "b"})) == []
    assert schema.check("c", ("in", {"a", "b"}))


def test_schema_containers_and_any_of():
    spec = {"rows": [{"n": int}], "tag": ("any_of", int, str)}
    assert schema.check({"rows": [{"n": 1}], "tag": "t"}, spec) == []
    errs = schema.check({"rows": [{"n": "bad"}], "tag": 1.5}, spec)
    assert len(errs) == 2                   # both collected, not fail-fast
    assert any("$.rows[0].n" in e for e in errs)
    assert schema.check({"a": 1, "b": 2}, ("keys", int)) == []
    assert schema.check({"a": "x"}, ("keys", int))


def test_schema_validate_raises_with_all_errors():
    with pytest.raises(AssertionError) as ei:
        schema.validate({"a": "x"}, {"a": int, "b": int},
                        [("always fails", lambda d: False)], "thing")
    msg = str(ei.value)
    assert "$.a" in msg and "missing key 'b'" in msg and "always fails" in msg


def test_bench_schemas_accept_committed_artifacts():
    """The unified validator must accept every committed BENCH artifact
    the old hand-rolled checkers accepted."""
    for fname, spec, rules in [
            ("BENCH_flash_int.json", schema.FLASH_INT_SPEC,
             schema.FLASH_INT_RULES),
            ("BENCH_decode.json", schema.DECODE_SPEC, schema.DECODE_RULES),
            ("BENCH_serve.json", schema.SERVE_SPEC, schema.SERVE_RULES),
            ("BENCH_block.json", schema.BLOCK_SPEC, schema.BLOCK_RULES)]:
        path = os.path.join(REPO, fname)
        if not os.path.exists(path):
            pytest.skip(f"{fname} not committed")
        schema.validate_file(path, spec, rules, fname)


def test_block_rules_catch_a_zero_saving():
    path = os.path.join(REPO, "BENCH_block.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_block.json not committed")
    with open(path) as fh:
        d = json.load(fh)
    seam = d["seams"]["attn_qkv_prologue"]
    seam["saved_bytes"] = 0
    seam["fused_hbm_bytes"] = seam["dense_hbm_bytes"]
    with pytest.raises(AssertionError, match="saves HBM traffic"):
        schema.validate(d, schema.BLOCK_SPEC, schema.BLOCK_RULES)


def test_serve_rules_catch_a_cache_copy():
    path = os.path.join(REPO, "BENCH_serve.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_serve.json not committed")
    with open(path) as fh:
        d = json.load(fh)
    d["modes"]["paged"]["cache_copies"] = 3
    with pytest.raises(AssertionError, match="never copied"):
        schema.validate(d, schema.SERVE_SPEC, schema.SERVE_RULES)


def test_serve_rules_catch_a_concurrency_tie_under_pressure():
    """The pressure rows' whole claim is reactive admission buying
    strictly more concurrency at the same pool — a tie must fail."""
    path = os.path.join(REPO, "BENCH_serve.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_serve.json not committed")
    with open(path) as fh:
        d = json.load(fh)
    d["pressure"]["modes"]["reactive"]["concurrent_hwm"] = \
        d["pressure"]["modes"]["worst_case"]["concurrent_hwm"]
    with pytest.raises(AssertionError, match="strictly higher"):
        schema.validate(d, schema.SERVE_SPEC, schema.SERVE_RULES)
    with open(path) as fh:
        d = json.load(fh)
    d["pressure"]["modes"]["reactive"]["leaked_blocks"] = 1
    with pytest.raises(AssertionError, match="zero blocks leaked"):
        schema.validate(d, schema.SERVE_SPEC, schema.SERVE_RULES)


# ---------------------------------------------------------------------------
# int-purity: clean tree, caught fixture, no false positive on the
# finishing divide
# ---------------------------------------------------------------------------


def test_int_purity_real_paths_clean():
    out = int_purity.run()
    assert out["status"] == "ok", out["violations"]
    # the walk must actually cover the unit, the pallas softmax tile and
    # every registered int attention entry — an empty 'checked' list
    # passing would mean the pass silently audits nothing
    checked = set(out["checked"])
    assert {"softmax:dualmode", "softmax:dualmode_snap", "gelu:dualmode",
            "softmax_pallas:int", "rmsnorm:dualmode",
            "layernorm:dualmode"} <= checked
    assert any(c.startswith("attn:flash_pallas_int:") for c in checked)
    assert any(c.startswith("attn:flash_decode:") for c in checked)


def test_int_purity_catches_exp_on_the_word_lattice():
    def bad(x):
        words = (x * 127.0).astype(jnp.int32)
        e = jnp.exp(words.astype(jnp.float32) * (1.0 / 127.0))
        return (e * 127.0).astype(jnp.int32)

    v = int_purity.audit_fn(bad, (jnp.zeros((8, 128), jnp.float32),),
                            "fixture")
    assert [x.prim for x in v] == ["exp"]


def test_int_purity_allows_float_div_after_the_words():
    """The blocked kernels' finishing acc/l divide never feeds an int var
    — the exact reason the rule is int->op->int, not 'no div anywhere'."""
    def fine(x):
        words = (x * 127.0).astype(jnp.int32)
        probs = words.astype(jnp.float32)
        return probs / (probs.sum(-1, keepdims=True) + 1.0)

    assert int_purity.audit_fn(
        fine, (jnp.zeros((8, 128), jnp.float32),), "p") == []


# ---------------------------------------------------------------------------
# vmem: every grid cell within budget, oversubscribed plan caught,
# declarations honest vs traced kernels
# ---------------------------------------------------------------------------


def test_vmem_grid_within_budget():
    out = vmem.run()
    assert out["status"] == "ok", out
    assert out["over_budget"] == 0
    assert len(out["cells"]) >= 10          # the whole grid, not a sample
    kernels = {c["kernel"] for c in out["cells"]}
    assert {"flash_attention", "flash_attention_int", "flash_decode",
            "fused_ffn", "fused_norm"} <= kernels
    # all three norm seams priced, not just one
    norm_calls = {c["call"] for c in out["cells"]
                  if c["kernel"] == "fused_norm"}
    assert {"resnorm_fwd", "norm_linear_fwd", "norm_glu_fwd"} <= norm_calls


def test_vmem_catches_oversubscribed_plan():
    plan = {"in:x": ((4096, 4096), "float32")}
    assert vmem.plan_footprint(plan) > tiling.VMEM_CORE_BUDGET


def test_vmem_footprint_arithmetic():
    plan = {"in:a": ((8, 128), "float32"), "out:b": ((8, 128), "float32"),
            "scratch:s": ((8, 128), "int32")}
    # 2 x (4096 + 4096) io + 4096 scratch
    assert vmem.plan_footprint(plan) == 2 * 2 * 8 * 128 * 4 + 8 * 128 * 4


def test_vmem_cross_check_declared_vs_traced():
    assert vmem.cross_check() == []


# ---------------------------------------------------------------------------
# dispatch-table truth
# ---------------------------------------------------------------------------


def test_dispatch_matrix_consistent():
    m = dispatch_table.enumerate_matrix()
    assert m["problems"] == []
    assert m["cells"] >= 100


def test_dispatch_matrix_pins_the_published_routing():
    m = dispatch_table.enumerate_matrix()
    auto = m["auto"]
    # the cells ARCHITECTURE.md promises
    assert auto[("prefill", "none", "dualmode")] == "flash_pallas_int"
    assert auto[("prefill", "ring8", "float")] == "flash_ring"
    assert auto[("decode", "none", "dualmode")] == "flash_decode"
    # the mesh gate: sharded decode stays on the shardable naive graph
    assert auto[("decode", "ring8", "dualmode")] == "naive"
    assert auto[("enc", "none", "float")] == "naive"
    # explicit float impls refuse the word contract
    assert m["explicit"]["flash"]["dualmode"] == "raise"
    assert m["explicit"]["flash_pallas"]["dualmode_snap"] == "raise"
    assert m["explicit"]["flash_pallas_int"]["float"] == "raise"


def test_dispatch_docs_not_drifted():
    """The tables committed in dispatch.py and ARCHITECTURE.md must match
    a fresh enumeration — regenerate with --write-docs, never by hand."""
    assert dispatch_table.check_docs() == []


def test_dispatch_catches_rogue_registry_entry():
    dispatch._load_attention_providers()
    dispatch._ATTENTION["rogue"] = lambda *a, **k: None
    try:
        m = dispatch_table.enumerate_matrix()
    finally:
        dispatch._ATTENTION.pop("rogue", None)
    assert any("rogue" in p and "without AttentionInfo" in p
               for p in m["problems"])


def test_dispatch_catches_half_fused_norm_provider():
    """A norm provider missing a NORM_SEAMS callable is exactly the
    half-fused block the provider contract refuses."""
    dispatch.get_norm("fused_pallas")
    dispatch._NORM["rogue"] = {"residual_norm": lambda *a, **k: None}
    try:
        m = dispatch_table.enumerate_matrix()
    finally:
        dispatch._NORM.pop("rogue", None)
    missing = [p for p in m["problems"]
               if "rogue" in p and "missing seam" in p]
    assert len(missing) == 2, m["problems"]       # norm_linear + norm_glu


# ---------------------------------------------------------------------------
# the CLI end to end (subprocess: the mesh pass needs XLA_FLAGS set
# before jax import, which an in-process test can't do)
# ---------------------------------------------------------------------------


def _run_audit(tmp_path, *args, timeout=560):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)              # the CLI sets its own
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = os.path.join(str(tmp_path), "AUDIT.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.audit", "--out", out,
         *args],
        env=env, capture_output=True, text=True, timeout=timeout,
        cwd=REPO)
    return r, out


def test_audit_cli_mesh_pass_clean(tmp_path):
    r, out = _run_audit(tmp_path, "--strict", "--passes", "mesh_safety")
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    with open(out) as fh:
        audit = json.load(fh)
    schema.validate(audit, schema.AUDIT_SPEC, schema.AUDIT_RULES)
    ms = audit["passes"]["mesh_safety"]
    assert ms["status"] == "ok"
    by_impl = {r_["impl"]: r_ for r_ in ms["impls"]}
    # naive really shards; the pallas kernels really don't — and say so
    assert by_impl["naive"]["declared_mesh_safe"]
    assert not by_impl["naive"]["whole_cache_gather"]
    assert not by_impl["flash_decode"]["declared_mesh_safe"]
    assert by_impl["flash_decode"]["whole_cache_gather"]


def test_audit_cli_mesh_fixture_detected(tmp_path):
    r, _ = _run_audit(tmp_path, "--fixture", "mesh", "--passes", "")
    assert r.returncode != 0, "falsely-declared mesh_safe went undetected"
    assert "detected as intended" in r.stdout


def test_audit_cli_purity_and_dispatch_fixtures_detected(tmp_path):
    for fixture in ("int_purity", "dispatch", "vmem", "norm"):
        r, _ = _run_audit(tmp_path, "--fixture", fixture, "--passes", "")
        assert r.returncode != 0, f"fixture {fixture} went undetected"
        assert "detected as intended" in r.stdout
