"""Data pipeline determinism/sharding + optimizer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.data import SyntheticLM, host_slice
from repro.optim import (adamw_init, adamw_update, compress_decompress,
                         ef_state_init, global_norm, wsd_schedule)


def test_batch_pure_in_step():
    ds = SyntheticLM(vocab=64, seq_len=16, global_batch=4, seed=3)
    a1, b1 = ds.batch(7)
    a2, b2 = ds.batch(7)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    a3, _ = ds.batch(8)
    assert not np.array_equal(np.asarray(a1), np.asarray(a3))


def test_labels_are_next_tokens():
    ds = SyntheticLM(vocab=64, seq_len=16, global_batch=4)
    t, l = ds.batch(0)
    np.testing.assert_array_equal(np.asarray(t[:, 1:]), np.asarray(l[:, :-1]))


@given(st.integers(1, 64), st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_host_slices_partition_batch(batch, hosts):
    slices = [host_slice(batch, hosts, h) for h in range(hosts)]
    covered = []
    for s in slices:
        covered.extend(range(s.start, s.stop))
    assert covered == list(range(batch))


def test_host_shards_differ_but_compose():
    full = SyntheticLM(vocab=32, seq_len=8, global_batch=6, seed=1)
    sh0 = SyntheticLM(vocab=32, seq_len=8, global_batch=6, seed=1,
                      n_hosts=2, host_id=0)
    sh1 = SyntheticLM(vocab=32, seq_len=8, global_batch=6, seed=1,
                      n_hosts=2, host_id=1)
    assert sh0.local_batch == sh1.local_batch == 3
    t0, _ = sh0.batch(5)
    t1, _ = sh1.batch(5)
    assert not np.array_equal(np.asarray(t0), np.asarray(t1))


# ---------------- optimizer ----------------

def test_wsd_schedule_shape():
    lrs = [float(wsd_schedule(jnp.asarray(s), lr=1.0, warmup=10, total=100))
           for s in range(0, 101, 10)]
    assert 0.0 < lrs[0] <= 0.2               # step 0 trains (lr/warmup)
    assert abs(lrs[1] - 1.0) < 0.11          # ~end of warmup
    assert lrs[-1] < lrs[1]                  # decayed
    assert lrs[-1] >= 0.1 - 1e-6             # min_frac floor


def test_adamw_decays_weights_not_biases():
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    st_ = adamw_init(params)
    g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(g, st_, params, lr=0.1, weight_decay=0.5)
    assert float(p2["w"][0, 0]) < 1.0        # decayed
    assert float(p2["b"][0]) == 1.0          # not decayed


def test_grad_clip_caps_update():
    params = {"w": jnp.zeros((2,))}
    st_ = adamw_init(params)
    g = {"w": jnp.asarray([1e6, -1e6])}
    _, _, m = adamw_update(g, st_, params, lr=0.1, grad_clip=1.0,
                           weight_decay=0.0)
    assert float(m["grad_norm"]) > 1e5       # reported raw norm


def test_error_feedback_carries_residual():
    g = {"a": jnp.asarray([1.0, 0.003, -2.0])}
    ef = ef_state_init(g)
    c, ef = compress_decompress(g, ef)
    # compressed + residual == original (exact decomposition)
    np.testing.assert_allclose(np.asarray(c["a"] + ef["a"]),
                               np.asarray(g["a"]), atol=1e-7)


def test_compressed_sgd_converges_like_exact():
    """EF-int8 training reaches the same optimum on a quadratic."""
    def run(compress):
        params = {"w": jnp.full((8,), 5.0)}
        st_ = adamw_init(params)
        ef = ef_state_init(params)
        for _ in range(300):
            g = {"w": 2 * params["w"]}
            if compress:
                g, ef = compress_decompress(g, ef)
            params, st_, _ = adamw_update(g, st_, params, lr=0.05,
                                          weight_decay=0.0)
        return float(jnp.abs(params["w"]).max())
    assert run(True) < 1e-2 and run(False) < 1e-2
