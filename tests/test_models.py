"""Per-architecture smoke tests (assignment: reduced config, one forward +
one train step on CPU, shape/NaN assertions) + cache-consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES, TrainConfig
from repro.models.transformer import (encoder_apply, init_caches, init_lm,
                                      lm_apply)
from repro.train.step import TrainState, make_train_step
from repro.optim import adamw_init

ARCHS = registry.ARCH_IDS


def _fwd_kwargs(cfg, b):
    kw = {}
    if cfg.family == "encdec":
        frames = jnp.zeros((b, 16, cfg.d_model))
        return {"frames": frames}
    if cfg.family == "vlm":
        return {"image_embeds": jnp.zeros((b, cfg.n_img_tokens, cfg.d_model))}
    return kw


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_finite(arch):
    cfg = registry.reduced_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    kw = _fwd_kwargs(cfg, 2)
    cross = None
    if "frames" in kw:
        cross = encoder_apply(params, cfg, kw["frames"])
    elif "image_embeds" in kw:
        cross = kw["image_embeds"]
    logits, caches, aux = lm_apply(params, cfg, toks, cross_src=cross)
    assert logits.shape == (2, 16, cfg.vocab)
    assert caches is None
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = registry.reduced_config(arch)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=10, remat=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = TrainState(params, adamw_init(params), {})
    step = jax.jit(make_train_step(cfg, tcfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((2, 16, cfg.d_model))
    elif cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros((2, cfg.n_img_tokens, cfg.d_model))
    state2, m = step(state, batch)
    assert np.isfinite(float(m["loss"])) and float(m["loss"]) > 0
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.abs(p - q).sum()),
                     state.params, state2.params))
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen3-14b", "minicpm3-4b", "jamba-v0.1-52b",
                                  "rwkv6-1.6b", "whisper-base"])
def test_prefill_then_decode_matches_full(arch):
    """prefill(0..n) + decode(n) logits == prefill(0..n+1) last logits."""
    cfg = registry.reduced_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 9), 0, cfg.vocab)
    caches = init_caches(cfg, 2, 32)
    lg1, caches, _ = lm_apply(params, cfg, toks[:, :8], pos=0, caches=caches)
    lg2, _, _ = lm_apply(params, cfg, toks[:, 8:9], pos=8, caches=caches)
    full_caches = init_caches(cfg, 2, 32)
    lgf, _, _ = lm_apply(params, cfg, toks, pos=0, caches=full_caches)
    np.testing.assert_allclose(np.asarray(lg2[:, -1]), np.asarray(lgf[:, -1]),
                               atol=2e-4)


def test_per_row_positions_decode():
    """Vector pos: two rows at different depths decode independently."""
    cfg = registry.reduced_config("yi-6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    t = jax.random.randint(jax.random.PRNGKey(4), (1, 12), 0, cfg.vocab)
    # row A: prefix of 5, row B: prefix of 9
    cA = init_caches(cfg, 1, 32)
    _, cA, _ = lm_apply(params, cfg, t[:, :5], pos=0, caches=cA)
    cB = init_caches(cfg, 1, 32)
    _, cB, _ = lm_apply(params, cfg, t[:, :9], pos=0, caches=cB)
    caches = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1)
                          if a.ndim > 1 and a.shape[1] == 1 else
                          jnp.concatenate([a, b], axis=0), cA, cB)
    # stacked-period caches have batch at axis 1
    caches = jax.tree_util.tree_map_with_path(
        lambda p, a: a, caches)  # structure sanity
    tok = jnp.concatenate([t[:, 5:6], t[:, 9:10]], axis=0)
    pos = jnp.asarray([5, 9], jnp.int32)
    lg, _, _ = lm_apply(params, cfg, tok, pos=pos, caches=caches)
    # oracle rows
    oA = init_caches(cfg, 1, 32)
    lgA, _, _ = lm_apply(params, cfg, t[:, :6], pos=0, caches=oA)
    oB = init_caches(cfg, 1, 32)
    lgB, _, _ = lm_apply(params, cfg, t[:, :10], pos=0, caches=oB)
    np.testing.assert_allclose(np.asarray(lg[0, -1]), np.asarray(lgA[0, -1]),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(lg[1, -1]), np.asarray(lgB[0, -1]),
                               atol=2e-4)


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (spot checks)."""
    c = registry.get_config("qwen3-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (40, 5120, 40, 8, 17408, 151936)
    assert c.qk_norm
    c = registry.get_config("jamba-v0.1-52b")
    assert c.moe.n_experts == 16 and c.moe.top_k == 2
    assert sum(1 for s in c.pattern if s.mixer == "attn") == 1  # 1:7
    c = registry.get_config("deepseek-v2-lite-16b")
    assert c.mla.kv_lora_rank == 512 and c.moe.top_k == 6
    assert c.moe.n_shared == 2
    c = registry.get_config("minicpm3-4b")
    assert c.n_layers == 62 and c.mla is not None
    c = registry.get_config("rwkv6-1.6b")
    assert c.sub_quadratic
    c = registry.get_config("whisper-base")
    assert c.enc_layers == 6 and c.vocab == 51865
    c = registry.get_config("granite-moe-3b-a800m")
    assert c.moe.n_experts == 40 and c.moe.top_k == 8


def test_cell_applicability_rules():
    jam = registry.get_config("jamba-v0.1-52b")
    yi = registry.get_config("yi-6b")
    assert registry.cell_applicable(jam, SHAPES["long_500k"])[0]
    assert not registry.cell_applicable(yi, SHAPES["long_500k"])[0]
