"""Serving engine: continuous batching == full-reforward oracle; EOS,
temperature, slot reuse."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models.transformer import init_caches, init_lm, lm_apply
from repro.serve import Request, ServeEngine


def _oracle(cfg, params, prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        caches = init_caches(cfg, 1, len(toks))
        logits, _, _ = lm_apply(params, cfg,
                                jnp.asarray(toks, jnp.int32)[None],
                                pos=0, caches=caches)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-1.6b",
                                  "jamba-v0.1-52b", "minicpm3-4b",
                                  "granite-moe-3b-a800m"])
def test_continuous_batching_matches_oracle(arch):
    cfg = registry.reduced_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=3, max_seq=48,
                      prefill_buckets=(8, 16))
    reqs = [Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new=5),
            Request(rid=1, prompt=[7, 8, 9], max_new=7),
            Request(rid=2, prompt=[4] * 10, max_new=4),
            Request(rid=3, prompt=[2, 3], max_new=3)]
    outs = eng.run(reqs)
    for r in reqs:
        assert outs[r.rid] == _oracle(cfg, params, r.prompt, r.max_new), r.rid
    assert eng.stats["prefills"] == 4
    assert eng.active == 0


def test_eos_stops_generation():
    cfg = registry.reduced_config("yi-6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ref = ServeEngine(cfg, params, n_slots=1, max_seq=32)
    out = ref.run([Request(rid=0, prompt=[1, 2, 3], max_new=10)])[0]
    eos = out[2] if len(out) > 2 else out[0]
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32, eos_id=eos)
    out2 = eng.run([Request(rid=0, prompt=[1, 2, 3], max_new=10)])[0]
    assert len(out2) <= len(out)
    assert out2[-1] == eos or len(out2) == 10


def test_temperature_sampling_varies():
    cfg = registry.reduced_config("yi-6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    outs = set()
    for seed in range(3):
        eng = ServeEngine(cfg, params, n_slots=1, max_seq=32, seed=seed)
        o = eng.run([Request(rid=0, prompt=[1, 2], max_new=8,
                             temperature=2.0)])[0]
        outs.add(tuple(o))
    assert len(outs) > 1                      # stochastic
    for o in outs:
        assert all(0 <= t < cfg.vocab for t in o)


def test_max_new_zero_emits_no_tokens():
    """A max_new=0 request finishes with an EMPTY completion — it used to
    emit the prefill-sampled token unconditionally (and burn a prefill)."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32,
                      prefill_buckets=(8,))
    outs = eng.run([Request(rid=0, prompt=[1, 2, 3], max_new=0),
                    Request(rid=1, prompt=[4, 5], max_new=3)])
    assert outs[0] == []
    assert outs[1] == _oracle(cfg, params, [4, 5], 3)
    assert eng.stats["prefills"] == 1          # zero request never prefilled
    assert eng.stats["admitted"] == 2
    assert eng.active == 0


def test_overlong_prompt_raises_bucketed():
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                      prefill_buckets=(8,))
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(Request(rid=0, prompt=list(range(9)), max_new=1))
    assert eng.pending() == 0                  # nothing left half-queued


def test_overlong_prompt_raises_exact_prefill():
    """The exact-length (mamba/rwkv) prefill path used to skip the length
    check entirely and silently overrun the cache."""
    cfg = registry.reduced_config("rwkv6-1.6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(rid=0, prompt=list(range(17)), max_new=1))
    assert eng.pending() == 0


def test_per_phase_attn_impl_selection():
    """Prefill and decode pin their own registry-resolved attention impls;
    an explicit per-phase choice is honored and still matches the oracle."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                      prefill_buckets=(8,))
    assert eng.decode_attn_impl == "naive"     # s_q=1 rows stay whole-row
    # a config that PINS an impl keeps it for both phases (the engine's
    # per-phase defaults defer to cfg.attn_impl rather than clobber it)
    pinned = ServeEngine(cfg.replace(attn_impl="naive"), params, n_slots=1,
                         max_seq=32, prefill_buckets=(8,))
    assert pinned.prefill_attn_impl == "naive"
    assert pinned.decode_attn_impl == "naive"
    eng2 = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                       prefill_buckets=(8,),
                       prefill_attn_impl="flash_pallas",
                       decode_attn_impl="naive")
    assert eng2.prefill_attn_impl == "flash_pallas"
    out = eng2.run([Request(rid=0, prompt=[1, 2, 3], max_new=4)])[0]
    assert out == _oracle(cfg, params, [1, 2, 3], 4)


def test_dualmode_engine_refuses_float_blocked_prefill():
    """softmax_impl='dualmode' + an explicit float blocked prefill impl
    must fail at engine construction, not silently drop the unit."""
    cfg = registry.reduced_config("qwen1.5-0.5b").replace(
        softmax_impl="dualmode")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="dualmode"):
        ServeEngine(cfg, params, n_slots=1, max_seq=32,
                    prefill_buckets=(8,), prefill_attn_impl="flash")


def test_slot_reuse_more_requests_than_slots():
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32,
                      prefill_buckets=(8,))
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new=3)
            for i in range(7)]
    outs = eng.run(reqs)
    assert sorted(outs) == list(range(7))
    assert all(len(v) == 3 for v in outs.values())
    assert eng.stats["admitted"] == 7
