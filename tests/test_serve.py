"""Serving engine: continuous batching == full-reforward oracle; EOS,
temperature, slot reuse."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models.transformer import init_caches, init_lm, lm_apply
from repro.serve import Request, ServeEngine


def _oracle(cfg, params, prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        caches = init_caches(cfg, 1, len(toks))
        logits, _, _ = lm_apply(params, cfg,
                                jnp.asarray(toks, jnp.int32)[None],
                                pos=0, caches=caches)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-1.6b",
                                  "jamba-v0.1-52b", "minicpm3-4b",
                                  "granite-moe-3b-a800m"])
def test_continuous_batching_matches_oracle(arch):
    cfg = registry.reduced_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=3, max_seq=48,
                      prefill_buckets=(8, 16))
    reqs = [Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new=5),
            Request(rid=1, prompt=[7, 8, 9], max_new=7),
            Request(rid=2, prompt=[4] * 10, max_new=4),
            Request(rid=3, prompt=[2, 3], max_new=3)]
    outs = eng.run(reqs)
    for r in reqs:
        assert outs[r.rid] == _oracle(cfg, params, r.prompt, r.max_new), r.rid
    assert eng.stats["prefills"] == 4
    assert eng.active == 0


def test_eos_stops_generation():
    cfg = registry.reduced_config("yi-6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ref = ServeEngine(cfg, params, n_slots=1, max_seq=32)
    out = ref.run([Request(rid=0, prompt=[1, 2, 3], max_new=10)])[0]
    eos = out[2] if len(out) > 2 else out[0]
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32, eos_id=eos)
    out2 = eng.run([Request(rid=0, prompt=[1, 2, 3], max_new=10)])[0]
    assert len(out2) <= len(out)
    assert out2[-1] == eos or len(out2) == 10


def test_temperature_sampling_varies():
    cfg = registry.reduced_config("yi-6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    outs = set()
    for seed in range(3):
        eng = ServeEngine(cfg, params, n_slots=1, max_seq=32, seed=seed)
        o = eng.run([Request(rid=0, prompt=[1, 2], max_new=8,
                             temperature=2.0)])[0]
        outs.add(tuple(o))
    assert len(outs) > 1                      # stochastic
    for o in outs:
        assert all(0 <= t < cfg.vocab for t in o)


def test_max_new_zero_emits_no_tokens():
    """A max_new=0 request finishes with an EMPTY completion — it used to
    emit the prefill-sampled token unconditionally (and burn a prefill)."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32,
                      prefill_buckets=(8,))
    outs = eng.run([Request(rid=0, prompt=[1, 2, 3], max_new=0),
                    Request(rid=1, prompt=[4, 5], max_new=3)])
    assert outs[0] == []
    assert outs[1] == _oracle(cfg, params, [4, 5], 3)
    assert eng.stats["prefills"] == 1          # zero request never prefilled
    assert eng.stats["admitted"] == 2
    assert eng.active == 0


def test_overlong_prompt_raises_bucketed():
    # bucket semantics are a CONTIGUOUS-path concept (paged prefill is
    # chunked and has no buckets) — pin the mode under test
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                      prefill_buckets=(8,), cache_mode="contiguous")
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(Request(rid=0, prompt=list(range(9)), max_new=1))
    assert eng.pending() == 0                  # nothing left half-queued


def test_overlong_prompt_raises_exact_prefill():
    """The exact-length (mamba/rwkv) prefill path used to skip the length
    check entirely and silently overrun the cache."""
    cfg = registry.reduced_config("rwkv6-1.6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(rid=0, prompt=list(range(17)), max_new=1))
    assert eng.pending() == 0


def test_per_phase_attn_impl_selection():
    """Prefill and decode pin their own registry-resolved attention impls;
    an explicit per-phase choice is honored and still matches the oracle."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                      prefill_buckets=(8,))
    assert eng.decode_attn_impl == "naive"     # s_q=1 rows stay whole-row
    # a config that PINS an impl keeps it for both phases (the engine's
    # per-phase defaults defer to cfg.attn_impl rather than clobber it)
    pinned = ServeEngine(cfg.replace(attn_impl="naive"), params, n_slots=1,
                         max_seq=32, prefill_buckets=(8,))
    assert pinned.prefill_attn_impl == "naive"
    assert pinned.decode_attn_impl == "naive"
    eng2 = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                       prefill_buckets=(8,),
                       prefill_attn_impl="flash_pallas",
                       decode_attn_impl="naive")
    assert eng2.prefill_attn_impl == "flash_pallas"
    out = eng2.run([Request(rid=0, prompt=[1, 2, 3], max_new=4)])[0]
    assert out == _oracle(cfg, params, [1, 2, 3], 4)


def test_dualmode_engine_refuses_float_blocked_prefill():
    """softmax_impl='dualmode' + an explicit float blocked prefill impl
    must fail at engine construction, not silently drop the unit."""
    cfg = registry.reduced_config("qwen1.5-0.5b").replace(
        softmax_impl="dualmode")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="dualmode"):
        ServeEngine(cfg, params, n_slots=1, max_seq=32,
                    prefill_buckets=(8,), prefill_attn_impl="flash")


def test_slot_reuse_more_requests_than_slots():
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32,
                      prefill_buckets=(8,))
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new=3)
            for i in range(7)]
    outs = eng.run(reqs)
    assert sorted(outs) == list(range(7))
    assert all(len(v) == 3 for v in outs.values())
    assert eng.stats["admitted"] == 7


# ---------------- paged KV cache ----------------

def test_paged_matches_contiguous_mixed_workload():
    """Token-level equivalence of the two cache layouts over a mixed
    greedy workload: ragged prompt lengths, EOS retires mid-stream, a
    repeated prompt that exercises prefix sharing, more requests than
    slots.  Same seed, same params — completions must be IDENTICAL."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)

    def mk_reqs():
        return [Request(rid=0, prompt=list(range(5, 25)), max_new=6),
                Request(rid=1, prompt=list(range(7, 40)), max_new=8),
                Request(rid=2, prompt=[3, 1, 4, 1, 5, 9, 2, 6], max_new=5),
                Request(rid=3, prompt=list(range(5, 25)), max_new=4),
                Request(rid=4, prompt=list(range(40, 44)), max_new=0),
                Request(rid=5, prompt=list(range(10, 48)), max_new=7)]

    paged = ServeEngine(cfg, params, n_slots=3, max_seq=64, seed=0,
                        cache_mode="paged", prefill_chunk=16)
    assert paged.cache_mode == "paged"
    contig = ServeEngine(cfg, params, n_slots=3, max_seq=64, seed=0,
                         cache_mode="contiguous", prefill_buckets=(16, 64))
    out_p = paged.run(mk_reqs())
    out_c = contig.run(mk_reqs())
    assert out_p == out_c
    # paged admission never copies a cache tree; contiguous splices one
    # row per prefill
    assert paged.stats["cache_copies"] == 0
    assert contig.stats["cache_copies"] == contig.stats["prefills"]
    # every block went back: retirement = pure decref, no leaks
    assert paged.pool.in_use() == 0
    assert paged.active == 0 and contig.active == 0


def test_paged_eos_retire_matches_contiguous():
    cfg = registry.reduced_config("yi-6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ref = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                      cache_mode="contiguous")
    out = ref.run([Request(rid=0, prompt=[1, 2, 3], max_new=10)])[0]
    eos = out[2]
    for mode in ("paged", "contiguous"):
        eng = ServeEngine(cfg, params, n_slots=1, max_seq=32, eos_id=eos,
                          cache_mode=mode)
        got = eng.run([Request(rid=0, prompt=[1, 2, 3], max_new=10)])[0]
        assert got == out[:out.index(eos) + 1], mode


def test_paged_prefix_sharing_blocks_accounted():
    """A second request extending an already-prefilled prompt reuses its
    full blocks by reference: shared_blocks counts them, the shared
    prefill is a single chunk, and the tokens still match contiguous."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    base = list(range(5, 45))                        # 40 toks = 5 blocks(8)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=128, seed=0,
                      cache_mode="paged", prefill_chunk=16)
    assert eng.block_size == 8
    eng.run([Request(rid=0, prompt=base, max_new=4)])
    assert eng.stats["shared_blocks"] == 0
    chunks_before = eng.stats["prefill_chunks"]
    out = eng.run([Request(rid=1, prompt=base + [77, 78], max_new=4)])
    # usable prefix = hashes[:(42-1)//8] = 5 full blocks, all registered
    assert eng.stats["shared_blocks"] == 5
    assert eng.stats["prefill_chunks"] == chunks_before + 1
    contig = ServeEngine(cfg, params, n_slots=2, max_seq=128, seed=0,
                         cache_mode="contiguous")
    contig.run([Request(rid=0, prompt=base, max_new=4)])
    ref = contig.run([Request(rid=1, prompt=base + [77, 78], max_new=4)])
    assert out[1] == ref[1]


def test_paged_chunked_prefill_interleaves_decode():
    """A long prompt admitted while another slot is decoding must not
    stall it: decode ticks keep firing between prefill chunks."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=128, seed=0,
                      cache_mode="paged", prefill_chunk=8)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=30))
    eng.step()                                   # admit + first decode
    assert eng._slots[0].decoding
    eng.submit(Request(rid=1, prompt=list(range(5, 85)), max_new=8))
    decoded_before = len(eng._slots[0].out)
    steps = 0
    while not eng._slots[1].decoding:
        eng.step()                               # rid 1 prefills 80/8 chunks
        steps += 1
        assert steps < 50
    # rid 0 decoded one token per engine step THROUGHOUT rid 1's prefill
    assert len(eng._slots[0].out) - decoded_before >= 80 // 8
    out = eng.run([])                            # drain
    contig = ServeEngine(cfg, params, n_slots=2, max_seq=128, seed=0,
                         cache_mode="contiguous")
    ref = contig.run([Request(rid=0, prompt=[1, 2, 3], max_new=30),
                      Request(rid=1, prompt=list(range(5, 85)), max_new=8)])
    assert out == ref


def test_paged_overlong_and_overcapacity_raise():
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                      cache_mode="paged")
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(Request(rid=0, prompt=list(range(33)), max_new=1))
    # within max_seq but over the pool's worst-case reach
    small = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                        cache_mode="paged", num_blocks=2)
    with pytest.raises(ValueError, match="exceeds"):
        small.submit(Request(rid=0, prompt=list(range(20)), max_new=8))
    assert eng.pending() == 0 and small.pending() == 0


def test_paged_rejects_unsupported_arch():
    cfg = registry.reduced_config("rwkv6-1.6b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, n_slots=1, max_seq=16, cache_mode="paged")
    # auto quietly falls back for state-carrying mixers
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=16)
    assert eng.cache_mode == "contiguous"


class _CountingInt(int):
    """int that counts how often it is compared via <= (the admission
    loop's drain predicate reads `req.max_new <= 0`)."""
    reads = 0

    def __le__(self, other):
        _CountingInt.reads += 1
        return int(self) <= other


def test_zero_token_drain_cost_is_per_queue_not_per_slot():
    """The max_new<=0 drain runs ONCE per admission pass, not once per
    slot: with every slot busy, the queue head's max_new is read O(1)
    times per step — the old in-loop drain re-read it once per slot."""
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=4, max_seq=32, seed=0,
                      prefill_buckets=(8,))
    eng.run([Request(rid=i, prompt=[i + 1], max_new=2)
             for i in range(4)])                 # warm compile caches
    for i in range(4):                           # occupy every slot
        eng.submit(Request(rid=10 + i, prompt=[i + 1], max_new=50))
    for _ in range(4):              # paged prefill: one chunk per step
        eng.step()
    assert eng.active == 4 and all(s.decoding for s in eng._slots)
    _CountingInt.reads = 0
    eng.submit(Request(rid=99, prompt=[7], max_new=_CountingInt(3)))
    eng._admit()
    # one drain pass reads the head once; the slot loop (4 busy slots)
    # must not re-read it
    assert _CountingInt.reads <= 2, _CountingInt.reads
