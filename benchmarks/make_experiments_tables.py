"""Generate the §Dry-run and §Roofline markdown tables for EXPERIMENTS.md
from benchmarks/artifacts/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.make_experiments_tables > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load():
    recs = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def gib(b):
    return f"{b / 2**30:.2f}"


def sec(t):
    return f"{t:.2e}"


def main() -> None:
    recs = load()
    single = [r for r in recs if r.get("mesh") == "pod16x16"]
    multi = [r for r in recs if r.get("mesh") == "pod2x16x16"]

    print("### §Dry-run — compile matrix\n")
    print("| arch | shape | kind | single-pod 16x16 | multi-pod 2x16x16 | "
          "resident/chip | fits 16G |")
    print("|---|---|---|---|---|---|---|")
    multi_by = {(r["arch"], r["shape"]): r for r in multi}
    for r in single:
        m = multi_by.get((r["arch"], r["shape"]))
        s_ok = ("OK" if r.get("ok") else
                "FAIL: " + r.get("error", "?")[:40])
        m_ok = ("OK" if (m and m.get("ok")) else
                ("FAIL" if m else "—"))
        mem = r.get("memory", {})
        print(f"| {r['arch']} | {r['shape']} | {r.get('kind','?')} | "
              f"{s_ok} ({r.get('compile_s','?')}s) | {m_ok} | "
              f"{gib(mem.get('resident_bytes_per_chip', 0))} GiB | "
              f"{'yes' if mem.get('fits_v5e_16g') else 'NO'} |")

    print("\n### §Roofline — per-chip time bounds (single-pod, per step)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | "
          "bottleneck | MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in single:
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        frac = rf["t_compute"] / rf["t_bound"] if rf["t_bound"] else 0
        print(f"| {r['arch']} | {r['shape']} | {sec(rf['t_compute'])} | "
              f"{sec(rf['t_memory'])} | {sec(rf['t_collective'])} | "
              f"{rf['bottleneck']} | {r.get('useful_ratio', 0):.2f} | "
              f"{frac:.2f} |")

    n_ok = sum(r.get("ok", False) for r in recs)
    print(f"\n{n_ok}/{len(recs)} cells compiled OK.")


if __name__ == "__main__":
    main()
