"""Paper Fig. 4 analogue: combined GELU-softmax unit vs separate designs.

ASIC version: [dual-mode softmax + k-datapath] vs [single-mode softmax +
N/2 i-GELU units] at equal throughput — paper reports 3.8-8.4% area and
10.7-13.2% power savings, attributed to removing the i-GELU polynomial
datapath and reusing the exp/log units.

TPU version at equal throughput (same tensors processed):
  separate = float-softmax program + i-GELU program (two datapaths)
  combined = dual-mode unit serving both (one shared exp/log datapath)
We report program op counts (area analogue) and wall time (power
analogue).  The structural saving — the i-GELU polynomial pipeline
disappearing — shows up directly in the op mix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import igelu
from repro.core import softmax_unit as unit

from .common import emit, hlo_op_counts, time_fn, total_real_ops

N = 32
ROWS = 4096


def main() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(ROWS, N)) * 3, jnp.float32)     # attn
    z = jnp.asarray(rng.normal(size=(ROWS, N // 2)) * 2, jnp.float32)  # ffn

    def separate(x, z):
        return jax.nn.softmax(x, axis=-1), igelu.igelu_quant(z)

    def combined(x, z):
        return unit.softmax_dualmode(x), unit.gelu_dualmode(z)

    t_sep = time_fn(jax.jit(separate), x, z)
    t_comb = time_fn(jax.jit(combined), x, z)
    emit("fig4/separate_us", t_sep, "single-mode softmax + i-GELU")
    emit("fig4/combined_us", t_comb, "dual-mode unit both modes")
    emit("fig4/power_analogue_saving", 0.0,
         f"time_delta={(1 - t_comb / t_sep) * 100:.1f}%")

    # AREA analogue — the *incremental datapath* an accelerator must add
    # to gain GELU capability (paper Fig. 3): the proposed design adds
    # only the k-datapath + output multiplier (exp/log ride the existing
    # softmax unit); the alternative adds a full i-GELU unit.
    from repro.core.fixedpoint import quantize
    from repro.core.softmax_unit import gelu_k_int
    zq = quantize(z)
    sig = jnp.ones_like(zq)          # stand-in for the reused softmax out

    def k_datapath(zq):              # the ONLY new arithmetic (Fig. 3)
        k = gelu_k_int(zq)
        return (zq * sig) >> 14, k

    ops_k = total_real_ops(hlo_op_counts(k_datapath, zq))
    ops_ig = total_real_ops(hlo_op_counts(
        lambda t: igelu.igelu_int(t), zq))
    emit("fig4/incremental_ops_proposed", 0.0,
         f"ops={ops_k} (k-datapath + mult; exp/log reused)")
    emit("fig4/incremental_ops_igelu", 0.0, f"ops={ops_ig} (own datapath)")
    emit("fig4/area_analogue_saving", 0.0,
         f"op_delta={(1 - ops_k / max(ops_ig, 1)) * 100:.1f}%")


if __name__ == "__main__":
    main()
