"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/artifacts/dryrun/*.json (written by repro.launch.dryrun)
and prints, per (arch x shape x mesh): the three per-chip time bounds, the
dominant term, MODEL_FLOPS/HLO_FLOPs, and what would move the bottleneck.
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

_ADVICE = {
    "compute": "raise MXU utilization: bigger per-chip tiles (less TP) or "
               "fewer remat recomputes",
    "memory": "cut HBM round-trips: fuse flash-attention intermediates "
              "(Pallas kernel), bf16 score tiles, wider fusion regions",
    "collective": "reshard: move collectives off the critical path "
                  "(reduce-scatter grads, overlap all-gather with compute, "
                  "less TP for small models)",
}


def load() -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def main() -> None:
    recs = load()
    if not recs:
        print("no dry-run artifacts found; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both")
        return
    ok = [r for r in recs if r.get("ok")]
    emit("roofline/cells_ok", 0.0, f"{len(ok)}/{len(recs)}")
    for r in ok:
        if r["mesh"] != "pod16x16":
            continue                      # roofline table is single-pod
        rf = r["roofline"]
        t_b = rf["t_bound"]
        frac = (rf["t_compute"] / t_b) if t_b else 0.0
        emit(f"roofline/{r['arch']}/{r['shape']}", t_b * 1e6,
             f"tc={rf['t_compute']:.3e}s tm={rf['t_memory']:.3e}s "
             f"tn={rf['t_collective']:.3e}s dom={rf['bottleneck']} "
             f"useful={r.get('useful_ratio', 0):.2f} "
             f"roofline_frac={frac:.2f} fix:{_ADVICE[rf['bottleneck']]}")


if __name__ == "__main__":
    main()
