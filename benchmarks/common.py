"""Shared benchmark utilities: timing, HLO op counting, CSV rows."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (jit'd fns: call once to
    compile first)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def hlo_op_counts(fn: Callable, *args) -> dict[str, int]:
    """Count optimized-HLO ops by kind — the TPU analogue of datapath
    area: how many distinct hardware operations the program needs."""
    import re
    txt = jax.jit(fn).lower(*args).compile().as_text()
    counts: dict[str, int] = {}
    for line in txt.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*.*?\s([\w\-]+)\(", line)
        if m:
            op = m.group(1)
            counts[op] = counts.get(op, 0) + 1
    return counts


def total_real_ops(counts: dict[str, int]) -> int:
    """Ops that map to datapath work (exclude pure bookkeeping)."""
    skip = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
            "after-all", "copy"}
    return sum(v for k, v in counts.items() if k not in skip)
