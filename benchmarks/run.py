"""Benchmark driver — one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig4]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
from __future__ import annotations

import argparse
import functools
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,table2,fig4,"
                         "kernels,flash,roofline")
    args = ap.parse_args()
    from . import (bench_kernels, fig4_combined_savings, roofline,
                   table1_accuracy, table2_dualmode_overhead)
    sections = {
        "table1": table1_accuracy.main,
        "table2": table2_dualmode_overhead.main,
        "fig4": fig4_combined_savings.main,
        "kernels": bench_kernels.main,
        "flash": functools.partial(bench_kernels.main_flash,
                                   "BENCH_flash.json"),
        "roofline": roofline.main,
    }
    chosen = (args.only.split(",") if args.only else list(sections))
    print("name,us_per_call,derived")
    failures = []
    for name in chosen:
        try:
            sections[name]()
        except Exception:  # noqa: BLE001 — report all sections
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
