"""Paper Table II analogue: cost of making the softmax unit dual-mode.

ASIC version: single-mode vs dual-mode softmax area/power (paper: +9.9%
area, +2.6% power for N=8/32).  TPU-kernel version: the dual-mode kernel
family is a compile-time specialization, so the analogue costs are
  (a) extra program ops of GELU mode vs plain softmax mode at equal
      element throughput (the pair-max/pair-sum/pair-log datapath), and
  (b) wall-time overhead of the bit-accurate int path vs its float lane
      (what the fixed-point emulation costs ON THIS HOST — on TPU the int
      path IS the unit, there is no emulation overhead).
Runtime mode-dispatch cost is structurally ZERO: mode is a static kernel
parameter, each binary contains exactly one datapath (shown by op counts).

ISSUE 7 adds the SNAPPED-max rows: snapping the online max to a power of
two (what makes the one-sweep int flash kernel possible) perturbs every
probability word by at most the max-quantization octave fraction.  Two
re-validations of the paper's "no accuracy loss" claim under snapping:
  (c) ULP histogram of the 2**-EXP_FRAC prob words, snapped vs classic
      unit — almost all words move by 0-2 ULP, none far, and
  (d) end-task accuracy delta on the bert repro classifier with the
      attention softmax swapped float -> dualmode -> dualmode_snap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import softmax_unit as unit
from repro.kernels import ops
from repro.models.transformer import init_lm, lm_apply
from repro.optim import adamw_init, adamw_update

from .common import emit, hlo_op_counts, time_fn, total_real_ops
from .table1_accuracy import _classifier_cfg, _make_data

N_ELEMS = (8, 32)          # the paper's vector widths
ROWS = 4096                # elements processed per call at equal throughput


# ------------- (c) snapped vs classic: prob-word ULP histogram -------------

def snap_ulp_histogram(n: int = 64, rows: int = 4096) -> dict[str, float]:
    """|Δ word| distribution between the snapped and classic units.

    Both outputs are expressed on the unit's own 2**-EXP_FRAC probability
    grid (the words the hardware would emit); buckets are exact-match,
    1 ULP, 2 ULP, and the tail.
    """
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(rows, n)) * 4, jnp.float32)
    scale = float(1 << unit.EXP_FRAC)
    w_classic = jnp.round(unit.softmax_dualmode(x) * scale).astype(jnp.int32)
    w_snap = jnp.round(
        unit.softmax_snap(unit.quantize(x)) * scale).astype(jnp.int32)
    d = np.abs(np.asarray(w_snap - w_classic)).ravel()
    total = d.size
    return {"ulp0": float((d == 0).sum() / total),
            "ulp1": float((d == 1).sum() / total),
            "ulp2": float((d == 2).sum() / total),
            "ulp3plus": float((d >= 3).sum() / total),
            "ulp_max": float(d.max())}


# ------------- (d) end-task accuracy delta under snapping -------------

def snap_downstream_accuracy(steps: int = 150) -> dict[str, float]:
    """Train the table1 bert-style classifier in FP32 softmax, then eval
    with the attention softmax swapped for each unit variant.  The claim
    re-validated: float == classic unit == snapped unit task accuracy."""
    cfg = _classifier_cfg()
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    params["cls"] = jnp.zeros((cfg.d_model, 2))
    xtr, ytr = _make_data(jax.random.PRNGKey(1))
    xte, yte = _make_data(jax.random.PRNGKey(2), n=256)

    def logits(p, impl, x):
        h, _, _ = lm_apply(p, cfg.replace(softmax_impl=impl), x,
                           return_hidden=True)
        return h.mean(axis=1) @ p["cls"]

    @jax.jit
    def step(params, opt):
        def loss(p):
            lp = jax.nn.log_softmax(logits(p, "float", xtr))
            return -jnp.take_along_axis(lp, ytr[:, None], 1).mean()
        g = jax.grad(loss)(params)
        return adamw_update(g, opt, params, lr=3e-3, weight_decay=0.0)[:2]

    opt = adamw_init(params)
    for _ in range(steps):
        params, opt = step(params, opt)

    return {impl: float((jnp.argmax(logits(params, impl, xte), -1)
                         == yte).mean())
            for impl in ("float", "dualmode", "dualmode_snap")}


def main() -> None:
    rng = np.random.default_rng(0)
    for n in N_ELEMS:
        x = jnp.asarray(rng.normal(size=(ROWS, n)) * 3, jnp.float32)
        # single mode: softmax over N; dual 'GELU mode': N/2 gelu outputs
        z = jnp.asarray(rng.normal(size=(ROWS, n // 2)) * 3, jnp.float32)

        c_soft = hlo_op_counts(lambda t: unit.softmax_dualmode(t), x)
        c_gelu = hlo_op_counts(lambda t: unit.gelu_dualmode(t), z)
        o_soft, o_gelu = total_real_ops(c_soft), total_real_ops(c_gelu)
        emit(f"table2/N{n}/softmax_mode_ops", 0.0, f"ops={o_soft}")
        emit(f"table2/N{n}/gelu_mode_ops", 0.0, f"ops={o_gelu}")
        emit(f"table2/N{n}/mode_op_overhead", 0.0,
             f"ratio={(o_gelu / o_soft):.2f}")

        t_int = time_fn(lambda t: ops.softmax(t, use_kernel=False), x)
        t_float = time_fn(
            lambda t: ops.softmax(t, precision="float", use_kernel=False), x)
        emit(f"table2/N{n}/softmax_int_us", t_int, "bit-accurate unit")
        emit(f"table2/N{n}/softmax_float_us", t_float, "float lane")
        g_int = time_fn(lambda t: ops.gelu(t, use_kernel=False), z)
        emit(f"table2/N{n}/gelu_int_us", g_int, "GELU mode, N/2 outputs")

    hist = snap_ulp_histogram()
    for k, frac in hist.items():
        emit(f"table2/snap_word_{k}", 0.0, f"frac={frac:.4f}"
             if k != "ulp_max" else f"ulp={frac:.0f}")
    # word-for-word: the overwhelming mass moves <= 1 ULP; the tail is the
    # near-1.0 words whose ULP count is just the relative octave-fraction
    # bound scaled by the word value (|Δp| stays under ~2**-8)
    assert hist["ulp0"] + hist["ulp1"] > 0.9, hist
    assert hist["ulp_max"] / (1 << unit.EXP_FRAC) < 4e-3, hist
    accs = snap_downstream_accuracy()
    for impl, a in accs.items():
        emit(f"table2/snap_downstream_acc/{impl}", 0.0, f"acc={a:.3f}")
    delta = max(accs.values()) - min(accs.values())
    emit("table2/snap_acc_delta", 0.0, f"delta={delta:.3f}")
    assert delta <= 0.03, accs     # the paper's claim, under snapping


if __name__ == "__main__":
    main()
