"""Paper Table II analogue: cost of making the softmax unit dual-mode.

ASIC version: single-mode vs dual-mode softmax area/power (paper: +9.9%
area, +2.6% power for N=8/32).  TPU-kernel version: the dual-mode kernel
family is a compile-time specialization, so the analogue costs are
  (a) extra program ops of GELU mode vs plain softmax mode at equal
      element throughput (the pair-max/pair-sum/pair-log datapath), and
  (b) wall-time overhead of the bit-accurate int path vs its float lane
      (what the fixed-point emulation costs ON THIS HOST — on TPU the int
      path IS the unit, there is no emulation overhead).
Runtime mode-dispatch cost is structurally ZERO: mode is a static kernel
parameter, each binary contains exactly one datapath (shown by op counts).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import softmax_unit as unit
from repro.kernels import ops

from .common import emit, hlo_op_counts, time_fn, total_real_ops

N_ELEMS = (8, 32)          # the paper's vector widths
ROWS = 4096                # elements processed per call at equal throughput


def main() -> None:
    rng = np.random.default_rng(0)
    for n in N_ELEMS:
        x = jnp.asarray(rng.normal(size=(ROWS, n)) * 3, jnp.float32)
        # single mode: softmax over N; dual 'GELU mode': N/2 gelu outputs
        z = jnp.asarray(rng.normal(size=(ROWS, n // 2)) * 3, jnp.float32)

        c_soft = hlo_op_counts(lambda t: unit.softmax_dualmode(t), x)
        c_gelu = hlo_op_counts(lambda t: unit.gelu_dualmode(t), z)
        o_soft, o_gelu = total_real_ops(c_soft), total_real_ops(c_gelu)
        emit(f"table2/N{n}/softmax_mode_ops", 0.0, f"ops={o_soft}")
        emit(f"table2/N{n}/gelu_mode_ops", 0.0, f"ops={o_gelu}")
        emit(f"table2/N{n}/mode_op_overhead", 0.0,
             f"ratio={(o_gelu / o_soft):.2f}")

        t_int = time_fn(lambda t: ops.softmax(t, use_kernel=False), x)
        t_float = time_fn(
            lambda t: ops.softmax(t, precision="float", use_kernel=False), x)
        emit(f"table2/N{n}/softmax_int_us", t_int, "bit-accurate unit")
        emit(f"table2/N{n}/softmax_float_us", t_float, "float lane")
        g_int = time_fn(lambda t: ops.gelu(t, use_kernel=False), z)
        emit(f"table2/N{n}/gelu_int_us", g_int, "GELU mode, N/2 outputs")


if __name__ == "__main__":
    main()
