"""Paper Table I analogue: GELU-variant accuracy.

(a) Mean-absolute error of each GELU implementation vs FP32 erf-GELU over
    activation-scale inputs — reproduces the paper's MAE ordering
    (Proposed ~1e-3 regime << i-GELU).
(b) Downstream-task parity: train a small BERT-style classifier in FP32,
    then evaluate with GELU swapped for each variant.  The paper's claim
    is *swapping GELU into the softmax unit does not move task accuracy*;
    real GLUE weights are unavailable offline, so the task is a synthetic
    sequence-classification GLUE stand-in (two bigram LMs; classify which
    generated the sequence) — same claim, same mechanism.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import igelu
from repro.core import softmax_unit as unit
from repro.core.activations import gelu_exact, gelu_tanh
from repro.models.transformer import init_lm, lm_apply
from repro.optim import adamw_init, adamw_update

from .common import emit, time_fn

VARIANTS = {
    "fp32": gelu_exact,
    "gelu_tanh": gelu_tanh,
    "proposed": unit.gelu_dualmode,          # dual-mode unit, int path
    "igelu": igelu.igelu_quant,              # I-BERT baseline
}


def mae_table() -> dict[str, float]:
    rng = np.random.default_rng(0)
    z = jnp.asarray(np.concatenate([
        rng.normal(size=8192) * 1.5,
        rng.normal(size=1024) * 5.0,
        np.linspace(-8, 8, 1024)]), jnp.float32)
    ref = gelu_exact(z)
    out = {}
    for name, fn in VARIANTS.items():
        if name == "fp32":
            continue
        out[name] = float(jnp.abs(fn(z) - ref).mean())
    return out


# ---------------- downstream classifier ----------------

def _make_data(key, vocab=256, seq=32, n=512):
    """Two distinguishable bigram LMs -> binary classification.

    The generating tables are FIXED (seed 42) so train and test draw from
    the same task; `key` only controls the sampled sequences."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(42))
    k3 = key
    t0 = jax.random.gumbel(k1, (vocab, vocab)) * 2
    t1 = jax.random.gumbel(k2, (vocab, vocab)) * 2

    def gen(k, table, n_seq):
        def step(tok, kk):
            nxt = jax.random.categorical(kk, table[tok], axis=-1)
            return nxt, nxt
        first = jax.random.randint(k, (n_seq,), 0, vocab)
        _, seqs = jax.lax.scan(step, first, jax.random.split(k, seq))
        return jnp.moveaxis(seqs, 0, 1)

    x0 = gen(k3, t0, n // 2)
    x1 = gen(jax.random.fold_in(k3, 1), t1, n // 2)
    x = jnp.concatenate([x0, x1])
    y = jnp.concatenate([jnp.zeros(n // 2, jnp.int32),
                         jnp.ones(n // 2, jnp.int32)])
    return x, y


def _classifier_cfg():
    return registry.get_config("bert-base").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, activation="gelu_tanh")


def _logits(params, cfg, x, act: str):
    h, _, _ = lm_apply(params, cfg.replace(activation=act), x,
                       return_hidden=True)
    pooled = h.mean(axis=1)
    return pooled @ params["cls"]


def downstream_accuracy(steps: int = 150) -> dict[str, float]:
    cfg = _classifier_cfg()
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    params["cls"] = jnp.zeros((cfg.d_model, 2))
    xtr, ytr = _make_data(jax.random.PRNGKey(1))
    xte, yte = _make_data(jax.random.PRNGKey(2), n=256)

    @jax.jit
    def step(params, opt):
        def loss(p):
            lg = _logits(p, cfg, xtr, "gelu_tanh")
            lp = jax.nn.log_softmax(lg)
            return -jnp.take_along_axis(lp, ytr[:, None], 1).mean()
        g = jax.grad(loss)(params)
        return adamw_update(g, opt, params, lr=3e-3, weight_decay=0.0)[:2]

    opt = adamw_init(params)
    for _ in range(steps):
        params, opt = step(params, opt)

    accs = {}
    for name in ("gelu_tanh", "gelu_dualmode", "igelu", "gelu_exact"):
        lg = _logits(params, cfg, xte, name)
        accs[name] = float((jnp.argmax(lg, -1) == yte).mean())
    return accs


def main() -> None:
    maes = mae_table()
    for name, m in maes.items():
        emit(f"table1/mae/{name}", 0.0, f"mae={m:.2e}")
    assert maes["proposed"] < maes["igelu"], "paper ordering violated"
    accs = downstream_accuracy()
    for name, a in accs.items():
        emit(f"table1/downstream_acc/{name}", 0.0, f"acc={a:.3f}")
    spread = max(accs.values()) - min(accs.values())
    emit("table1/acc_spread", 0.0, f"spread={spread:.3f}")


if __name__ == "__main__":
    main()
