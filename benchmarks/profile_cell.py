"""Dry-run profiler: top collectives + top tensors for one cell.

    PYTHONPATH=src python -m benchmarks.profile_cell --arch qwen3-14b \
        --shape train_4k

This is the §Perf microscope: it attributes trip-count-weighted wire
bytes to individual collective ops (with their tensor shapes) so each
hillclimb hypothesis targets the actual dominant transfer.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
import argparse
import collections
import re

import jax  # noqa: E402

from repro.launch import hlo_analysis as H  # noqa: E402
from repro.launch.cells import build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def profile(arch: str, shape: str, multi_pod: bool = False, top: int = 12):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape, mesh)
    with mesh:
        compiled = cell.lower().compile()
    txt = compiled.as_text()
    p = H.HloProgram(txt)
    coll = collections.Counter()
    ops_bytes = collections.Counter()

    def walk(comp, mult):
        for line in p.comps.get(comp, ()):
            m = H._DEF_RE.match(line)
            if not m:
                continue
            _, rt, op = m.groups()
            if op == "while":
                c = H._COND_RE.search(line)
                b = H._CALLS_RE.search(line)
                t = p.trip_count(c.group(1)) if c else 1
                if b:
                    walk(b.group(1), mult * t)
                continue
            base = op.replace("-start", "").replace("-done", "")
            if base in H._COLL_FACTOR and not op.endswith("-done"):
                _, rb = H._shape_elems_bytes(rt)
                meta = re.search(r'op_name="([^"]*)"', line)
                tag = (meta.group(1).split("/")[-1][:48] if meta else "?")
                coll[f"{base:20s} {rt[:48]:50s} {tag}"] += \
                    mult * rb * H._COLL_FACTOR[base]
            cc = H._CALLS_RE.search(line)
            if op in ("fusion", "call") and cc and cc.group(1) in p.comps:
                walk(cc.group(1), mult)

    walk(p.entry, 1)
    print(f"== {arch} {shape} {'multi' if multi_pod else 'single'} — "
          f"top collectives (wire bytes/chip) ==")
    total = sum(coll.values())
    for k, v in coll.most_common(top):
        print(f"{v/1e9:9.2f} GB ({v/max(total,1)*100:4.1f}%)  {k}")
    print(f"{total/1e9:9.2f} GB TOTAL -> t_n = {total/50e9:.2f} s")
    return coll


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    profile(args.arch, args.shape, args.multi_pod)


if __name__ == "__main__":
    main()
