"""Kernel micro-benchmarks: dual-mode unit vs native ops at model shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import softmax_unit as unit
from repro.models.flash import flash_attention

from .common import emit, time_fn


def main() -> None:
    rng = np.random.default_rng(0)
    # softmax at attention-row shapes
    for rows, cols in ((512, 128), (1024, 1024)):
        x = jnp.asarray(rng.normal(size=(rows, cols)) * 3, jnp.float32)
        t_unit = time_fn(jax.jit(unit.softmax_dualmode), x)
        t_nat = time_fn(jax.jit(lambda t: jax.nn.softmax(t, -1)), x)
        emit(f"kernels/softmax_unit_{rows}x{cols}_us", t_unit,
             f"native={t_nat:.1f}us ratio={t_unit/t_nat:.2f}")
    # GELU at FFN shapes
    z = jnp.asarray(rng.normal(size=(512, 2816)), jnp.float32)
    t_unit = time_fn(jax.jit(unit.gelu_dualmode), z)
    t_nat = time_fn(jax.jit(jax.nn.gelu), z)
    emit("kernels/gelu_unit_512x2816_us", t_unit,
         f"native={t_nat:.1f}us ratio={t_unit/t_nat:.2f}")
    # flash attention vs naive at a mid shape
    b, s, k, g, h = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = jnp.ones((b, s), bool)
    f = jax.jit(lambda q, kk, v: flash_attention(
        q, kk, v, q_pos=q_pos, kv_valid=valid, block=256))
    emit("kernels/flash_attn_1k_us", time_fn(f, q, kk, v), "block=256")


if __name__ == "__main__":
    main()
