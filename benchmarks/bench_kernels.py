"""Kernel micro-benchmarks: dual-mode unit vs native ops at model shapes."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import softmax_unit as unit
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention_int import flash_attention_pallas_int
from repro.models.attention import _naive_sdpa
from repro.models.flash import flash_attention

from .common import emit, time_fn


def main() -> None:
    rng = np.random.default_rng(0)
    # softmax at attention-row shapes
    for rows, cols in ((512, 128), (1024, 1024)):
        x = jnp.asarray(rng.normal(size=(rows, cols)) * 3, jnp.float32)
        t_unit = time_fn(jax.jit(unit.softmax_dualmode), x)
        t_nat = time_fn(jax.jit(lambda t: jax.nn.softmax(t, -1)), x)
        emit(f"kernels/softmax_unit_{rows}x{cols}_us", t_unit,
             f"native={t_nat:.1f}us ratio={t_unit/t_nat:.2f}")
    # GELU at FFN shapes
    z = jnp.asarray(rng.normal(size=(512, 2816)), jnp.float32)
    t_unit = time_fn(jax.jit(unit.gelu_dualmode), z)
    t_nat = time_fn(jax.jit(jax.nn.gelu), z)
    emit("kernels/gelu_unit_512x2816_us", t_unit,
         f"native={t_nat:.1f}us ratio={t_unit/t_nat:.2f}")
    # flash attention vs naive at a mid shape
    b, s, k, g, h = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = jnp.ones((b, s), bool)
    f = jax.jit(lambda q, kk, v: flash_attention(
        q, kk, v, q_pos=q_pos, kv_valid=valid, block=256))
    emit("kernels/flash_attn_1k_us", time_fn(f, q, kk, v), "block=256")


def main_flash(json_path: str | None = None) -> None:
    """Flash-attention shoot-out: naive vs pure-JAX flash vs Pallas flash.

    Records a BENCH_flash.json baseline so later PRs (backward kernel,
    int-path flash, sharded attention) have a reference.  Off-TPU the
    Pallas kernel runs in interpret mode — the number is a correctness
    checkpoint, not a speed claim; on TPU the same entry measures the
    compiled kernel.
    """
    rng = np.random.default_rng(0)
    b, s, k, g, h = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = jnp.ones((b, s), bool)

    impls = {
        "naive": jax.jit(lambda q_, k_, v_: _naive_sdpa(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid)),
        "flash_jax": jax.jit(lambda q_, k_, v_: flash_attention(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid, block=256)),
        "flash_pallas": lambda q_, k_, v_: flash_attention_pallas(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid),
    }
    results = {"shape": {"b": b, "s": s, "kv_heads": k, "groups": g,
                         "head_dim": h},
               "backend": jax.default_backend(), "us_per_call": {}}
    for name, fn in impls.items():
        t = time_fn(fn, q, kk, v)
        results["us_per_call"][name] = t
        emit(f"kernels/flash_shootout_{name}_us", t,
             f"backend={jax.default_backend()}")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {os.path.abspath(json_path)}")


def main_flash_int(json_path: str | None = None) -> None:
    """Int-path shoot-out: the blocked bit-accurate kernel vs its two
    neighbours — naive dual-mode (same words, whole-row, O(S*T) scores
    materialized) and float blocked flash (same streaming, float words).

    Records BENCH_flash_int.json: the cost of bit-exactness (3 KV sweeps)
    next to what it replaces.  Off-TPU the Pallas number is interpret
    mode — a correctness checkpoint, not a speed claim.  Also records the
    max |naive_dualmode - flash_pallas_int| parity residual, which is
    pure f32 prob@v reduction-order noise (the prob words are identical).
    """
    rng = np.random.default_rng(0)
    b, s, k, g, h = 1, 512, 2, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = jnp.ones((b, s), bool)

    impls = {
        "naive_dualmode": jax.jit(lambda q_, k_, v_: _naive_sdpa(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid,
            softmax_impl="dualmode")),
        "flash_jax_float": jax.jit(lambda q_, k_, v_: flash_attention(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid, block=128)),
        "flash_pallas_int": lambda q_, k_, v_: flash_attention_pallas_int(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid),
    }
    results = {"shape": {"b": b, "s": s, "kv_heads": k, "groups": g,
                         "head_dim": h},
               "backend": jax.default_backend(), "us_per_call": {}}
    outs = {}
    for name, fn in impls.items():
        outs[name] = jax.block_until_ready(fn(q, kk, v))  # warm + capture
        t = time_fn(fn, q, kk, v)
        results["us_per_call"][name] = t
        emit(f"kernels/flash_int_{name}_us", t,
             f"backend={jax.default_backend()}")
    parity = float(jnp.abs(outs["flash_pallas_int"]
                           - outs["naive_dualmode"]).max())
    results["parity_max_abs_vs_naive_dualmode"] = parity
    emit("kernels/flash_int_parity_max_abs", parity * 1e6,
         "combine reduction-order residual, x1e-6 (prob words identical)")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {os.path.abspath(json_path)}")


if __name__ == "__main__":
    main()
    main_flash("BENCH_flash.json")
    main_flash_int("BENCH_flash_int.json")
