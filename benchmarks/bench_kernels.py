"""Kernel micro-benchmarks: dual-mode unit vs native ops at model shapes."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import softmax_unit as unit
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention_int import flash_attention_pallas_int
from repro.models.attention import _naive_sdpa
from repro.models.flash import flash_attention

from .common import emit, time_fn


def main() -> None:
    rng = np.random.default_rng(0)
    # softmax at attention-row shapes
    for rows, cols in ((512, 128), (1024, 1024)):
        x = jnp.asarray(rng.normal(size=(rows, cols)) * 3, jnp.float32)
        t_unit = time_fn(jax.jit(unit.softmax_dualmode), x)
        t_nat = time_fn(jax.jit(lambda t: jax.nn.softmax(t, -1)), x)
        emit(f"kernels/softmax_unit_{rows}x{cols}_us", t_unit,
             f"native={t_nat:.1f}us ratio={t_unit/t_nat:.2f}")
    # GELU at FFN shapes
    z = jnp.asarray(rng.normal(size=(512, 2816)), jnp.float32)
    t_unit = time_fn(jax.jit(unit.gelu_dualmode), z)
    t_nat = time_fn(jax.jit(jax.nn.gelu), z)
    emit("kernels/gelu_unit_512x2816_us", t_unit,
         f"native={t_nat:.1f}us ratio={t_unit/t_nat:.2f}")
    # flash attention vs naive at a mid shape
    b, s, k, g, h = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = jnp.ones((b, s), bool)
    f = jax.jit(lambda q, kk, v: flash_attention(
        q, kk, v, q_pos=q_pos, kv_valid=valid, block=256))
    emit("kernels/flash_attn_1k_us", time_fn(f, q, kk, v), "block=256")


def main_flash(json_path: str | None = None) -> None:
    """Flash-attention shoot-out: naive vs pure-JAX flash vs Pallas flash.

    Records a BENCH_flash.json baseline so later PRs (backward kernel,
    int-path flash, sharded attention) have a reference.  Off-TPU the
    Pallas kernel runs in interpret mode — the number is a correctness
    checkpoint, not a speed claim; on TPU the same entry measures the
    compiled kernel.
    """
    rng = np.random.default_rng(0)
    b, s, k, g, h = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = jnp.ones((b, s), bool)

    impls = {
        "naive": jax.jit(lambda q_, k_, v_: _naive_sdpa(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid)),
        "flash_jax": jax.jit(lambda q_, k_, v_: flash_attention(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid, block=256)),
        "flash_pallas": lambda q_, k_, v_: flash_attention_pallas(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid),
    }
    results = {"shape": {"b": b, "s": s, "kv_heads": k, "groups": g,
                         "head_dim": h},
               "backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu", "us_per_call": {}}
    for name, fn in impls.items():
        t = time_fn(fn, q, kk, v)
        results["us_per_call"][name] = t
        emit(f"kernels/flash_shootout_{name}_us", t,
             f"backend={jax.default_backend()}")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {os.path.abspath(json_path)}")


def main_flash_int(json_path: str | None = None) -> None:
    """Int-path shoot-out: the one-sweep snapped kernel and the
    three-sweep classic oracle vs their neighbours — naive dual-mode
    (whole-row unit, O(S*T) scores materialized) and float blocked flash
    (same streaming, float words).

    Records BENCH_flash_int.json.  Off-TPU the Pallas numbers are
    interpret mode — a correctness checkpoint, not a speed claim.  The
    ``sweeps_rows`` section carries one row per int kernel (sweeps: 1 =
    snapped one-sweep, sweeps: 3 = classic oracle) with its word-parity
    residual against the matching whole-row unit, measured through an
    identity-v probe (output rows ARE the normalized prob words, so the
    residual is exactly 0.0 when the words are bit-identical — no f32
    prob@v reduction-order noise in the way).
    """
    rng = np.random.default_rng(0)
    b, s, k, g, h = 1, 512, 2, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = jnp.ones((b, s), bool)

    from repro.kernels.flash_attention_int import flash_attention_pallas_int3

    impls = {
        "naive_dualmode": jax.jit(lambda q_, k_, v_: _naive_sdpa(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid,
            softmax_impl="dualmode")),
        "flash_jax_float": jax.jit(lambda q_, k_, v_: flash_attention(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid, block=128)),
        "flash_pallas_int": lambda q_, k_, v_: flash_attention_pallas_int(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid),
        "flash_pallas_int3": lambda q_, k_, v_: flash_attention_pallas_int3(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid),
    }
    results = {"shape": {"b": b, "s": s, "kv_heads": k, "groups": g,
                         "head_dim": h},
               "backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu", "us_per_call": {}}
    outs = {}
    for name, fn in impls.items():
        outs[name] = jax.block_until_ready(fn(q, kk, v))  # warm + capture
        t = time_fn(fn, q, kk, v)
        results["us_per_call"][name] = t
        emit(f"kernels/flash_int_{name}_us", t,
             f"backend={jax.default_backend()}")
    parity = float(jnp.abs(outs["flash_pallas_int3"]
                           - outs["naive_dualmode"]).max())
    results["parity_max_abs_vs_naive_dualmode"] = parity
    emit("kernels/flash_int_parity_max_abs", parity * 1e6,
         "combine reduction-order residual, x1e-6 (prob words identical)")

    # word-parity rows: identity-v probe (output rows = normalized prob
    # words) at a small square shape, each kernel vs its whole-row oracle
    sp = 128
    qp = jnp.asarray(rng.normal(size=(1, sp, 1, 1, 32)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(1, sp, 1, 32)), jnp.float32)
    vp = jnp.eye(sp, dtype=jnp.float32)[None, :, None, :]
    probe_pos = jnp.arange(sp)[None]
    probe_valid = jnp.ones((1, sp), bool)

    def probe(kern, oracle_softmax):
        got = kern(qp, kp, vp, q_pos=probe_pos, kv_valid=probe_valid)
        want = _naive_sdpa(qp, kp, vp, q_pos=probe_pos,
                           kv_valid=probe_valid,
                           softmax_impl=oracle_softmax)
        return float(jnp.abs(got - want).max())

    results["sweeps_rows"] = [
        {"impl": "flash_pallas_int", "sweeps": 1,
         "oracle": "whole-row softmax_snap (naive dualmode_snap)",
         "word_parity_residual": probe(flash_attention_pallas_int,
                                       "dualmode_snap")},
        {"impl": "flash_pallas_int3", "sweeps": 3,
         "oracle": "whole-row softmax_int (naive dualmode)",
         "word_parity_residual": probe(flash_attention_pallas_int3,
                                       "dualmode")},
    ]
    for row in results["sweeps_rows"]:
        emit(f"kernels/flash_int_sweeps{row['sweeps']}_word_parity",
             row["word_parity_residual"],
             f"{row['impl']} vs {row['oracle']}")
        assert row["word_parity_residual"] == 0.0, row
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {os.path.abspath(json_path)}")


def check_flash_int_schema(json_path: str) -> None:
    """BENCH_flash_int.json contract: both a sweeps-1 (snapped one-sweep)
    and a sweeps-3 (classic oracle) row, each with an exactly-zero
    word-parity residual vs its whole-row reference."""
    from repro.analysis import schema
    schema.validate_file(json_path, schema.FLASH_INT_SPEC,
                         schema.FLASH_INT_RULES, "BENCH_flash_int.json")
    print(f"# BENCH_flash_int schema OK: {json_path}")


def main_flash_bwd(json_path: str | None = None) -> None:
    """Backward shoot-out: one full (dq, dk, dv) grad step through naive /
    pure-JAX flash / the Pallas kernel, whose VJP now runs the dedicated
    dq and dk/dv backward kernels (kernels/flash_attention_bwd.py) from
    the saved (m, l) residuals — plus the fused-GLU backward kernel next
    to the unfused reference VJP.

    Records BENCH_flash_bwd.json.  Off-TPU the Pallas numbers are
    interpret mode — a correctness checkpoint, not a speed claim; the max
    |pallas - reference| grad residuals are recorded alongside.
    """
    rng = np.random.default_rng(0)
    b, s, k, g, h = 1, 512, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = jnp.ones((b, s), bool)

    from repro.kernels.fused_ffn import _glu_reference, fused_glu_pallas

    def grad_of(fn):
        return jax.jit(jax.grad(
            lambda q_, k_, v_: fn(q_, k_, v_).sum(), argnums=(0, 1, 2)))

    impls = {
        "naive_bwd": grad_of(lambda q_, k_, v_: _naive_sdpa(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid)),
        "flash_jax_bwd": grad_of(lambda q_, k_, v_: flash_attention(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid, block=256)),
        "flash_pallas_bwd": grad_of(lambda q_, k_, v_:
                                    flash_attention_pallas(
                                        q_, k_, v_, q_pos=q_pos,
                                        kv_valid=valid)),
    }
    results = {"shape": {"b": b, "s": s, "kv_heads": k, "groups": g,
                         "head_dim": h},
               "backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu", "us_per_call": {}}
    grads = {}
    for name, fn in impls.items():
        grads[name] = jax.block_until_ready(fn(q, kk, v))  # warm + capture
        t = time_fn(fn, q, kk, v, iters=5)
        results["us_per_call"][name] = t
        emit(f"kernels/flash_bwd_{name}_us", t,
             f"backend={jax.default_backend()}")
    residual = max(
        float(jnp.abs(a - b_).max())
        for a, b_ in zip(grads["flash_pallas_bwd"], grads["flash_jax_bwd"]))
    results["grad_parity_max_abs_vs_flash_jax"] = residual
    emit("kernels/flash_bwd_parity_max_abs", residual * 1e6,
         "max |dq/dk/dv pallas - pure-JAX flash VJP|, x1e-6")

    # fused GLU backward: the VMEM d_gate/d_up kernel vs the unfused graph
    m_, k_, f_ = 256, 512, 1024
    x = jnp.asarray(rng.normal(size=(m_, k_)) * 0.5, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(k_, f_)) / k_ ** 0.5, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(k_, f_)) / k_ ** 0.5, jnp.float32)
    interp = jax.default_backend() != "tpu"
    glu = {
        "glu_ref_bwd": jax.jit(jax.grad(
            lambda *a: _glu_reference(*a, "silu").sum(), argnums=(0, 1, 2))),
        "glu_fused_bwd": jax.jit(jax.grad(
            lambda *a: fused_glu_pallas(
                *a, mode="silu", interpret=interp).sum(),
            argnums=(0, 1, 2))),
    }
    gouts = {}
    for name, fn in glu.items():
        gouts[name] = jax.block_until_ready(fn(x, wg, wu))
        t = time_fn(fn, x, wg, wu, iters=5)
        results["us_per_call"][name] = t
        emit(f"kernels/{name}_us", t, f"backend={jax.default_backend()}")
    glu_res = max(float(jnp.abs(a - b_).max()) for a, b_ in
                  zip(gouts["glu_fused_bwd"], gouts["glu_ref_bwd"]))
    results["glu_grad_parity_max_abs_vs_reference"] = glu_res
    emit("kernels/glu_bwd_parity_max_abs", glu_res * 1e6,
         "max |d(x,wg,wu) fused - unfused reference VJP|, x1e-6")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {os.path.abspath(json_path)}")


def main_flash_ring(json_path: str | None = None, ring_devices: int = 8
                    ) -> None:
    """Ring shoot-out: sequence-parallel flash_ring on an emulated
    ring-devices-wide mesh vs the single-device Pallas kernel, with the
    per-hop-count parity residual recorded.

    Needs >= ring_devices devices; off-TPU with a single CPU device it
    re-execs itself in a child with
    ``XLA_FLAGS=--xla_force_host_platform_device_count`` set, so
    ``python -m benchmarks.bench_kernels`` works from any host.  Records
    BENCH_flash_ring.json: tokens/s for both paths (interpret mode off
    TPU — a correctness checkpoint, not a speed claim) and the max
    |ring - single-device| output residual per ring width (1/2/4/8
    hops), i.e. the merge's split-point invariance at kernel scale.
    """
    if len(jax.devices()) < ring_devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count="
                            f"{ring_devices}").strip()
        # force cpu: the device-count flag only affects the host platform,
        # so inheriting e.g. JAX_PLATFORMS=tpu would re-exec forever
        env["JAX_PLATFORMS"] = "cpu"
        subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_kernels",
             "--ring-only", json_path or "BENCH_flash_ring.json"],
            check=True, env=env)
        return

    from repro.kernels.ring_attention import ring_flash_attention
    from repro.launch.mesh import auto_mesh

    rng = np.random.default_rng(0)
    b, s, k, g, h = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, k, g, h)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, k, h)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = jnp.ones((b, s), bool)

    single = lambda q_, k_, v_: flash_attention_pallas(
        q_, k_, v_, q_pos=q_pos, kv_valid=valid)
    out_single = jax.block_until_ready(single(q, kk, v))
    t_single = time_fn(single, q, kk, v, iters=3)

    results = {"shape": {"b": b, "s": s, "kv_heads": k, "groups": g,
                         "head_dim": h},
               "backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu",
               "n_devices": len(jax.devices()),
               "us_per_call": {"flash_pallas_1dev": t_single},
               "tokens_per_s": {"flash_pallas_1dev": b * s / t_single * 1e6},
               "parity_max_abs_vs_1dev_by_hops": {}}
    emit("kernels/flash_ring_single_us", t_single,
         f"backend={jax.default_backend()}")
    hops = 2
    while hops <= results["n_devices"]:
        mesh = auto_mesh((hops,), ("model",))
        ring = lambda q_, k_, v_: ring_flash_attention(
            q_, k_, v_, q_pos=q_pos, kv_valid=valid, mesh=mesh)
        out_ring = jax.block_until_ready(ring(q, kk, v))
        parity = float(jnp.abs(out_ring - out_single).max())
        t_ring = time_fn(ring, q, kk, v, iters=3)
        results["us_per_call"][f"flash_ring_{hops}dev"] = t_ring
        results["tokens_per_s"][f"flash_ring_{hops}dev"] = \
            b * s / t_ring * 1e6
        results["parity_max_abs_vs_1dev_by_hops"][str(hops)] = parity
        emit(f"kernels/flash_ring_{hops}dev_us", t_ring,
             f"parity_vs_1dev={parity:.2e}")
        hops *= 2
    assert max(results["parity_max_abs_vs_1dev_by_hops"].values()) <= 1e-5
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {os.path.abspath(json_path)}")


def main_decode(json_path: str | None = None,
                cache_lens: tuple[int, ...] = (4096, 16384, 65536),
                splits: tuple[int, ...] = (1, 2, 4, 8),
                engine_max_seq: int = 2048, engine_requests: int = 6,
                engine_max_new: int = 8) -> None:
    """Decode shoot-out: naive s_q=1 attention vs the split-KV
    flash-decode kernel across cache lengths and split counts, plus
    engine-level continuous-batching throughput with mixed-length slots.

    Records BENCH_decode.json — the serving-throughput trajectory file:
    us/token per (cache length, impl, split count), the max
    |flash_decode - naive| output residual per cache length, and
    tokens/sec through a reduced ServeEngine whose decode program runs
    each impl.  Off-TPU the Pallas numbers are interpret mode — a
    correctness checkpoint, not a speed claim; on TPU the same entries
    measure the compiled kernel.
    """
    from repro.configs import registry
    from repro.kernels.flash_decode import flash_decode_pallas
    from repro.models.transformer import init_lm
    from repro.serve import Request, ServeEngine

    rng = np.random.default_rng(0)
    b, kh, g, h = 1, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, 1, kh, g, h)), jnp.float32)
    results = {"shape": {"b": b, "kv_heads": kh, "groups": g, "head_dim": h},
               "backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu",
               "cache_lens": list(cache_lens), "splits": list(splits),
               "us_per_token": {"naive": {}, "flash_decode": {}},
               "parity_max_abs_vs_naive": {}, "engine": {}}
    for t in cache_lens:
        kk = jnp.asarray(rng.normal(size=(b, t, kh, h)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, t, kh, h)), jnp.float32)
        q_pos = jnp.full((b, 1), t - 1, jnp.int32)
        valid = jnp.ones((b, t), bool)
        naive = jax.jit(lambda q_, k_, v_, qp=q_pos, va=valid: _naive_sdpa(
            q_, k_, v_, q_pos=qp, kv_valid=va))
        out_naive = jax.block_until_ready(naive(q, kk, v))
        t_naive = time_fn(naive, q, kk, v, iters=5)
        results["us_per_token"]["naive"][str(t)] = t_naive
        emit(f"kernels/decode_naive_{t}_us", t_naive,
             f"backend={jax.default_backend()}")
        per_split, parity = {}, 0.0
        for ns in splits:
            fn = lambda q_, k_, v_, ns_=ns, qp=q_pos, va=valid: \
                flash_decode_pallas(q_, k_, v_, q_pos=qp, kv_valid=va,
                                    num_splits=ns_)
            out = jax.block_until_ready(fn(q, kk, v))
            parity = max(parity, float(jnp.abs(out - out_naive).max()))
            t_fd = time_fn(fn, q, kk, v, iters=5)
            per_split[str(ns)] = t_fd
            emit(f"kernels/decode_flash_{t}_splits{ns}_us", t_fd,
                 f"parity_vs_naive={parity:.2e}")
        results["us_per_token"]["flash_decode"][str(t)] = per_split
        results["parity_max_abs_vs_naive"][str(t)] = parity
    assert max(results["parity_max_abs_vs_naive"].values()) <= 1e-5

    # engine-level: continuous batching with MIXED-length slots, decode
    # program pinned to each impl — tokens/sec over the full run (the
    # ragged per-slot tile skip is what flash_decode adds here)
    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    lens = [2 + 5 * (i % 4) for i in range(engine_requests)]  # 2..17 mixed
    tps = {}
    for impl in ("naive", "flash_decode"):
        eng = ServeEngine(cfg, params, n_slots=4, max_seq=engine_max_seq,
                          prefill_buckets=(32,), decode_attn_impl=impl)
        reqs = [Request(rid=i, prompt=list(range(1, n + 1)),
                        max_new=engine_max_new)
                for i, n in enumerate(lens)]
        t0 = time.perf_counter()
        outs = eng.run(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(o) for o in outs.values())
        tps[impl] = toks / dt
        emit(f"serve/decode_engine_{impl}_tok_s", dt / max(toks, 1) * 1e6,
             f"{toks} tokens, max_seq={engine_max_seq}")
    results["engine"] = {"arch": cfg.name, "max_seq": engine_max_seq,
                         "n_slots": 4, "prompt_lens": lens,
                         "max_new": engine_max_new, "tokens_per_s": tps}
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {os.path.abspath(json_path)}")


def check_decode_schema(json_path: str) -> None:
    """BENCH_decode.json contract: per-cache-length us/token for naive and
    per-split flash_decode, a parity residual per cache length, and engine
    tokens/sec for both decode impls.  Lengths/splits themselves may vary
    (the CI smoke runs a reduced sweep)."""
    from repro.analysis import schema
    schema.validate_file(json_path, schema.DECODE_SPEC,
                         schema.DECODE_RULES, "BENCH_decode.json")
    print(f"# BENCH_decode schema OK: {json_path}")



def _run_engine_traced(eng, reqs):
    """Drive the engine step-by-step, tracking concurrency high-water and
    decode progress on the steps where a prefill chunk also ran."""
    for r in reqs:
        eng.submit(r)
    conc_hwm = 0
    prefill_steps_with_decoders = 0
    decode_ticks_during_prefill = 0
    t0 = time.perf_counter()
    steps = 0
    while eng.pending() and steps < 10_000:
        chunks0 = eng.stats["prefill_chunks"]
        decodes0 = eng.stats["decode_steps"]
        had_decoders = any(sl.decoding for sl in eng._slots)
        eng.step()
        steps += 1
        conc_hwm = max(conc_hwm, eng.active)
        if eng.stats["prefill_chunks"] > chunks0 and had_decoders:
            prefill_steps_with_decoders += 1
            decode_ticks_during_prefill += (eng.stats["decode_steps"]
                                            - decodes0)
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in eng.finished.values())
    return {"tokens": toks, "wall_s": dt,
            "tokens_per_s": toks / dt,
            "concurrent_hwm": conc_hwm,
            "decode_ticks_per_prefill_step":
                (decode_ticks_during_prefill / prefill_steps_with_decoders
                 if prefill_steps_with_decoders else None)}


def main_serve(json_path: str | None = None, *, n_requests: int = 12,
               n_slots: int = 4, max_seq: int = 256,
               max_new: int = 8, prefill_chunk: int = 32) -> None:
    """Serving shoot-out: paged block-table KV cache vs the slotted
    contiguous layout AT EQUAL HBM (the paged pool holds exactly the
    contiguous cache's token capacity, but gets 2x the scheduler slots —
    worst-case-reach admission is what lets it use them).

    Records BENCH_serve.json: tokens/s per cache mode, the paged pool's
    blocks-in-use high-water, mean admission latency, cache-tree copies
    per admission (paged must be ZERO — that is the tentpole claim),
    concurrency high-water at equal HBM, and decode ticks per
    chunked-prefill step (1.0 = decode never stalled behind a prompt).
    Off-TPU everything here is interpret/CPU timing — a scheduling and
    correctness checkpoint, not a speed claim.
    """
    from repro.configs import registry
    from repro.models.transformer import init_lm
    from repro.serve import Request, ServeEngine

    cfg = registry.reduced_config("qwen1.5-0.5b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    def mk_reqs():
        reqs = []
        shared = list(range(100, 124))                # 24-token base
        for i in range(n_requests):
            if i % 3 == 2:       # every third request extends the shared
                prompt = shared + [int(x) for x in
                                   rng.integers(1, 200, size=i % 5 + 1)]
            else:
                plen = int(rng.integers(4, 28))
                prompt = [int(x) for x in rng.integers(1, 200, size=plen)]
            reqs.append(Request(rid=i, prompt=prompt, max_new=max_new))
        return reqs

    results = {"backend": jax.default_backend(),
               "interpret": jax.default_backend() != "tpu",
               "arch": cfg.name,
               "workload": {"n_requests": n_requests, "max_new": max_new,
                            "max_seq": max_seq,
                            "prefill_chunk": prefill_chunk},
               "equal_hbm_tokens": n_slots * max_seq, "modes": {}}
    for mode in ("contiguous", "paged"):
        kw = dict(cache_mode=mode, max_seq=max_seq, seed=0)
        if mode == "paged":
            # 2x slots, same token budget: the pool is sized to the
            # contiguous cache (n_slots rows of max_seq tokens)
            bs = __import__("repro.kernels.tiling",
                            fromlist=["x"]).paged_block_size(max_seq)
            kw.update(n_slots=2 * n_slots, prefill_chunk=prefill_chunk,
                      num_blocks=n_slots * (max_seq // bs) + 1)
        else:
            kw.update(n_slots=n_slots, prefill_buckets=(32, max_seq))
        eng = ServeEngine(cfg, params, **kw)
        run = _run_engine_traced(eng, mk_reqs())
        st = eng.stats
        run.update({
            "cache_copies": st["cache_copies"],
            "admit_latency_us_mean":
                st["admit_time_s"] / max(st["admitted"], 1) * 1e6,
            "prefill_chunks": st["prefill_chunks"],
            "shared_blocks": st["shared_blocks"],
            "blocks_hwm": (eng.pool.hwm if eng.pool is not None else None),
            "n_slots": kw["n_slots"]})
        results["modes"][mode] = run
        emit(f"serve/{mode}_tok_s", run["wall_s"] / max(run["tokens"], 1)
             * 1e6, f"{run['tokens']} tokens, conc_hwm="
             f"{run['concurrent_hwm']}, copies={run['cache_copies']}")

    # mixed per-phase impls (ISSUE 7 / ROADMAP carried item): float
    # prefill + dual-mode decode — prompt ingestion at float speed, every
    # GENERATED token's attention through the bit-accurate snapped int
    # split-KV path.  Needs a cache deep enough for the decode resolution
    # to pick flash_decode (not whole-row naive), hence its own max_seq.
    mixed_seq = 2048
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=mixed_seq, seed=0,
                      cache_mode="contiguous", prefill_buckets=(32,),
                      decode_softmax_impl="dualmode")
    run = _run_engine_traced(eng, mk_reqs())
    run.update({"max_seq": mixed_seq,
                "prefill_attn_impl": eng.prefill_attn_impl,
                "prefill_softmax_impl": eng.prefill_softmax_impl,
                "decode_attn_impl": eng.decode_attn_impl,
                "decode_softmax_impl": eng.decode_softmax_impl})
    assert eng.decode_attn_impl == "flash_decode", run
    results["mixed_phase"] = run
    emit("serve/mixed_float_prefill_dualmode_decode_tok_s",
         run["wall_s"] / max(run["tokens"], 1) * 1e6,
         f"{run['tokens']} tokens, decode={eng.decode_attn_impl}"
         f"/{eng.decode_softmax_impl}")

    # pressure rows (ISSUE 10): a decode-heavy workload on a pool sized
    # at 0.5x the worst-case block demand of a full slot complement,
    # worst-case reservation vs reactive allocation + preemption.
    # Reserving only prompt reach must buy strictly more concurrency at
    # equal memory, and preemption/recompute must be invisible in the
    # token counts — every request terminates, nothing leaks.  The
    # requests are deterministic and UNIFORM (2-block prompts, 6-block
    # worst-case reach, no shared prefixes): mixed sizes would let
    # worst-case reservation sneak small requests into the pool and tie
    # the concurrency high-water it is supposed to lose.
    from repro.kernels import tiling
    bs = tiling.paged_block_size(max_seq)
    press_slots = n_slots
    press_reqs = [Request(rid=i, prompt=[1000 * i + j + 1
                                         for j in range(2 * bs)],
                          max_new=4 * bs)
                  for i in range(2 * press_slots)]
    worst = tiling.cdiv(6 * bs, bs)                   # 6 blocks apiece
    press_blocks = (press_slots * worst) // 2 + 1
    results["pressure"] = {"num_blocks": press_blocks - 1,
                           "worst_case_demand": press_slots * worst,
                           "modes": {}}
    for adm in ("worst_case", "reactive"):
        eng = ServeEngine(cfg, params, cache_mode="paged", seed=0,
                          n_slots=press_slots, max_seq=max_seq,
                          prefill_chunk=prefill_chunk,
                          num_blocks=press_blocks, admission=adm)
        run = _run_engine_traced(eng, [Request(**vars(r))
                                       for r in press_reqs])
        st = eng.stats
        run.update({"preemptions": st["preemptions"],
                    "resumes": st["resumes"],
                    "admit_blocked": st["admit_blocked"],
                    "hol_skips": st["hol_skips"],
                    "unterminated": sum(1 for r in press_reqs
                                        if r.rid not in eng.finished),
                    "leaked_blocks": eng.pool.in_use()})
        results["pressure"]["modes"][adm] = run
        emit(f"serve/pressure_{adm}_tok_s",
             run["wall_s"] / max(run["tokens"], 1) * 1e6,
             f"{run['tokens']} tokens, conc_hwm={run['concurrent_hwm']}, "
             f"preempts={run['preemptions']}, "
             f"blocked={run['admit_blocked']}")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {os.path.abspath(json_path)}")


def main_block(json_path: str | None = None, *, m: int = 1024,
               d: int = 512, f: int = 2048, kind: str = "rms",
               eps: float = 1e-6) -> None:
    """Block norm-seam shoot-out: each fused Pallas seam
    (kernels/fused_norm.py) vs its dense two-kernel composition, with the
    analytic HBM-bytes-per-block saving recorded per seam.

    The saving is analytic f32 stream accounting of the intermediate the
    fusion never materializes in HBM:

      * norm1 -> QKV prologue: dense writes, then re-reads, the
        normalized activations h (m x d) -> 2*m*d*4 bytes saved;
      * residual-add + norm2 epilogue: dense re-reads the residual sum
        it just wrote before normalizing -> m*d*4 saved;
      * norm2 -> gate/up GLU prologue: same stream shape as the QKV
        seam -> 2*m*d*4 saved.

    Off-TPU the fused timings are interpret mode — a correctness
    checkpoint, not a speed claim; the parity columns are the real
    content there.  Records BENCH_block.json, validated by
    ``analysis.schema.BLOCK_SPEC``/``BLOCK_RULES``.
    """
    from repro.kernels import datapath as dp
    from repro.kernels.fused_norm import (fused_norm_glu, fused_norm_linear,
                                          fused_residual_norm)

    rng = np.random.default_rng(0)
    interp = jax.default_backend() != "tpu"
    itemsize = 4
    x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    g = jnp.asarray(1.0 + 0.1 * rng.normal(size=(d,)), jnp.float32)
    b = (jnp.asarray(0.1 * rng.normal(size=(d,)), jnp.float32)
         if kind == "layer" else None)
    w_qkv = jnp.asarray(rng.normal(size=(d, 3 * d)) / d ** 0.5, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(d, f)) / d ** 0.5, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(d, f)) / d ** 0.5, jnp.float32)

    def norm_dense(t):
        return (dp.rmsnorm(t, g, eps) if kind == "rms"
                else dp.layernorm(t, g, b, eps))

    md = m * d * itemsize
    impls = {
        "attn_qkv_prologue": (
            jax.jit(lambda x_: norm_dense(x_) @ w_qkv),
            lambda x_: fused_norm_linear(x_, g, b, w_qkv, kind=kind,
                                         eps=eps, interpret=interp),
            (x,),
            # read x, write+read h, read w, write y  /  h never lands
            3 * md + (d * 3 * d + m * 3 * d) * itemsize, 2 * md),
        "attn_out_epilogue": (
            jax.jit(lambda x_, r_: (x_ + r_, norm_dense(x_ + r_))),
            lambda x_, r_: fused_residual_norm(x_, r_, g, b, kind=kind,
                                               eps=eps, interpret=interp),
            (x, r),
            # read x+r, write x_new, re-read x_new, write h
            5 * md, md),
        "ffn_glu_prologue": (
            jax.jit(lambda x_: dp.pair_act(norm_dense(x_) @ wg, "gelu")
                    * (norm_dense(x_) @ wu)),
            lambda x_: fused_norm_glu(x_, g, b, wg, wu, kind=kind,
                                      eps=eps, mode="gelu",
                                      interpret=interp),
            (x,),
            3 * md + (2 * d * f + m * f) * itemsize, 2 * md),
    }
    results = {"backend": jax.default_backend(), "interpret": interp,
               "shape": {"m": m, "d": d, "f": f}, "norm_kind": kind,
               "seams": {}}
    for name, (dense_fn, fused_fn, args, dense_bytes, saved) in impls.items():
        out_d = jax.tree_util.tree_leaves(
            jax.block_until_ready(dense_fn(*args)))
        out_f = jax.tree_util.tree_leaves(
            jax.block_until_ready(fused_fn(*args)))
        parity = max(float(jnp.abs(a - b_).max())
                     for a, b_ in zip(out_f, out_d))
        us_d = time_fn(dense_fn, *args, iters=5)
        us_f = time_fn(fused_fn, *args, iters=5)
        results["seams"][name] = {
            "dense_hbm_bytes": dense_bytes,
            "fused_hbm_bytes": dense_bytes - saved,
            "saved_bytes": saved,
            "us_dense": us_d, "us_fused": us_f,
            "parity_max_abs": parity}
        emit(f"kernels/block_{name}_us", us_f,
             f"dense={us_d:.1f}us saved={saved}B parity={parity:.2e}")
    dense_total = sum(s["dense_hbm_bytes"]
                      for s in results["seams"].values())
    saved_total = sum(s["saved_bytes"] for s in results["seams"].values())
    results["block_total"] = {
        "dense_hbm_bytes": dense_total,
        "fused_hbm_bytes": dense_total - saved_total,
        "saved_bytes": saved_total,
        "saved_frac": saved_total / dense_total}
    emit("kernels/block_hbm_saved_pct",
         results["block_total"]["saved_frac"] * 100,
         f"{saved_total} of {dense_total} bytes per block (m={m} d={d})")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {os.path.abspath(json_path)}")


def check_block_schema(json_path: str) -> None:
    """BENCH_block.json contract: every fused seam records a positive
    HBM-bytes saving consistent with its dense/fused accounting, the
    epilogue holds the pinned 1e-5 dense-contract parity, and the matmul
    prologues stay within small-ULP reassociation (5e-5)."""
    from repro.analysis import schema
    schema.check_block_json(json_path)
    print(f"# BENCH_block schema OK: {json_path}")


def check_serve_schema(json_path: str) -> None:
    """BENCH_serve.json contract: zero cache copies on paged admission,
    strictly more concurrent slots than contiguous at equal HBM, and
    decode not stalling during chunked prefill (>= 1 decode tick per
    prefill-chunk step)."""
    from repro.analysis import schema
    schema.validate_file(json_path, schema.SERVE_SPEC,
                         schema.SERVE_RULES, "BENCH_serve.json")
    print(f"# BENCH_serve schema OK: {json_path}")


if __name__ == "__main__":
    if "--check-audit" in sys.argv:
        # validate an existing AUDIT.json through the same declarative
        # engine the bench schemas use (CI pairs this with the audit job)
        from repro.analysis import schema
        i = sys.argv.index("--check-audit")
        path = (sys.argv[i + 1] if len(sys.argv) > i + 1
                else "AUDIT.json")
        schema.check_audit_json(path)
        print(f"# AUDIT schema OK: {path}")
        sys.exit(0)
    if "--ring-only" in sys.argv:
        i = sys.argv.index("--ring-only")
        main_flash_ring(sys.argv[i + 1] if len(sys.argv) > i + 1
                        else "BENCH_flash_ring.json")
        sys.exit(0)
    if "--flash-int-only" in sys.argv:
        i = sys.argv.index("--flash-int-only")
        path = (sys.argv[i + 1] if len(sys.argv) > i + 1
                else "BENCH_flash_int.json")
        main_flash_int(path)
        check_flash_int_schema(path)
        sys.exit(0)
    if "--serve-only" in sys.argv:
        i = sys.argv.index("--serve-only")
        path = (sys.argv[i + 1] if len(sys.argv) > i + 1
                else "BENCH_serve.json")
        if "--quick" in sys.argv:   # CI smoke: fewer requests, same schema
            main_serve(path, n_requests=8, n_slots=2, max_seq=128,
                       max_new=4, prefill_chunk=16)
        else:
            main_serve(path)
        check_serve_schema(path)
        sys.exit(0)
    if "--block-only" in sys.argv:
        i = sys.argv.index("--block-only")
        path = (sys.argv[i + 1] if len(sys.argv) > i + 1
                else "BENCH_block.json")
        if "--quick" in sys.argv:   # CI smoke: small shapes, same schema
            main_block(path, m=128, d=128, f=256)
        else:
            main_block(path)
        check_block_schema(path)
        sys.exit(0)
    if "--decode-only" in sys.argv:
        i = sys.argv.index("--decode-only")
        path = (sys.argv[i + 1] if len(sys.argv) > i + 1
                else "BENCH_decode.json")
        if "--quick" in sys.argv:   # CI smoke: reduced sweep, same schema
            main_decode(path, cache_lens=(2048, 4096), splits=(1, 2),
                        engine_max_seq=1024, engine_requests=3,
                        engine_max_new=3)
        else:
            main_decode(path)
        check_decode_schema(path)
        sys.exit(0)
    main()
    main_flash("BENCH_flash.json")
    main_flash_int("BENCH_flash_int.json")
    main_flash_bwd("BENCH_flash_bwd.json")
    main_flash_ring("BENCH_flash_ring.json")
    main_decode("BENCH_decode.json")
    main_serve("BENCH_serve.json")
    main_block("BENCH_block.json")
